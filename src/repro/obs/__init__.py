"""repro.obs — observability for the staged pipeline.

Three pieces, all stdlib-only (this package sits at the very bottom of the
dependency stack, below even :mod:`repro.core.policy` — it must import from
nowhere inside ``repro``):

* :mod:`repro.obs.trace` — span tracing.  ``with obs.trace.span("plan"):``
  records Chrome-trace complete events when tracing is enabled (off by
  default; the disabled path is one branch/no-op context manager per span
  site).  Export with ``obs.trace.trace_json()`` or, at the pipeline level,
  ``Executable.trace_json()``.
* :mod:`repro.obs.metrics` — one thread-safe registry of counters, gauges
  and p50/p99 histograms.  The analysis/inspector/compile cache stat dicts
  are registry-backed views now; speculation rollbacks, WavefrontError
  rejections, per-backend run counts and serve per-wave latencies live here
  too.  ``obs.metrics.snapshot()`` is the JSON artifact.
* :mod:`repro.obs.profile` — predicted-vs-measured strategy rows (every
  ``StrategyPlan`` offer's predicted cost next to the winning strategy's
  measured wall time), emitted into ``SYNC_REPORTS`` by
  ``benchmarks/run.py``.

``reset_all()`` is the single test/bench reset: metrics, trace buffer,
profiler records, and the three pipeline caches, in one call.
"""

from __future__ import annotations

from . import metrics, profile, trace

__all__ = ["metrics", "profile", "trace", "reset_all", "obs_summary"]


def obs_summary(backend: str = "") -> dict:
    """The deterministic observability stub attached to every
    ``ParallelizationReport.summary()["obs"]``.

    Deliberately carries NO live counter values: two reports for the same
    plan must summarize identically no matter how many pipeline runs
    happened between them (the shim-vs-staged bit-identity contract), so
    this records only where the volatile data lives, plus the report-stable
    tracing flag state at summary time.
    """

    # repro.calibrate is import-light (stdlib + obs.metrics), so the lazy
    # import keeps this module's no-repro-imports rule at module scope only
    from repro.calibrate import summary_pointer

    return {
        "tracing": trace.tracing_enabled(),
        "trace_export": "Executable.trace_json() / obs.trace.trace_json()",
        "metrics_export": "obs.metrics.snapshot()",
        # where the host cost profile lives (report-stable: names the
        # calibration *state*, never measured values — two reports for the
        # same plan summarize identically regardless of runs in between)
        "calibration": summary_pointer(),
        "backend": backend,
    }


def reset_all() -> None:
    """Zero every observability surface and clear the pipeline caches.

    Replaces the three-surface reset dance tests used to do by hand
    (``clear_analysis_cache()`` + ``clear_inspector_cache()`` +
    ``clear_compile_cache()``).  Imports lazily so ``repro.obs`` itself
    stays import-light and cycle-free.
    """

    metrics.reset()
    trace.clear()
    profile.clear()
    from repro.core.inspector import clear_inspector_cache
    from repro.core.parallelizer import clear_analysis_cache

    clear_analysis_cache()
    clear_inspector_cache()
    import sys

    # the compile cache lives behind the lazily-registered xla backend;
    # only clear it when something already paid that import
    cache_mod = sys.modules.get("repro.compile.cache")
    if cache_mod is not None:
        cache_mod.clear_compile_cache()
    # the SPMD backend memoizes mesh/device handles (and owns its own
    # structural cache); dropping them keeps tests that vary
    # --xla_force_host_platform_device_count order-independent
    spmd_mod = sys.modules.get("repro.compile.spmd")
    if spmd_mod is not None:
        spmd_mod.reset_spmd_caches()
    # likewise the plan service's per-tenant LRUs (repro.serve): discard the
    # process-default service so plan_cache.* counters and cache contents
    # reset together
    serve_mod = sys.modules.get("repro.serve.service")
    if serve_mod is not None:
        serve_mod.reset_default_service()
    # and the in-memory cost profile (repro.calibrate): persisted profile
    # files survive on purpose — a reset process re-loads, never re-measures
    calib_mod = sys.modules.get("repro.calibrate")
    if calib_mod is not None:
        calib_mod.reset()
