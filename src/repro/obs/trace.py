"""Zero-dependency span tracer for the staged pipeline.

Spans are context managers::

    with trace.span("plan", method="isd"):
        ...

Disabled by default: ``span()`` then returns a shared no-op context manager
whose enter/exit are empty slots-class methods, so instrumented call sites
cost one function call when tracing is off.  Hot loops (the wavefront
per-level loop) must not even pay that — they hoist ``tracing_enabled()``
once and call :func:`emit` with raw ``perf_counter_ns`` stamps only when it
was true.

Enabled spans record Chrome-trace *complete* events (``"ph": "X"``): wall
timestamps in microseconds, duration, pid/tid, plus the span's keyword args.
Nesting is tracked per thread through a ``threading.local`` stack — two
planner threads tracing concurrently interleave in the buffer but each
thread's own spans keep strict stack discipline (pinned by a test).  The
buffer is a bounded deque guarded by one lock; exceeding the bound drops the
*oldest* events, so a long serving run keeps its most recent waves.

Everything here is stdlib-only on purpose: this module sits below
``repro.core.policy`` in the dependency stack and must never pull in
numpy/jax.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

MAX_EVENTS = 65536

_events: deque = deque(maxlen=MAX_EVENTS)
_events_lock = threading.Lock()
_tls = threading.local()
_enabled = False

# perf_counter_ns is monotonic but epoch-less; anchor ts=0 at import so
# exported traces start near zero instead of at machine uptime
_T0_NS = time.perf_counter_ns()


def enable() -> None:
    """Turn span recording on (global, all threads)."""

    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def tracing_enabled() -> bool:
    return _enabled


class tracing:
    """``with trace.tracing():`` — enable within a block, restore on exit."""

    __slots__ = ("_prev",)

    def __enter__(self) -> "tracing":
        self._prev = _enabled
        enable()
        return self

    def __exit__(self, *exc) -> None:
        global _enabled
        _enabled = self._prev


def _stack() -> List[str]:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


def emit(
    name: str,
    t0_ns: int,
    t1_ns: Optional[int] = None,
    cat: str = "repro",
    **args: Any,
) -> None:
    """Record one complete event from raw ``perf_counter_ns`` stamps.

    The low-level hook for hot loops that hoist the enabled check: caller
    guarantees tracing was enabled when the stamps were taken.
    """

    if t1_ns is None:
        t1_ns = time.perf_counter_ns()
    stack = _stack()
    ev = {
        "name": name,
        "cat": cat,
        "ph": "X",
        "ts": (t0_ns - _T0_NS) / 1000.0,
        "dur": (t1_ns - t0_ns) / 1000.0,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": dict(args, depth=len(stack), parent=stack[-1] if stack else None),
    }
    with _events_lock:
        _events.append(ev)


class _Span:
    __slots__ = ("name", "cat", "args", "t0")

    def __init__(self, name: str, cat: str, args: Dict[str, Any]):
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        _stack().append(self.name)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter_ns()
        stack = _stack()
        stack.pop()
        ev = {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": (self.t0 - _T0_NS) / 1000.0,
            "dur": (t1 - self.t0) / 1000.0,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": dict(
                self.args,
                depth=len(stack) + 1,
                parent=stack[-1] if stack else None,
            ),
        }
        with _events_lock:
            _events.append(ev)


class _NullSpan:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL = _NullSpan()


def span(name: str, cat: str = "repro", **args: Any):
    """A timed span context manager (no-op while tracing is disabled)."""

    if not _enabled:
        return _NULL
    return _Span(name, cat, args)


def events() -> List[dict]:
    """Snapshot of the buffered events, oldest first."""

    with _events_lock:
        return list(_events)


def clear() -> None:
    with _events_lock:
        _events.clear()


def to_chrome_trace() -> Dict[str, Any]:
    """The buffered spans in Chrome trace-event format (load in
    ``chrome://tracing`` / Perfetto)."""

    return {"traceEvents": events(), "displayTimeUnit": "ms"}


def trace_json(indent: Optional[int] = None) -> str:
    return json.dumps(to_chrome_trace(), indent=indent)
