"""Predicted-vs-measured strategy profiler.

``CostModelPolicy`` scores every strategy offer (chunk/skew/dswp/serial)
per recurrence SCC and keeps the full scoreboard on the winning
:class:`~repro.core.policy.StrategyPlan` (its ``offers`` field).  This
module closes ROADMAP item 3c's loop: run the compiled executable, measure
real wall time, and put the measurement NEXT TO every offer's predicted
cost — one row per recurrence SCC — so cost-model mispredictions are
diffable across PRs from the ``SYNC_REPORTS`` artifact alone, and CI can
check the model never inverts a clearly-measured ordering
(``benchmarks/run.py --check-baseline``).

Measured numbers are wall time of ``Executable.run()`` (best of
``repeats``), normalized per schedule level when the backend exposes a
depth, because predicted costs are per-level too (depth × width terms).
Rows are plain JSON-serializable dicts.

Stdlib-only; executables come in from the caller, never imported here.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional

_RECORDS: deque = deque(maxlen=512)


def record(row: Dict[str, Any]) -> None:
    _RECORDS.append(dict(row))


def records() -> List[Dict[str, Any]]:
    return [dict(r) for r in _RECORDS]


def clear() -> None:
    _RECORDS.clear()


def _schedule_depth(exe) -> Optional[int]:
    """Level count of the executable's schedule, when the backend exposes
    one (wavefront artifact, or the compiled program's report summary)."""

    wf = exe.artifacts.get("wavefront")
    if wf is not None:
        return int(wf.depth)
    summary = exe.report().summary()
    depth = summary.get("wavefront_depth")
    return int(depth) if depth is not None else None


def profile_executable(
    exe,
    program: str = "",
    store: Optional[dict] = None,
    repeats: int = 3,
) -> List[Dict[str, Any]]:
    """Measure ``exe.run()`` and pair it with every recurrence SCC's
    predicted offer costs.  Returns the rows (one per recurrence; a single
    whole-program row when the plan has none) and appends them to the
    module record buffer."""

    init = store if store is not None else exe.plan.program.initial_store()
    best = None
    for _ in range(max(1, repeats)):
        fresh = {a: dict(c) for a, c in init.items()}
        t0 = time.perf_counter()
        exe.run(store=fresh)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    measured_us = best * 1e6
    depth = _schedule_depth(exe)

    summary = exe.report().summary()
    recurrences = summary.get("scc", {}).get("recurrences", [])
    rows: List[Dict[str, Any]] = []
    base = {
        "program": program,
        "backend": exe.backend,
        "measured_us": measured_us,
        "levels": depth,
        "measured_us_per_level": (measured_us / depth) if depth else None,
    }
    if recurrences:
        for rec in recurrences:
            offers = rec.get("offers") or {}
            rows.append(
                dict(
                    base,
                    strategy=rec.get("strategy"),
                    predicted_cost=rec.get("cost"),
                    predicted=dict(offers),
                )
            )
    else:
        rows.append(dict(base, strategy="doall", predicted_cost=None, predicted={}))
    for row in rows:
        record(row)
    return rows
