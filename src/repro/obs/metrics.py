"""Unified metrics registry: counters, gauges, histograms behind one
thread-safe surface.

This absorbs the repo's three ad-hoc stat dicts (`_ANALYSIS_STATS` in the
parallelizer, `_INSPECTOR_STATS` in the inspector, `CacheStats` on the
global compile cache) — those modules now hold registry-backed
:class:`Counter` objects and their ``*_cache_stats()`` functions are thin
views over the same values — and carries the new pipeline metrics:
speculation rollbacks, WavefrontError rejections, per-backend run counts,
and the serving loop's per-wave latency histograms.

Instruments are identified by dotted names (``"compile_cache.hits"``,
``"serve.run_ms"``).  ``counter(name)`` is get-or-create, so independent
modules naming the same metric share one instrument.  A single module lock
guards creation and all updates: the hot increments here are cache-counter
bumps at most a few thousand per second, far below the contention regime
where per-instrument locks would matter.

Stdlib-only, same as the rest of ``repro.obs``.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional

_LOCK = threading.Lock()

# bounded percentile reservoir: big enough for every wave of any realistic
# serving run, small enough to sort on demand
HISTOGRAM_SAMPLES = 4096


class Counter:
    """Monotonic (reset-able) integer counter.

    Constructed standalone (``Counter()``) for private per-instance stats —
    test-local :class:`repro.compile.cache.CompileCache` objects keep
    unregistered counters — or via :func:`counter` to register globally.
    """

    __slots__ = ("name", "_value")

    def __init__(self, name: str = ""):
        self.name = name
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with _LOCK:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def reset(self) -> None:
        with _LOCK:
            self._value = 0

    def snapshot(self):
        return self._value


class Gauge:
    """Last-write-wins numeric value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str = ""):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        with _LOCK:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        with _LOCK:
            self._value = 0.0

    def snapshot(self):
        return self._value


class Histogram:
    """Streaming summary (count/sum/min/max) plus a bounded reservoir of the
    most recent samples for p50/p99."""

    __slots__ = ("name", "count", "sum", "min", "max", "_samples")

    def __init__(self, name: str = ""):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._samples: deque = deque(maxlen=HISTOGRAM_SAMPLES)

    def observe(self, v: float) -> None:
        v = float(v)
        with _LOCK:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            self._samples.append(v)

    def percentile(self, p: float) -> Optional[float]:
        """Nearest-rank percentile over the retained reservoir; None when
        nothing was observed."""

        with _LOCK:
            data = sorted(self._samples)
        if not data:
            return None
        rank = max(0, min(len(data) - 1, int(round(p / 100.0 * (len(data) - 1)))))
        return data[rank]

    def reset(self) -> None:
        with _LOCK:
            self.count = 0
            self.sum = 0.0
            self.min = None
            self.max = None
            self._samples.clear()

    def snapshot(self) -> Dict[str, Optional[float]]:
        with _LOCK:
            data = sorted(self._samples)
            count, total = self.count, self.sum
            lo, hi = self.min, self.max

        def _pct(p: float) -> Optional[float]:
            if not data:
                return None
            rank = max(
                0, min(len(data) - 1, int(round(p / 100.0 * (len(data) - 1))))
            )
            return data[rank]

        return {
            "count": count,
            "mean": (total / count) if count else None,
            "min": lo,
            "max": hi,
            "p50": _pct(50.0),
            "p99": _pct(99.0),
        }


class Registry:
    """Name → instrument store; get-or-create per kind, type-checked."""

    def __init__(self) -> None:
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls):
        with _LOCK:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name)
                self._instruments[name] = inst
                return inst
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, not {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self) -> List[str]:
        with _LOCK:
            return sorted(self._instruments)

    def snapshot(self) -> Dict[str, object]:
        """name → value (counters/gauges) or summary dict (histograms);
        JSON-serializable, suitable for the CI artifact."""

        with _LOCK:
            items = list(self._instruments.items())
        return {name: inst.snapshot() for name, inst in sorted(items)}

    def reset(self) -> None:
        """Zero every instrument IN PLACE — modules hold direct references
        to their counters, so instruments are never discarded, only reset."""

        with _LOCK:
            items = list(self._instruments.values())
        for inst in items:
            inst.reset()


REGISTRY = Registry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def snapshot() -> Dict[str, object]:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
