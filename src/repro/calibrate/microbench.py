"""Synthetic microbenchmarks behind :func:`repro.calibrate.measure`.

Each benchmark drives the *real* machinery it prices — the jitted band
step of :mod:`repro.compile.lowering` (with its laundered per-lane
arithmetic, masking and scatter), the sharded variant with its
``all_gather``, and the NumPy wavefront interpreter — instead of an
idealized gather/scatter kernel, because the auction constants only have
to be honest about *this* code on *this* host.  The driver program is a
1-D chain recurrence ``a[i] = f(a[i-d])`` whose carried distance ``d``
pins the chunk width: forced ``scc_policy="chunk"`` lowers it to one
uniform recurrence band of ``~n/d`` levels, each ``d`` lanes wide, so the
per-level cost at several pow2 widths gives a clean (flat, per-lane)
linear fit.

Measurement discipline: the compiled backends are timed on the *jitted
level loop alone* — device buffers are packed once outside the clock and
the jit callable is invoked directly — so the O(cells) host wrapper
(store copy, densify, transfer) never leaks into per-level estimates;
the flat python dispatch that remains is cancelled by differencing two
problem sizes at the same width (only the level count changes between
them).

Everything here is jax-heavy and imported lazily by the package front
door; all compiles go through *local* :class:`CompileCache` instances so
measurement never pollutes the process-global structural caches.  Every
timed sample ticks ``calibrate.measurements`` — the counter the
persistence tests (and the CI artifact) watch to prove a reused profile
re-measures nothing.
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

from repro.obs import metrics as _metrics

# noise floor for fitted units: timing jitter can drive a least-squares
# intercept (or a collective delta) slightly negative, which a cost model
# must never see
_MIN_UNIT_US = 1e-4


def _chain_program(n: int, dist: int):
    from repro.core import ArrayRef, LoopProgram, Statement

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), (ArrayRef("a", -dist),)),
        ),
        bounds=((dist, n),),
    )


def _sync_for(prog):
    from repro.core import analyze, insert_synchronization

    return insert_synchronization(prog, analyze(prog))


def _best_of(fn, repeats: int) -> float:
    """Best-of-N wall time in seconds; every sample is one measurement."""

    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
        _metrics.counter("calibrate.measurements").inc()
    return best


def _fit_line(points) -> Tuple[float, float]:
    """Least-squares ``y = intercept + slope * x`` over ≥ 2 points."""

    xs = [float(x) for x, _ in points]
    ys = [float(y) for _, y in points]
    mx = sum(xs) / len(xs)
    my = sum(ys) / len(ys)
    var = sum((x - mx) ** 2 for x in xs)
    slope = (
        sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / var
        if var
        else 0.0
    )
    return my - slope * mx, slope


def _jit_band_seconds(cache, n: int, dist: int, repeats: int) -> Tuple[
    float, int
]:
    """Best-of wall time of the *jitted level loop alone* for one chain
    program, plus its level count.  One warm ``run_xla`` builds (and
    traces) the artifact; the timed calls then replay the jit callable on
    pre-packed device buffers."""

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental import enable_x64

    from repro.compile.executor import run_xla
    from repro.core.wavefront import _DenseStore

    prog = _chain_program(n, dist)
    sync = _sync_for(prog)
    init = prog.initial_store(pad=dist)
    rep = run_xla(
        sync,
        cache=cache,
        scc_policy="chunk",
        compare=False,
        store=init,
    )
    compiled = rep.compiled
    dense = _DenseStore({a: dict(c) for a, c in init.items()})
    case, _ = compiled.prepare(prog, dense)
    with enable_x64():
        store = {}
        for a in case.arrays:
            flat = np.zeros(case.padded_sizes[a], dtype=np.float64)
            flat[: case.flat_sizes[a]] = dense.data[a].ravel()
            store[a] = jnp.asarray(flat)
        coverage = {}  # chain programs have no sparse arrays

        def call():
            out_store, _, bad = compiled._jit(
                case.static,
                case.n_levels,
                case._device_segdyn,
                case._device_tables,
                store,
                coverage,
                jnp.zeros((2,), bool),
                jnp.int64(0),
            )
            jax.block_until_ready((out_store, bad))

        call()  # warm this exact shape (same bucket — no re-trace)
        best = _best_of(call, repeats)
    return best, rep.stats.levels


def _per_level_us(sample, n: int, dist: int, repeats: int) -> float:
    """Per-level µs via the two-size difference trick: only the level
    count changes between ``n // 2`` and ``n``, so flat per-call overhead
    cancels.  ``sample(size) -> (seconds, levels)``."""

    t_small, l_small = sample(n // 2)
    t_big, l_big = sample(n)
    if l_big <= l_small:  # degenerate sizing; avoid a zero division
        return max((t_big / max(l_big, 1)) * 1e6, _MIN_UNIT_US)
    return max(
        ((t_big - t_small) / (l_big - l_small)) * 1e6, _MIN_UNIT_US
    )


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _pow2_floor(n: int) -> int:
    return 1 if n <= 1 else 1 << (n.bit_length() - 1)


def measure_units(
    *,
    n: int = 8192,
    widths: Tuple[int, ...] = (8, 64, 512),
    repeats: int = 3,
    spmd: Optional[bool] = None,
) -> Tuple[Dict[str, float], dict]:
    """Run the suite; returns ``(units, meta)`` for a fresh CostProfile.

    ``widths`` must be powers of two (each is a carried distance = chunk
    width = padded lane count); ``n`` the largest chain length (the small
    size is ``n // 2``).  ``spmd=None`` measures collectives only when the
    host actually has ≥ 2 devices, else scales the hand-set collective
    ratios by the measured per-lane cost so the profile stays on one unit
    scale.
    """

    from repro.compile.cache import CompileCache

    widths = tuple(sorted({_next_pow2(max(2, w)) for w in widths}))
    if len(widths) < 2:
        raise ValueError(
            f"need >= 2 distinct pow2 widths to fit a lane slope, got "
            f"{widths!r}"
        )
    if n // 2 <= 4 * max(widths):
        raise ValueError(
            f"n={n} too small for widths {widths!r}: the smallest run must "
            "still produce a multi-level recurrence band"
        )
    meta: dict = {"n": n, "widths": list(widths), "repeats": repeats}

    # -- xla band step: flat per-level cost + per padded lane ----------- #
    xla_cache = CompileCache()
    xla_points = [
        (
            w,
            _per_level_us(
                lambda size, w=w: _jit_band_seconds(
                    xla_cache, size, w, repeats
                ),
                n,
                w,
                repeats,
            ),
        )
        for w in widths
    ]
    step, lane_slope = _fit_line(xla_points)
    xla_lane = max(lane_slope, _MIN_UNIT_US)
    xla_step = max(step, _MIN_UNIT_US)
    meta["xla_per_level_us"] = {str(w): y for w, y in xla_points}

    # -- spmd band step: collective flat + per gathered lane ------------ #
    n_dev = 1
    if spmd is not False:
        try:
            import jax

            n_dev = _pow2_floor(jax.local_device_count())
        except Exception:  # pragma: no cover - jax is baked into the image
            n_dev = 1
    if spmd is True or (spmd is None and n_dev >= 2):
        from repro.compile.spmd import SpmdCompiledProgram

        spmd_cache = CompileCache(factory=SpmdCompiledProgram)
        deltas = []
        for w in widths:
            wp = max(w, n_dev)  # the sharded artifact's lane padding
            per_level = _per_level_us(
                lambda size, w=w: _jit_band_seconds(
                    spmd_cache, size, w, repeats
                ),
                n,
                w,
                repeats,
            )
            deltas.append(
                (wp, per_level - (xla_step + xla_lane * wp / n_dev))
            )
        coll, coll_slope = _fit_line(deltas)
        spmd_collective = max(coll, _MIN_UNIT_US)
        spmd_collective_lane = max(coll_slope, _MIN_UNIT_US)
        meta["spmd_delta_us"] = {str(w): d for w, d in deltas}
        meta["spmd_devices"] = n_dev
    else:
        # single-device host: keep the hand-set collective *ratios* (they
        # are expressed in lane units) on the measured lane scale
        import repro.compile.spmd as _spmd

        spmd_collective = _spmd.SPMD_COLLECTIVE_UNITS * xla_lane
        spmd_collective_lane = _spmd.SPMD_COLLECTIVE_LANE_UNITS * xla_lane
        meta["spmd_delta_us"] = "skipped (single-device host)"
        meta["spmd_devices"] = n_dev

    # -- interpreter dispatch: per batched group of the NumPy wavefront - #
    from repro.core.wavefront import run_wavefront

    def wf_sample(size):
        prog = _chain_program(size, widths[0])
        sync = _sync_for(prog)
        init = prog.initial_store(pad=widths[0])
        run_wavefront(  # warm analysis/schedule caches outside the clock
            sync, scc_policy="chunk", compare=False, store=init
        )
        secs = _best_of(
            lambda: run_wavefront(
                sync, scc_policy="chunk", compare=False, store=init
            ),
            repeats,
        )
        levels = run_wavefront(
            sync, scc_policy="chunk", compare=False, store=init
        ).stats.levels
        return secs, levels

    dispatch = max(
        _per_level_us(wf_sample, n, widths[0], repeats), _MIN_UNIT_US
    )
    meta["wavefront_per_group_us"] = dispatch

    units = {
        "xla_step": xla_step,
        "xla_lane": xla_lane,
        "spmd_collective": spmd_collective,
        "spmd_collective_lane": spmd_collective_lane,
        "dispatch": dispatch,
    }
    return units, meta
