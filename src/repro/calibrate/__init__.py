"""``repro.calibrate`` — measured per-host cost profiles for the strategy
auction.

The backend cost hooks (:func:`repro.compile.xla_level_cost`,
:func:`repro.compile.spmd.spmd_level_cost`) and the interpreters' default
depth × statement-groups model price strategy offers with hand-set
constants tuned on one developer box.  This package replaces those
constants with *measured* ones: at first use (:func:`warm`) it runs a
small suite of synthetic microbenchmarks through the real lowering
machinery (:mod:`repro.calibrate.microbench`) and persists the resulting
:class:`CostProfile` as a schema-versioned JSON file keyed by a host
fingerprint (platform / device count / jax version), so serving restarts
reuse it with zero re-measurement.

Design contract:

* **Nothing measures implicitly.**  The cost hooks read the active profile
  through :func:`units`, which never triggers a microbenchmark — with no
  profile warmed, they resolve the hand-set module constants *late*
  (``repro.compile.XLA_STEP_LANE_UNITS`` and friends), so monkeypatched
  values take effect everywhere and test runs stay deterministic.
* **Calibration never enters structural cache keys.**  Like the
  ``level_cost`` hook it feeds (see :func:`repro.core.policy.resolve_policy`),
  the profile re-prices offers but is invisible to
  ``structural_key`` — two processes with different profiles share
  artifacts; only the auction outcome may differ.
* **Corrupt / stale files fall back to defaults.**  A profile that fails
  schema, fingerprint, or unit validation is ignored
  (``calibrate.fallbacks`` counter) and the hand-set constants apply.
* ``REPRO_CALIBRATE=off`` (or ``0`` / ``false``) pins the hand-set
  defaults regardless of any warmed or persisted profile;
  ``REPRO_CALIBRATE_DIR`` overrides the cache directory.

Metrics (unified ``repro.obs.metrics`` registry): ``calibrate.measurements``
(one per timed microbenchmark sample — flat across a restart that reuses a
persisted profile), ``calibrate.loads``, ``calibrate.fallbacks`` counters
and the ``calibrate.generation`` gauge.  :func:`reset` (installed in
``obs.reset_all()``) restores the in-memory default state without touching
persisted files.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform as _platform
import sys
import tempfile
import threading
from pathlib import Path
from typing import Dict, Optional

from repro.obs import metrics as _metrics

__all__ = [
    "SCHEMA_VERSION",
    "UNIT_NAMES",
    "CostProfile",
    "active_profile",
    "cache_dir",
    "default_profile",
    "dispatch_units",
    "enabled",
    "host_fingerprint",
    "host_info",
    "load_profile",
    "measure",
    "profile_generation",
    "profile_path",
    "reset",
    "save_profile",
    "set_profile",
    "summary_pointer",
    "unit",
    "units",
    "warm",
]

SCHEMA_VERSION = 1

# The five calibrated unit costs.  All are relative weights inside one
# backend's auction, so hand-set defaults (abstract units) and measured
# values (microseconds) are both legitimate — they are never mixed within
# one profile.
#   xla_step             flat per-level cost of the jitted band step
#   xla_lane             per padded lane on top of it
#   spmd_collective      flat per-level collective cost on the mesh
#   spmd_collective_lane per gathered lane of that collective
#   dispatch             per batched group dispatch of the interpreters
UNIT_NAMES = (
    "xla_step",
    "xla_lane",
    "spmd_collective",
    "spmd_collective_lane",
    "dispatch",
)


@dataclasses.dataclass(frozen=True)
class CostProfile:
    """One host's measured (or default) cost units.

    ``source`` is ``"default"`` (hand-set constants, generation 0),
    ``"measured"`` (fresh microbenchmarks this process) or ``"persisted"``
    (reloaded from the cache dir with zero re-measurement).
    """

    units: Dict[str, float]
    fingerprint: str
    generation: int = 0
    source: str = "default"
    schema: int = SCHEMA_VERSION
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "schema": self.schema,
            "fingerprint": self.fingerprint,
            "generation": self.generation,
            "units": {k: float(self.units[k]) for k in UNIT_NAMES},
            "meta": dict(self.meta),
        }


_LOCK = threading.Lock()
_ACTIVE: Optional[CostProfile] = None


# ---------------------------------------------------------------------- #
# Environment / host identity
# ---------------------------------------------------------------------- #

def enabled() -> bool:
    """False when ``REPRO_CALIBRATE`` is ``off``/``0``/``false`` — the
    hand-set defaults then apply regardless of warmed/persisted state."""

    return os.environ.get("REPRO_CALIBRATE", "").strip().lower() not in (
        "off",
        "0",
        "false",
    )


def host_info() -> Dict[str, str]:
    """The identity a profile is keyed by: platform, accelerator backend,
    device count and jax version (``nojax`` placeholders when jax is
    absent, so the fingerprint is still stable)."""

    try:
        import jax

        backend = jax.default_backend()
        devices = str(jax.local_device_count())
        version = str(jax.__version__)
    except Exception:  # pragma: no cover - jax is baked into the image
        backend, devices, version = "nojax", "0", "0"
    return {
        "machine": _platform.machine(),
        "system": _platform.system(),
        "backend": backend,
        "devices": devices,
        "jax": version,
    }


def host_fingerprint(info: Optional[Dict[str, str]] = None) -> str:
    info = info if info is not None else host_info()
    raw = "|".join(
        f"{k}={info[k]}"
        for k in ("machine", "system", "backend", "devices", "jax")
    )
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def cache_dir() -> Path:
    """Profile directory: ``REPRO_CALIBRATE_DIR`` when set, else the
    XDG-style user cache (``~/.cache/repro-calibrate``)."""

    override = os.environ.get("REPRO_CALIBRATE_DIR")
    if override:
        return Path(override)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base / "repro-calibrate"


def profile_path(fingerprint: Optional[str] = None) -> Path:
    fp = fingerprint if fingerprint is not None else host_fingerprint()
    return cache_dir() / f"cost_profile-{fp}.json"


# ---------------------------------------------------------------------- #
# Default (hand-set) units, resolved LATE
# ---------------------------------------------------------------------- #

def _hand_set_units() -> Dict[str, float]:
    """Today's module constants, read at call time — monkeypatching
    ``repro.compile.XLA_STEP_LANE_UNITS`` (or the spmd/policy constants)
    changes every consumer, which is the satellite fix for the old
    import-by-value in ``spmd.py``."""

    import repro.compile as _compile

    spmd = sys.modules.get("repro.compile.spmd")
    policy = sys.modules.get("repro.core.policy")
    return {
        "xla_step": float(_compile.XLA_STEP_LANE_UNITS),
        "xla_lane": float(getattr(_compile, "XLA_LANE_UNITS", 1.0)),
        "spmd_collective": float(
            getattr(spmd, "SPMD_COLLECTIVE_UNITS", 4.0)
        ),
        "spmd_collective_lane": float(
            getattr(spmd, "SPMD_COLLECTIVE_LANE_UNITS", 0.125)
        ),
        "dispatch": float(getattr(policy, "DISPATCH_UNITS", 1.0)),
    }


def default_profile() -> CostProfile:
    return CostProfile(
        units=_hand_set_units(),
        fingerprint=host_fingerprint(),
        generation=0,
        source="default",
    )


# ---------------------------------------------------------------------- #
# Active-profile state
# ---------------------------------------------------------------------- #

def active_profile() -> CostProfile:
    """The installed profile, or a fresh default snapshot when none (or
    when calibration is disabled via the env switch)."""

    with _LOCK:
        prof = _ACTIVE
    if prof is None or not enabled():
        return default_profile()
    return prof


def set_profile(profile: Optional[CostProfile]) -> None:
    global _ACTIVE
    with _LOCK:
        _ACTIVE = profile
    _metrics.gauge("calibrate.generation").set(
        0 if profile is None else profile.generation
    )


def reset() -> None:
    """Back to hand-set defaults in-memory (``obs.reset_all()`` hook).
    Persisted profile files are left on disk — restarts reuse them."""

    global _ACTIVE
    with _LOCK:
        _ACTIVE = None


def units() -> Dict[str, float]:
    """The unit costs every cost hook prices with *right now*."""

    prof = active_profile()
    if prof.source == "default":
        # a default snapshot may be stale vs a just-monkeypatched constant;
        # re-resolve late
        return _hand_set_units()
    return dict(prof.units)


def unit(name: str) -> float:
    if name not in UNIT_NAMES:
        raise KeyError(
            f"unknown calibration unit {name!r}; expected one of {UNIT_NAMES}"
        )
    return units()[name]


def dispatch_units() -> float:
    """Per-group dispatch weight of the interpreters' default cost model."""

    return units()["dispatch"]


def profile_generation() -> int:
    """Generation of the profile pricing the auction (0 = hand-set)."""

    prof = active_profile()
    return prof.generation if prof.source != "default" else 0


def summary_pointer() -> dict:
    """Deterministic pointer for ``report.summary()["obs"]`` — state flags
    plus where the full profile lives, never measured values."""

    prof = active_profile()
    return {
        "enabled": enabled(),
        "source": prof.source,
        "generation": prof.generation,
        "profile_export": (
            "repro.calibrate.active_profile() / profile_path()"
        ),
    }


# ---------------------------------------------------------------------- #
# Persistence
# ---------------------------------------------------------------------- #

def _valid_units(raw: object) -> Optional[Dict[str, float]]:
    if not isinstance(raw, dict):
        return None
    out: Dict[str, float] = {}
    for name in UNIT_NAMES:
        v = raw.get(name)
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return None
        v = float(v)
        if not (v > 0.0) or v != v or v == float("inf"):
            return None
        out[name] = v
    return out


def load_profile(path: Optional[Path] = None) -> Optional[CostProfile]:
    """Read + validate a persisted profile; ``None`` (and a
    ``calibrate.fallbacks`` tick) on a missing, corrupt, schema-mismatched
    or foreign-host file — the caller falls back to defaults or
    re-measures."""

    path = Path(path) if path is not None else profile_path()
    try:
        raw = json.loads(path.read_text())
    except FileNotFoundError:
        return None
    except (OSError, ValueError):
        _metrics.counter("calibrate.fallbacks").inc()
        return None
    units_d = _valid_units(raw.get("units")) if isinstance(raw, dict) else None
    if (
        units_d is None
        or raw.get("schema") != SCHEMA_VERSION
        or raw.get("fingerprint") != host_fingerprint()
        or isinstance(raw.get("generation"), bool)
        or not isinstance(raw.get("generation"), int)
        or raw["generation"] < 0
    ):
        _metrics.counter("calibrate.fallbacks").inc()
        return None
    meta = raw.get("meta")
    return CostProfile(
        units=units_d,
        fingerprint=raw["fingerprint"],
        generation=raw["generation"],
        source="persisted",
        meta=dict(meta) if isinstance(meta, dict) else {},
    )


def save_profile(
    profile: CostProfile, path: Optional[Path] = None
) -> Path:
    """Atomic write (tempfile in the target dir + ``os.replace``), so a
    concurrent reader never sees a partial profile."""

    path = Path(path) if path is not None else profile_path(
        profile.fingerprint
    )
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=str(path.parent), prefix=path.name, suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(profile.as_dict(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


# ---------------------------------------------------------------------- #
# Measurement entry points
# ---------------------------------------------------------------------- #

def measure(persist: bool = True, **bench_kwargs) -> CostProfile:
    """Run the microbenchmark suite and install (and by default persist)
    the resulting profile.  A no-op returning the defaults when the env
    switch disables calibration.  ``bench_kwargs`` forward to
    :func:`repro.calibrate.microbench.measure_units` (tests shrink the
    problem sizes through them)."""

    if not enabled():
        return default_profile()
    from repro.calibrate import microbench as _mb

    units_d, meta = _mb.measure_units(**bench_kwargs)
    prev = load_profile()
    info = host_info()
    meta = dict(meta)
    meta.update(info)
    prof = CostProfile(
        units=units_d,
        fingerprint=host_fingerprint(info),
        generation=(prev.generation if prev is not None else 0) + 1,
        source="measured",
        meta=meta,
    )
    if persist:
        save_profile(prof)
    set_profile(prof)
    return prof


def warm(**bench_kwargs) -> CostProfile:
    """The documented "first use": reuse an already-installed or persisted
    profile (zero re-measurement — ``calibrate.measurements`` stays flat),
    else measure and persist one.  ``PlanService`` calls this at startup
    when ``ServiceOptions(warm_profile=True)``."""

    if not enabled():
        return default_profile()
    with _LOCK:
        prof = _ACTIVE
    if prof is not None and prof.source != "default":
        return prof
    prof = load_profile()
    if prof is not None:
        set_profile(prof)
        _metrics.counter("calibrate.loads").inc()
        return prof
    return measure(**bench_kwargs)
