"""Pure-jnp oracle for the pipelined matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(a.dtype)
