"""Pallas TPU blocked matmul with producer/consumer-pipelined K-loop.

C[M,N] = A[M,K] @ B[K,N], grid (M/BM, N/BN, K/BK) with K innermost; the
accumulator lives in VMEM scratch and the automatic Pallas pipeline
double-buffers the A/B tiles.  The buffer depth and per-step wait schedule
are *derived* by the paper's transitive-reduction algorithm in
``schedule.py`` (LOAD on the DMA processor, ISSUE+COMPUTE on the compute
processor): with prefetch distance 1 and depth ≥ 2 the buffer-reuse anti
dependence is transitively covered and only the arrival (flow) wait
survives — one semaphore wait per grid step, which is exactly what
``pl.pallas_call`` emits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("blk_m", "blk_n", "blk_k", "interpret")
)
def pipelined_matmul(
    a: jax.Array,  # (M, K)
    b: jax.Array,  # (K, N)
    *,
    blk_m: int = 128,
    blk_n: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    blk_m, blk_n, blk_k = min(blk_m, M), min(blk_n, N), min(blk_k, K)
    gm, gn, gk = -(-M // blk_m), -(-N // blk_n), -(-K // blk_k)
    if gm * blk_m != M or gk * blk_k != K:
        a = jnp.pad(a, ((0, gm * blk_m - M), (0, gk * blk_k - K)))
    if gk * blk_k != K or gn * blk_n != N:
        b = jnp.pad(b, ((0, gk * blk_k - K), (0, gn * blk_n - N)))

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((blk_m, blk_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((blk_k, blk_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * blk_m, gn * blk_n), a.dtype),
        scratch_shapes=[pltpu.VMEM((blk_m, blk_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N]
