"""Producer/consumer synchronization plan for the pipelined matmul kernel —
the paper's algorithms applied to a kernel's K-loop.

The K-loop of a double-buffered blocked matmul has three statement roles on
TWO processors (the paper's §3.2 DSWP setting with an explicit processor
map — ``model="procmap"``):

  compute unit ("mxu"):   ISSUE(i)  — enqueue the DMA for tile i+1
                          COMPUTE(i) — acc += A·buf[i mod D]
  DMA engine   ("dma"):   LOAD(i)   — the asynchronous tile-i write

dependences:
  flow  ISSUE → LOAD,   Δ=1  (a DMA runs only after its descriptor issue;
                              prefetch distance 1 — ISSUE(i) starts tile i+1)
  flow  LOAD → COMPUTE, Δ=0  (arrival: the DMA-completion semaphore)
  anti  COMPUTE → LOAD, Δ=D  (slot reuse: tile i+D overwrites slot i mod D)

Running the paper's ISD transitive reduction (procmap model) proves the
classic double-buffering theorem mechanically:

  * D = 1: the anti dependence is NOT covered — single buffering needs an
    explicit consumed-credit semaphore (2 waits per step);
  * D ≥ 2: COMPUTE(i) →(mxu order) ISSUE(i+1) →(flow) LOAD(i+2) →(dma
    order) LOAD(i+D) covers the anti dependence — only the arrival wait
    survives (1 wait per step), which is exactly the schedule
    ``pl.pallas_call``'s automatic pipelining emits.

``min_buffers()`` returns the smallest depth whose anti dependence is
eliminable = 2.  Asserted in tests and reported by the benchmarks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List

from repro.core.dependence import ANTI, FLOW, Dependence
from repro.core.ir import ArrayRef, LoopProgram, Statement
from repro.core.parallelizer import PlanOptions, SyncPlan, plan
from repro.core.wavefront import WavefrontSchedule

PROCESSORS = {"ISSUE": "mxu", "COMPUTE": "mxu", "LOAD": "dma"}


def make_kloop_program(steps: int) -> LoopProgram:
    """The ISSUE/LOAD/COMPUTE loop program.  Lexical order puts ISSUE first
    (prefetch happens before the compute of the current step)."""

    return LoopProgram(
        statements=(
            Statement("ISSUE", ArrayRef("desc", 0), ()),
            Statement("LOAD", ArrayRef("buf", 0), (ArrayRef("desc", -1),)),
            Statement(
                "COMPUTE",
                ArrayRef("acc", 0),
                (ArrayRef("buf", 0), ArrayRef("acc", -1)),
            ),
        ),
        bounds=((0, steps),),
    )


def kloop_dependences(depth: int) -> List[Dependence]:
    """Explicit dependence list (the ``i mod depth`` slot aliasing is not
    affine, so the anti distance is written directly)."""

    return [
        Dependence(FLOW, "ISSUE", "LOAD", "desc", (1,)),
        Dependence(FLOW, "LOAD", "COMPUTE", "buf", (0,)),
        Dependence(ANTI, "COMPUTE", "LOAD", "buf", (depth,)),
        Dependence(FLOW, "COMPUTE", "COMPUTE", "acc", (1,)),
    ]


@dataclasses.dataclass(frozen=True)
class KernelPipelinePlan:
    depth: int
    retained: tuple
    eliminated: tuple
    waits_per_step: int
    credit_wait_needed: bool
    # dependence-level layering of the K-loop under the same retained deps —
    # the steady-state overlap the Pallas pipeline realizes (LOAD of a later
    # tile sharing a level with an earlier COMPUTE)
    wavefront: WavefrontSchedule

    def summary(self) -> dict:
        return {
            "buffer_depth": self.depth,
            "retained": [d.pretty() for d in self.retained],
            "eliminated": [d.pretty() for d in self.eliminated],
            "waits_per_step": self.waits_per_step,
            "credit_wait_needed": self.credit_wait_needed,
            "wavefront_depth": self.wavefront.depth,
            "overlapped_levels": overlapped_levels(self.wavefront),
        }


def _kloop_options(depth: int) -> PlanOptions:
    """The staged pipeline's typed options for the K-loop: explicit
    dependences (the ``i mod depth`` aliasing is not affine) under the
    two-processor ``procmap`` execution model."""

    return PlanOptions(
        method="isd",
        deps=tuple(kloop_dependences(depth)),
        model="procmap",
        processors=PROCESSORS,
    )


@functools.lru_cache(maxsize=32)
def _kloop_plan(depth: int, steps: int) -> SyncPlan:
    """``plan()`` of the K-loop, memoized per (depth, steps).

    The parallelizer memoizes the elimination bounds-free, but fission,
    naive insertion and retained validation would still re-run per call —
    this cache keeps the warm ``compile_kloop`` path analysis-free, like
    the pre-staged ``_KLOOP_RETAINED`` memo did.
    """

    return plan(make_kloop_program(steps), _kloop_options(depth))


def plan_pipeline(depth: int = 2, steps: int = 16) -> KernelPipelinePlan:
    p = _kloop_plan(depth, steps)
    res = p.elimination
    cross = [
        d
        for d in res.retained
        if PROCESSORS[d.source] != PROCESSORS[d.sink]
    ]
    credit = any(d.kind == ANTI for d in res.retained)
    wf = p.compile("wavefront").report().wavefront
    return KernelPipelinePlan(
        depth=depth,
        retained=tuple(res.retained),
        eliminated=tuple(res.eliminated),
        waits_per_step=len(cross),
        credit_wait_needed=credit,
        wavefront=wf,
    )


def kloop_wavefronts(depth: int = 2, steps: int = 16) -> WavefrontSchedule:
    """The K-loop's dependence-level layering (same retained deps as the
    plan) — consumed by tests/benchmarks to check DMA/compute overlap."""

    return plan_pipeline(depth, steps).wavefront


def compile_kloop(depth: int = 2, steps: int = 16):
    """Resolve the K-loop plan through the structural compile cache.

    Staged end to end: ``plan()`` (bounds-free memoized elimination) →
    ``compile("xla")`` (structural cache).  The cache key covers the
    statement graph, the retained dependences and the procmap model —
    *not* ``steps`` — so re-planning the same pipeline at a different K
    extent is a structural hit: only the per-bounds level tables are
    (re)built.  Returns ``(CompiledProgram, hit)``.
    """

    exe = _kloop_plan(depth, steps).compile("xla")
    return exe.artifacts["compiled"], exe.artifacts["compile_hit"]


def overlapped_levels(wf: WavefrontSchedule) -> int:
    """Levels in which a tile LOAD shares a wavefront with a COMPUTE — the
    mechanical signature of double buffering: with D ≥ 2 the layering puts
    LOAD(i+1) beside COMPUTE(i), with D = 1 the credit wait serializes them."""

    count = 0
    for groups in wf.levels:
        names = {g.statement for g in groups}
        if "LOAD" in names and "COMPUTE" in names:
            count += 1
    return count


def min_buffers(steps: int = 16, max_depth: int = 4) -> int:
    """Smallest depth whose buffer-reuse anti dependence is transitively
    covered (→ only the arrival wait remains)."""

    for depth in range(1, max_depth + 1):
        if not plan_pipeline(depth, steps).credit_wait_needed:
            return depth
    return max_depth
