"""jit'd public wrapper for the pipelined matmul (interpret on CPU)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.pipelined_matmul.kernel import pipelined_matmul


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("blk_m", "blk_n", "blk_k", "interpret")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    blk_m: int = 128,
    blk_n: int = 128,
    blk_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interp = (not _on_tpu()) if interpret is None else interpret
    return pipelined_matmul(
        a, b, blk_m=blk_m, blk_n=blk_n, blk_k=blk_k, interpret=interp
    )
