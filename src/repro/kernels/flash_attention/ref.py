"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,  # (BH, Sk, hd)
    v: jax.Array,  # (BH, Sk, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    hd = q.shape[-1]
    s = jnp.einsum("bqk,bsk->bqs", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * hd**-0.5
    Sq, Sk = q.shape[1], k.shape[1]
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqs,bsk->bqk", p, v.astype(jnp.float32)).astype(q.dtype)
