"""Pallas TPU flash-attention kernel (forward).

Blocked streaming-softmax attention with explicit BlockSpec VMEM tiling:
grid = (batch·heads, Sq/BLK_Q, Sk/BLK_K), K as the innermost ("arbitrary")
dimension so the automatic Pallas pipeline double-buffers the K/V tiles —
the hardware producer (DMA) / consumer (MXU) pair whose synchronization
schedule is exactly the paper's send/wait structure (see
``repro.kernels.pipelined_matmul.schedule`` for the derivation; the minimal
retained dependence set implies double buffering, which is what
``pl.pallas_call``'s pipelining emits).

Running max/sum/accumulator live in VMEM scratch across K-steps; the output
tile is written once at the last K-step.  Causal and sliding-window masking
are applied from block-index arithmetic; fully-masked K-blocks are skipped
via ``pl.when`` (the compute-side elimination of provably-unneeded work).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, BLK_Q, hd)
    k_ref,  # (1, BLK_K, hd)
    v_ref,  # (1, BLK_K, hd)
    o_ref,  # (1, BLK_Q, hd)
    m_scratch,  # (BLK_Q, 1) f32
    l_scratch,  # (BLK_Q, 1) f32
    acc_scratch,  # (BLK_Q, hd) f32
    *,
    blk_q: int,
    blk_k: int,
    sq: int,
    sk: int,
    causal: bool,
    window: Optional[int],
    scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)

    # block-level skip: K-block entirely after the causal frontier or
    # entirely before the sliding window
    run = jnp.asarray(True)
    if causal:
        run &= ki * blk_k <= qi * blk_q + blk_q - 1
    if window is not None:
        run &= (ki + 1) * blk_k - 1 > qi * blk_q - window

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BLK_Q, BLK_K)

        mask = (q_pos < sq) & (k_pos < sk)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]  # (BLK_Q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scratch[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scratch[...] = acc_scratch[...] * corr + pv
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[0] = (acc_scratch[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,  # (BH, Sk, hd)
    v: jax.Array,  # (BH, Sk, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    nq = -(-Sq // blk_q)
    nk = -(-Sk // blk_k)

    # pad to block multiples (masked out inside the kernel)
    if nq * blk_q != Sq:
        q = jnp.pad(q, ((0, 0), (0, nq * blk_q - Sq), (0, 0)))
    if nk * blk_k != Sk:
        k = jnp.pad(k, ((0, 0), (0, nk * blk_k - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * blk_k - Sk), (0, 0)))

    kernel = functools.partial(
        _flash_kernel,
        blk_q=blk_q,
        blk_k=blk_k,
        sq=Sq,
        sk=Sk,
        causal=causal,
        window=window,
        scale=hd**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * blk_q, hd), q.dtype),
        scratch_shapes=[
            # running max / sum / accumulator live in VMEM across K-steps
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq, :]
