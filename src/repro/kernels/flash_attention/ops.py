"""jit'd public wrapper: GQA-aware flash attention over (B, S, H, hd).

Folds (batch, heads) into the kernel's leading grid dimension, expands GQA
KV heads, and dispatches to the Pallas kernel (interpret=True on CPU — the
container has no TPU; the kernel is written for TPU BlockSpec tiling and
validated against ``ref.py``)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,  # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], hd)
    interp = (not _on_tpu()) if interpret is None else interpret
    of = flash_attention_kernel(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        blk_q=blk_q,
        blk_k=blk_k,
        interpret=interp,
    )
    return of.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
