"""Checkpointing: atomic step directories, async writer, retention, resume.

Layout::

    <root>/step_000123/
        MANIFEST.json        # tree structure, shapes, dtypes, data state
        arrays.npz           # flattened leaves (np arrays)
    <root>/step_000123.tmp/  # write staging — renamed atomically on commit

Restore picks the newest COMMITTED step (a crash mid-write leaves only a
``.tmp`` directory, which is ignored and garbage-collected).  The async
writer runs on a daemon thread with a bounded queue of one in-flight
snapshot — the train loop never blocks on I/O unless two checkpoints are
requested back-to-back (standard large-run behaviour).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.data.pipeline import DataState

# numpy's npz format round-trips extended dtypes (bfloat16 → void16) badly;
# store them as a same-width integer view + the dtype name in the manifest
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8}


def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def _encode(a: np.ndarray) -> Tuple[np.ndarray, str]:
    name = a.dtype.name
    if name in _VIEW_DTYPES:
        return a.view(_VIEW_DTYPES[name]), name
    return a, name


def _decode(a: np.ndarray, name: str) -> np.ndarray:
    if name in _VIEW_DTYPES:
        return a.view(getattr(ml_dtypes, name))
    return a


@dataclasses.dataclass
class Snapshot:
    step: int
    tree: Any
    data_state: Optional[DataState] = None


class CheckpointManager:
    def __init__(
        self,
        root: str | pathlib.Path,
        *,
        keep: int = 3,
        async_writes: bool = True,
    ) -> None:
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async = async_writes
        self._queue: "queue.Queue[Optional[Snapshot]]" = queue.Queue(maxsize=1)
        self._errors: List[BaseException] = []
        self._worker: Optional[threading.Thread] = None
        if async_writes:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        self._gc_tmp()

    # ------------------------------------------------------------------ #
    def save(self, snap: Snapshot) -> None:
        if self._async:
            self._raise_pending()
            self._queue.put(snap)  # blocks only if one write is in flight
        else:
            self._write(snap)

    def wait(self) -> None:
        """Block until all queued writes are committed (tests / shutdown)."""

        if self._async:
            self._queue.join()
        self._raise_pending()

    def restore(self, target: Any = None) -> Optional[Snapshot]:
        """Newest committed snapshot, or None.

        ``target``: example pytree defining the structure to restore into —
        REQUIRED when the tree contains non-JSON containers (NamedTuples
        like AdamWState); plain nested dicts restore without it."""

        steps = self.committed_steps()
        if not steps:
            return None
        return self.restore_step(steps[-1], target)

    def restore_step(self, step: int, target: Any = None) -> Snapshot:
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        dtypes = manifest.get("dtypes")
        with np.load(d / "arrays.npz") as z:
            leaves = [
                _decode(z[f"leaf_{i}"], dtypes[i] if dtypes else z[f"leaf_{i}"].dtype.name)
                for i in range(manifest["num_leaves"])
            ]
        if target is not None:
            treedef = jax.tree.structure(target)
        else:
            treedef = jax.tree.structure(
                json.loads(manifest["treedef_example"]),
                is_leaf=lambda x: x is None,
            )
        tree = jax.tree.unflatten(treedef, leaves)
        ds = manifest.get("data_state")
        return Snapshot(
            step=manifest["step"],
            tree=tree,
            data_state=DataState(**ds) if ds else None,
        )

    def committed_steps(self) -> List[int]:
        out = []
        for d in self.root.iterdir():
            if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp"):
                if (d / "MANIFEST.json").exists():
                    out.append(int(d.name.split("_")[1]))
        return sorted(out)

    # ------------------------------------------------------------------ #
    def _drain(self) -> None:
        while True:
            snap = self._queue.get()
            if snap is None:
                self._queue.task_done()
                return
            try:
                self._write(snap)
            except BaseException as e:  # noqa: BLE001
                self._errors.append(e)
            finally:
                self._queue.task_done()

    def _write(self, snap: Snapshot) -> None:
        final = self.root / f"step_{snap.step:09d}"
        tmp = self.root / f"step_{snap.step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = _flatten(snap.tree)
        encoded = [_encode(a) for a in leaves]
        np.savez(
            tmp / "arrays.npz",
            **{f"leaf_{i}": a for i, (a, _) in enumerate(encoded)},
        )
        # serialize tree structure via an example pytree of Nones
        example = jax.tree.unflatten(treedef, [None] * len(leaves))
        manifest = {
            "step": snap.step,
            "num_leaves": len(leaves),
            "dtypes": [name for _, name in encoded],
            "treedef_example": json.dumps(example),
            "data_state": dataclasses.asdict(snap.data_state)
            if snap.data_state
            else None,
        }
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._retain()

    def _retain(self) -> None:
        steps = self.committed_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    def _gc_tmp(self) -> None:
        for d in self.root.glob("step_*.tmp"):
            shutil.rmtree(d, ignore_errors=True)

    def _raise_pending(self) -> None:
        if self._errors:
            raise self._errors.pop(0)

    def close(self) -> None:
        if self._async and self._worker is not None:
            self._queue.put(None)
            self._worker.join(timeout=10)
