"""Checkpointing: atomic step dirs, async writer, retention, resume."""
