"""Deterministic synthetic token pipeline: sharded, resumable, prefetching.

Production shape without production data: a seeded token stream whose
content is a pure function of (seed, step, position) — so a restart from a
checkpointed ``DataState`` reproduces the exact batch sequence (tested), and
every data-parallel host can generate ONLY its shard (no central dispenser,
scales to any host count).

``host_batch_slice`` mirrors how a multi-host deployment would carve the
global batch: host h of H owns rows [h·B/H, (h+1)·B/H).  On this single-
process container the "hosts" are simulated, but the slicing/resume logic is
the part that must be correct at 1000 nodes — and is what the tests cover.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataState:
    """Everything needed to resume the stream exactly."""

    seed: int
    step: int

    def advance(self, n: int = 1) -> "DataState":
        return DataState(seed=self.seed, step=self.step + n)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    def __post_init__(self) -> None:
        assert self.global_batch % self.num_hosts == 0
        assert 0 <= self.host_id < self.num_hosts

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts


def _batch_tokens(
    cfg: DataConfig, model_cfg: ModelConfig, state: DataState
) -> np.ndarray:
    """Token block for THIS host at ``state.step`` — pure function of
    (seed, step, global row, position)."""

    rows = np.arange(
        cfg.host_id * cfg.host_batch, (cfg.host_id + 1) * cfg.host_batch
    )
    # counter-mode "philox-lite": cheap, deterministic, order-free
    pos = np.arange(cfg.seq_len + 1, dtype=np.uint64)
    r = rows.astype(np.uint64)[:, None]
    mask = (1 << 64) - 1  # fold the step/seed terms in Python ints — numpy
    # scalar uint64 multiplies warn on the (intended) wraparound
    x = (
        r * np.uint64(0x9E3779B97F4A7C15)
        + pos[None, :] * np.uint64(0xBF58476D1CE4E5B9)
        + np.uint64((state.step * 0x94D049BB133111EB) & mask)
        + np.uint64((state.seed * 0xD6E8FEB86659FD93) & mask)
    )
    x ^= x >> np.uint64(31)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    # triangular marginal over the vocab (mean of two independent draws):
    # entropy sits ~0.3 nats below log(vocab), so a model CAN learn the
    # stream's statistics — uniform tokens put the loss at its floor on
    # step 0 and make any "loss decreases" check a coin flip
    v = np.uint64(model_cfg.vocab_size)
    lo = x % v
    hi = (x >> np.uint64(32)) % v
    return ((lo + hi) // np.uint64(2)).astype(np.int32)


def make_batch(
    cfg: DataConfig, model_cfg: ModelConfig, state: DataState
) -> Dict[str, np.ndarray]:
    """One host-local batch: tokens + next-token labels (+ frontend stubs)."""

    block = _batch_tokens(cfg, model_cfg, state)
    batch = {
        "tokens": block[:, :-1],
        "labels": block[:, 1:],
    }
    if model_cfg.family == "encdec":
        rng = np.random.default_rng((cfg.seed, state.step, cfg.host_id, 7))
        batch["frame_embeds"] = rng.standard_normal(
            (cfg.host_batch, model_cfg.encoder.num_frames, model_cfg.d_model),
            dtype=np.float32,
        )
    if model_cfg.frontend == "vision" and model_cfg.num_patches:
        rng = np.random.default_rng((cfg.seed, state.step, cfg.host_id, 13))
        batch["patch_embeds"] = 0.1 * rng.standard_normal(
            (cfg.host_batch, model_cfg.num_patches, model_cfg.d_model),
            dtype=np.float32,
        )
    return batch


class DataIterator:
    """Stateful iterator with single-slot prefetch and exact resume."""

    def __init__(
        self,
        cfg: DataConfig,
        model_cfg: ModelConfig,
        state: Optional[DataState] = None,
    ) -> None:
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.state = state or DataState(seed=cfg.seed, step=0)
        self._prefetched: Optional[Dict[str, np.ndarray]] = None

    def peek_state(self) -> DataState:
        return self.state

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._prefetched is not None:
            batch, self._prefetched = self._prefetched, None
        else:
            batch = make_batch(self.cfg, self.model_cfg, self.state)
        self.state = self.state.advance()
        # prefetch the next host batch eagerly (numpy — cheap, overlaps the
        # device step in a real deployment via a background thread)
        self._prefetched = make_batch(self.cfg, self.model_cfg, self.state)
        return batch
