"""Data substrate: deterministic synthetic sharded token pipeline."""
