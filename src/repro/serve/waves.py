"""The serving demo's wave workloads, on the public service surface.

Extracted from ``repro.launch.serve`` (which remains a thin demo client):
four program shapes a decode wave re-plans every iteration — the acyclic
decode chain, the cyclic cross-slot rescoring scan, and the two non-affine
workloads (inspector-planned routing histogram, speculative sparse rescore).
Where the old module memoized each ``SyncPlan`` in an unbounded
``functools.lru_cache``, these helpers resolve through the default
:class:`~repro.serve.service.PlanService` — bounded per-tenant LRUs whose
traffic is observable (``plan_cache.*`` in ``obs.metrics``) instead of
invisible function attributes.  Each workload is its own tenant, so one
chatty structure cannot evict another tenant's plans.
"""

from __future__ import annotations

import concurrent.futures
import time
from typing import List, Optional

from repro.obs import metrics as _metrics
from repro.core import (
    ArrayRef,
    LoopProgram,
    PlanOptions,
    Statement,
    histogram,
    sparse_matvec,
)
from repro.serve.service import default_service

__all__ = [
    "decode_program",
    "scan_program",
    "plan_wave_sync",
    "plan_scan_sync",
    "plan_route_sync",
    "plan_rescore_sync",
    "run_nonaffine_wave",
    "plan_wave",
]


def decode_program(max_new: int) -> LoopProgram:
    """The per-slot decode chain — the paper's loop in miniature: DECODE
    extends the KV cache from the previous step's cache (flow, Δ=1), SAMPLE
    reads the fresh cache (flow, Δ=0).  The structure is independent of
    which requests occupy the slots, so the plan (and below it the compiled
    artifact — bounds are not part of the structural key) is shared by
    every wave at this ``max_new``."""

    return LoopProgram(
        statements=(
            Statement("DECODE", ArrayRef("kv", 0), (ArrayRef("kv", -1),)),
            Statement("SAMPLE", ArrayRef("tok", 0), (ArrayRef("kv", 0),)),
        ),
        bounds=((1, max(2, max_new)),),
    )


def scan_program(slots: int, horizon: int) -> LoopProgram:
    """The cross-slot rescoring scan — a *cyclic* wave shape.

    RESCORE folds each slot's running score with the previous step's score
    of the same slot (reads ``score[s, t-1]``: flow, Δ=(0,1)) and borrows
    the neighboring slot's one-step-newer score (reads ``score[s-1, t+1]``:
    flow, Δ=(1,-1)) — a mixed-sign recurrence SCC, the request shape the
    acyclic decode plan never produces.  EMIT reads the settled score
    (DOALL, pipelined against the scan).  The (0,1) carried dependence pins
    DOACROSS chunks to 1, and the per-backend cost model decides between
    the unimodular skew and unit chunks at compile time — either way a
    *hybrid* artifact served from the structural cache wave after wave."""

    return LoopProgram(
        statements=(
            Statement(
                "RESCORE",
                ArrayRef("score", (0, 0)),
                (ArrayRef("score", (0, -1)), ArrayRef("score", (-1, 1))),
            ),
            Statement(
                "EMIT", ArrayRef("beam", (0, 0)), (ArrayRef("score", (0, 0)),)
            ),
        ),
        bounds=((0, max(2, slots)), (0, max(2, horizon))),
    )


def _timed_compile(plan_obj, backend: str = "xla"):
    t0 = time.perf_counter()
    exe = plan_obj.compile(backend)
    _metrics.histogram("serve.compile_ms").observe(
        (time.perf_counter() - t0) * 1e3
    )
    return exe


def plan_wave_sync(max_new: int):
    """One wave's decode-chain report: tenant plan LRU + structural cache."""

    p, _ = default_service().resolve(decode_program(max_new), tenant="decode")
    return _timed_compile(p).report()


def plan_scan_sync(slots: int, horizon: int):
    """One wave's rescoring-scan report (hybrid artifact, see
    :func:`scan_program`)."""

    p, _ = default_service().resolve(
        scan_program(slots, horizon), tenant="scan"
    )
    return _timed_compile(p).report()


def plan_route_sync(tokens: int):
    """One wave's routing-histogram Executable (non-affine,
    ``deps="inspect"``): each decoded token scatters into its expert's bin,
    ``h[bin[i]] += w[i]`` with ``bin`` only known at runtime."""

    p, _ = default_service().resolve(
        histogram(max(2, tokens)), PlanOptions(deps="inspect"), tenant="route"
    )
    return _timed_compile(p)


def plan_rescore_sync(tokens: int):
    """One wave's sparse-rescore Executable (non-affine,
    ``deps="speculate"``): ``y[row[k]] += v[k]*x[col[k]]`` runs
    doall-optimistic, validates against the inspector graph post-hoc, and
    rolls back conservatively on a conflicting wave."""

    p, _ = default_service().resolve(
        sparse_matvec(max(2, tokens)),
        PlanOptions(deps="speculate"),
        tenant="rescore",
    )
    return _timed_compile(p)


def run_nonaffine_wave(route_exe, rescore_exe, sampled: List[int], bins: int):
    """Execute the wave's non-affine workloads with this wave's runtime
    index contents; returns (route store, rescore store) after asserting
    both bit-equal the sequential oracle."""

    from repro.core import indexed_store, run_sequential

    route_prog = route_exe.plan.program
    (lo, hi), = route_prog.bounds
    n = hi - lo
    pattern = [sampled[k % len(sampled)] % bins for k in range(n)]
    store = indexed_store(route_prog, {"bin": pattern})
    init = {a: dict(c) for a, c in store.items()}
    routed = route_exe.run(store=init)
    assert routed == run_sequential(route_prog, init)

    rescore_prog = rescore_exe.plan.program
    (lo, hi), = rescore_prog.bounds
    n = hi - lo
    rows = [sampled[k % len(sampled)] % max(2, n // 2) for k in range(n)]
    store = indexed_store(
        rescore_prog, {"row": rows, "col": list(range(n))}
    )
    init = {a: dict(c) for a, c in store.items()}
    rescored = rescore_exe.run(store=init)
    assert rescored == run_sequential(rescore_prog, init)
    return routed, rescored


def plan_wave(
    max_new: int,
    slots: int,
    pool: Optional[concurrent.futures.ThreadPoolExecutor] = None,
):
    """Resolve one wave's four plans concurrently (decode chain, rescoring
    scan, routing histogram, sparse rescore).

    The planner threads race through ``SyncPlan.compile("xla")`` into the
    structural compile cache — the concurrency the cache's locking
    discipline is built for, now exercised by a cyclic workload on every
    serving wave.  Pass a long-lived ``pool`` from the serving loop: warm
    waves plan in sub-millisecond cache hits, which per-wave executor setup
    would dwarf.
    """

    if pool is None:
        with concurrent.futures.ThreadPoolExecutor(max_workers=2) as own:
            return plan_wave(max_new, slots, pool=own)
    f_decode = pool.submit(plan_wave_sync, max_new)
    f_scan = pool.submit(plan_scan_sync, slots, max_new)
    f_route = pool.submit(plan_route_sync, 2 * slots)
    f_rescore = pool.submit(plan_rescore_sync, 2 * slots)
    return (
        f_decode.result(),
        f_scan.result(),
        f_route.result(),
        f_rescore.result(),
    )
