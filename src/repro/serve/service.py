"""``PlanService`` — a continuous-batching plan service over the staged
pipeline.

The serving-layer form of the paper's amortization promise: analyze a loop
structure once, then serve any number of waves from caches.  A service
instance admits requests for many program *structures* concurrently and
resolves each through the full cache hierarchy —

  per-tenant plan LRU  →  structural compile cache  →  trace bucket
  →  per-bounds tables

— so a warm request touches no analysis, no scheduling, and (for bounds in
an already-traced bucket, see :mod:`repro.compile.lowering`) no jax tracing.

Concurrency discipline:

* a fixed worker pool (``ServiceOptions.workers``) runs submitted requests;
* *per-structure admission*: requests for the same program structure are
  serialized through a per-fingerprint lock, so a cold structure is planned
  and lowered exactly once no matter how many submitters race it — the
  structural cache's miss count stays equal to the number of distinct
  structures;
* *bounded admission*: more than ``max_queue_depth`` outstanding requests
  rejects at ``submit()`` instead of queueing without limit.

Observability (all in the unified ``repro.obs.metrics`` registry, so
``obs.reset_all()`` covers them): ``plan_cache.hits`` / ``plan_cache.misses``
/ ``plan_cache.evictions`` counters and the ``plan_cache.size`` gauge for
the per-tenant LRUs, the ``serve.queue_depth`` gauge, and per-tenant
``serve.latency_ms.<tenant>`` histograms beside the global
``serve.plan_ms`` / ``serve.compile_ms`` ones.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Dict, Mapping, Optional, Tuple

from repro.obs import metrics as _metrics
from repro.core.ir import LoopProgram
from repro.core.parallelizer import (
    Executable,
    PlanOptions,
    SyncPlan,
    plan as _plan,
)
from repro.serve.options import ServiceOptions

__all__ = [
    "PlanService",
    "ServiceResult",
    "default_service",
    "reset_default_service",
]


@dataclasses.dataclass(frozen=True)
class ServiceResult:
    """What one admitted request resolved to."""

    tenant: str
    plan: SyncPlan
    executable: Executable
    store: Optional[dict]        # output store when the request ran
    plan_cached: bool            # per-tenant plan-LRU hit?
    latency_ms: float


class _TenantCache:
    """One tenant's bounded plan LRU (counters are plain ints here; the
    registry-backed totals are maintained by the owning service)."""

    __slots__ = ("entries", "hits", "misses", "evictions")

    def __init__(self) -> None:
        self.entries: "collections.OrderedDict[Tuple, SyncPlan]" = (
            collections.OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self.evictions = 0


def _options_key(options: PlanOptions) -> object:
    """A hashable stand-in for the plan options (scc_policy instances may
    not be hashable; their repr is stable enough for a cache key)."""

    try:
        hash(options)
        return options
    except TypeError:
        return repr(options)


class PlanService:
    """Multi-tenant plan service: ``submit()`` / ``drain()`` / ``stats()`` /
    ``close()`` over per-tenant bounded plan LRUs and a worker pool."""

    def __init__(self, options: Optional[ServiceOptions] = None) -> None:
        self.options = options if options is not None else ServiceOptions()
        self._lock = threading.Lock()
        self._tenants: Dict[str, _TenantCache] = {}
        self._structure_locks: Dict[str, threading.Lock] = {}
        self._outstanding: set = set()
        self._submitted = 0
        self._completed = 0
        self._closed = False
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.options.workers,
            thread_name_prefix="plan-serve",
        )

    # ------------------------------------------------------------------ #
    # Cache plumbing
    # ------------------------------------------------------------------ #

    def _tenant(self, name: str) -> _TenantCache:
        cache = self._tenants.get(name)
        if cache is None:
            cache = self._tenants.setdefault(name, _TenantCache())
        return cache

    def _structure_lock(self, fingerprint: str) -> threading.Lock:
        with self._lock:
            lock = self._structure_locks.get(fingerprint)
            if lock is None:
                lock = self._structure_locks[fingerprint] = threading.Lock()
            return lock

    def _cache_size(self) -> int:
        return sum(len(t.entries) for t in self._tenants.values())

    def resolve(
        self,
        program: LoopProgram,
        options: Optional[PlanOptions] = None,
        *,
        tenant: Optional[str] = None,
    ) -> Tuple[SyncPlan, bool]:
        """The synchronous core: per-tenant plan LRU with per-structure
        admission.  Returns ``(plan, cached)``; records ``serve.plan_ms``
        (every call, hits included — the latency a serving wave observes)
        and the per-tenant ``plan_cache.*`` counters."""

        tenant = tenant if tenant is not None else self.options.default_tenant
        options = options if options is not None else PlanOptions()
        t0 = time.perf_counter()
        from repro.compile.structure import program_fingerprint

        fp = program_fingerprint(program)
        key = (fp, program.bounds, _options_key(options))
        with self._lock:
            cache = self._tenant(tenant)
            cached = cache.entries.get(key)
            if cached is not None:
                cache.entries.move_to_end(key)
                cache.hits += 1
        if cached is not None:
            _metrics.counter("plan_cache.hits").inc()
            _metrics.histogram("serve.plan_ms").observe(
                (time.perf_counter() - t0) * 1e3
            )
            return cached, True
        # per-structure admission: one planner per structure at a time, so
        # racing submitters of a cold structure queue here instead of
        # planning (and structurally compiling) the same thing twice
        with self._structure_lock(fp):
            with self._lock:
                cached = cache.entries.get(key)
                if cached is not None:
                    cache.entries.move_to_end(key)
                    cache.hits += 1
            if cached is not None:
                _metrics.counter("plan_cache.hits").inc()
                _metrics.histogram("serve.plan_ms").observe(
                    (time.perf_counter() - t0) * 1e3
                )
                return cached, True
            built = _plan(program, options)
            with self._lock:
                cache.misses += 1
                cache.entries[key] = built
                while len(cache.entries) > self.options.plan_cache_capacity:
                    cache.entries.popitem(last=False)
                    cache.evictions += 1
                    _metrics.counter("plan_cache.evictions").inc()
                _metrics.gauge("plan_cache.size").set(self._cache_size())
        _metrics.counter("plan_cache.misses").inc()
        _metrics.histogram("serve.plan_ms").observe(
            (time.perf_counter() - t0) * 1e3
        )
        return built, False

    # ------------------------------------------------------------------ #
    # The public request surface
    # ------------------------------------------------------------------ #

    def submit(
        self,
        program: LoopProgram,
        options: Optional[PlanOptions] = None,
        *,
        tenant: Optional[str] = None,
        store: Optional[Mapping[str, dict]] = None,
        run: bool = False,
    ) -> "concurrent.futures.Future[ServiceResult]":
        """Admit one request: plan (through the tenant's LRU), compile for
        the service backend, optionally execute.

        Returns a future of :class:`ServiceResult`.  ``store``/``run=True``
        execute the compiled artifact (``store`` is copied, not mutated).
        Raises ``RuntimeError`` when the service is closed or the admission
        bound (``max_queue_depth``) is reached.
        """

        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "PlanService is closed — create a new service to submit"
                )
            if len(self._outstanding) >= self.options.max_queue_depth:
                raise RuntimeError(
                    f"admission rejected: {len(self._outstanding)} requests "
                    f"outstanding >= max_queue_depth="
                    f"{self.options.max_queue_depth}"
                )
            self._submitted += 1
        future = self._pool.submit(
            self._handle, program, options, tenant, store, run
        )
        with self._lock:
            self._outstanding.add(future)
            _metrics.gauge("serve.queue_depth").set(len(self._outstanding))
        future.add_done_callback(self._settle)
        return future

    def _settle(self, future) -> None:
        with self._lock:
            self._outstanding.discard(future)
            self._completed += 1
            _metrics.gauge("serve.queue_depth").set(len(self._outstanding))

    def _handle(
        self,
        program: LoopProgram,
        options: Optional[PlanOptions],
        tenant: Optional[str],
        store: Optional[Mapping[str, dict]],
        run: bool,
    ) -> ServiceResult:
        tenant = tenant if tenant is not None else self.options.default_tenant
        t0 = time.perf_counter()
        plan_obj, cached = self.resolve(program, options, tenant=tenant)
        tc = time.perf_counter()
        # compile under the same per-structure admission lock as planning:
        # get_or_compile counts a lost race as a second structural miss, so
        # without this two workers handling the same cold structure would
        # both lower it and the miss count would exceed #distinct structures
        from repro.compile.structure import program_fingerprint

        with self._structure_lock(program_fingerprint(program)):
            executable = plan_obj.compile(self.options.backend)
        _metrics.histogram("serve.compile_ms").observe(
            (time.perf_counter() - tc) * 1e3
        )
        out = None
        if run or store is not None:
            init = {
                a: dict(c)
                for a, c in (store or program.initial_store()).items()
            }
            out = executable.run(store=init)
        latency = (time.perf_counter() - t0) * 1e3
        _metrics.histogram(f"serve.latency_ms.{tenant}").observe(latency)
        return ServiceResult(
            tenant=tenant,
            plan=plan_obj,
            executable=executable,
            store=out,
            plan_cached=cached,
            latency_ms=latency,
        )

    def drain(self, timeout: Optional[float] = None) -> dict:
        """Block until every outstanding request settles; returns
        :meth:`stats`.  Raises ``TimeoutError`` if ``timeout`` (seconds)
        elapses first."""

        with self._lock:
            pending = tuple(self._outstanding)
        done, not_done = concurrent.futures.wait(pending, timeout=timeout)
        if not_done:
            raise TimeoutError(
                f"drain timed out with {len(not_done)} requests outstanding"
            )
        return self.stats()

    def stats(self) -> dict:
        """A JSON-able snapshot: per-tenant cache traffic, queue state, and
        the trace/bucket counters behind the re-trace rate (this is the
        ``SERVE_sync`` artifact the bench job uploads)."""

        snap = _metrics.snapshot()
        with self._lock:
            tenants = {
                name: {
                    "size": len(t.entries),
                    "hits": t.hits,
                    "misses": t.misses,
                    "evictions": t.evictions,
                }
                for name, t in sorted(self._tenants.items())
            }
            out = {
                "backend": self.options.backend,
                "workers": self.options.workers,
                "tenants": tenants,
                "plan_cache": {
                    "size": self._cache_size(),
                    "capacity_per_tenant": self.options.plan_cache_capacity,
                    "hits": sum(t.hits for t in self._tenants.values()),
                    "misses": sum(t.misses for t in self._tenants.values()),
                    "evictions": sum(
                        t.evictions for t in self._tenants.values()
                    ),
                },
                "queue_depth": len(self._outstanding),
                "submitted": self._submitted,
                "completed": self._completed,
            }
        out["traces"] = snap.get("xla.traces", 0)
        out["bucket_hits"] = snap.get("xla.bucket_hits", 0)
        out["bucket_misses"] = snap.get("xla.bucket_misses", 0)
        out["latency_ms"] = {
            name.split("serve.latency_ms.", 1)[1]: snap[name]
            for name in snap
            if name.startswith("serve.latency_ms.")
        }
        return out

    def close(self) -> None:
        """Drain the pool and reject further submits (idempotent)."""

        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------- #
# The process-default service (what the launch/serve demo client rides)
# ---------------------------------------------------------------------- #

_DEFAULT: Optional[PlanService] = None
_DEFAULT_LOCK = threading.Lock()


def default_service() -> PlanService:
    """The lazily created process-global service instance."""

    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = PlanService()
        return _DEFAULT


def reset_default_service() -> None:
    """Close and discard the default service (``obs.reset_all()`` hook —
    the next ``default_service()`` call starts cold)."""

    global _DEFAULT
    with _DEFAULT_LOCK:
        svc, _DEFAULT = _DEFAULT, None
    if svc is not None:
        svc.close()
