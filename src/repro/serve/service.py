"""``PlanService`` — a continuous-batching plan service over the staged
pipeline.

The serving-layer form of the paper's amortization promise: analyze a loop
structure once, then serve any number of waves from caches.  A service
instance admits requests for many program *structures* concurrently and
resolves each through the full cache hierarchy —

  per-tenant plan LRU  →  structural compile cache  →  trace bucket
  →  per-bounds tables

— so a warm request touches no analysis, no scheduling, and (for bounds in
an already-traced bucket, see :mod:`repro.compile.lowering`) no jax tracing.

Concurrency discipline:

* a fixed worker pool (``ServiceOptions.workers``) runs submitted requests;
* *per-structure admission*: requests for the same program structure are
  serialized through a per-fingerprint lock, so a cold structure is planned
  and lowered exactly once no matter how many submitters race it — the
  structural cache's miss count stays equal to the number of distinct
  structures;
* *bounded admission*: more than ``max_queue_depth`` outstanding requests
  rejects at ``submit()`` instead of queueing without limit.

Cache entries are *artifact-level*: an entry holds the plan plus, once the
first request for it has compiled, the backend executable — warm requests
skip ``SyncPlan.compile`` entirely (``plan_cache.artifact_hits``).  Each
entry carries an estimated byte footprint; eviction enforces both the
per-tenant count bound and a global byte budget
(``ServiceOptions.plan_cache_bytes``), oldest-first from the heaviest
tenant, with the running total on the ``plan_cache.bytes`` gauge.

Observability (all in the unified ``repro.obs.metrics`` registry, so
``obs.reset_all()`` covers them): ``plan_cache.hits`` / ``plan_cache.misses``
/ ``plan_cache.evictions`` / ``plan_cache.artifact_hits`` counters and the
``plan_cache.size`` / ``plan_cache.bytes`` gauges for the per-tenant LRUs,
the ``serve.queue_depth`` gauge, and per-tenant
``serve.latency_ms.<tenant>`` histograms beside the global
``serve.plan_ms`` / ``serve.compile_ms`` ones.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Dict, Mapping, Optional, Tuple

from repro.obs import metrics as _metrics
from repro.core.ir import LoopProgram
from repro.core.parallelizer import (
    Executable,
    PlanOptions,
    SyncPlan,
    plan as _plan,
)
from repro.serve.options import ServiceOptions

__all__ = [
    "PlanService",
    "ServiceResult",
    "default_service",
    "reset_default_service",
]


@dataclasses.dataclass(frozen=True)
class ServiceResult:
    """What one admitted request resolved to."""

    tenant: str
    plan: SyncPlan
    executable: Executable
    store: Optional[dict]        # output store when the request ran
    plan_cached: bool            # per-tenant plan-LRU hit?
    latency_ms: float


class _CacheEntry:
    """One artifact-level LRU entry: the plan, the compiled executable once
    a request has built it (so warm requests skip ``SyncPlan.compile``
    entirely), and the entry's estimated byte footprint."""

    __slots__ = ("plan", "executable", "nbytes")

    def __init__(self, plan: SyncPlan, nbytes: int) -> None:
        self.plan = plan
        self.executable: Optional[Executable] = None
        self.nbytes = nbytes


class _TenantCache:
    """One tenant's bounded plan/artifact LRU (counters are plain ints
    here; the registry-backed totals are maintained by the owning
    service)."""

    __slots__ = ("entries", "bytes", "hits", "misses", "evictions")

    def __init__(self) -> None:
        self.entries: "collections.OrderedDict[Tuple, _CacheEntry]" = (
            collections.OrderedDict()
        )
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0


def _options_key(options: PlanOptions) -> object:
    """A hashable stand-in for the plan options (scc_policy instances may
    not be hashable; their repr is stable enough for a cache key)."""

    try:
        hash(options)
        return options
    except TypeError:
        return repr(options)


_SKIP_MODULES = ("_thread", "threading", "concurrent.futures", "builtins")


def _approx_nbytes(obj, _seen=None, _depth: int = 0) -> int:
    """Defensive recursive footprint estimate of a cache entry.

    Arrays report ``.nbytes`` (numpy and jax alike — the level tables and
    device buffers that dominate a compiled artifact); containers,
    dataclasses and slotted objects are walked to a bounded depth with a
    visited set; callables, modules, locks and thread machinery are
    skipped.  This is an *estimate* for eviction accounting, not an exact
    resident-size: structure shared between entries (e.g. one structural
    artifact behind two bounds) is charged to each entry that references
    it, which over-counts — the conservative direction for a byte budget.
    """

    import sys as _sys

    if _seen is None:
        _seen = set()
    if _depth > 8 or id(obj) in _seen:
        return 0
    _seen.add(id(obj))
    try:
        nbytes = getattr(obj, "nbytes", None)
        if isinstance(nbytes, int):
            return nbytes
        if obj is None or isinstance(obj, (bool, int, float, complex)):
            return _sys.getsizeof(obj)
        if isinstance(obj, (str, bytes, bytearray)):
            return _sys.getsizeof(obj)
        if callable(obj) or type(obj).__module__ in _SKIP_MODULES:
            return 0
        total = _sys.getsizeof(obj, 0)
        if isinstance(obj, Mapping):
            items = list(obj.items())[:256]
            for k, v in items:
                total += _approx_nbytes(k, _seen, _depth + 1)
                total += _approx_nbytes(v, _seen, _depth + 1)
            return total
        if isinstance(obj, (list, tuple, set, frozenset)):
            for v in list(obj)[:256]:
                total += _approx_nbytes(v, _seen, _depth + 1)
            return total
        state = getattr(obj, "__dict__", None)
        if state:
            total += _approx_nbytes(state, _seen, _depth + 1)
        for slot in getattr(type(obj), "__slots__", ()) or ():
            total += _approx_nbytes(
                getattr(obj, slot, None), _seen, _depth + 1
            )
        return total
    except Exception:
        return 0


class PlanService:
    """Multi-tenant plan service: ``submit()`` / ``drain()`` / ``stats()`` /
    ``close()`` over per-tenant bounded plan LRUs and a worker pool."""

    def __init__(self, options: Optional[ServiceOptions] = None) -> None:
        self.options = options if options is not None else ServiceOptions()
        self._lock = threading.Lock()
        self._tenants: Dict[str, _TenantCache] = {}
        self._structure_locks: Dict[str, threading.Lock] = {}
        self._outstanding: set = set()
        self._submitted = 0
        self._completed = 0
        self._closed = False
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.options.workers,
            thread_name_prefix="plan-serve",
        )
        if self.options.warm_profile:
            # load-or-measure the host cost profile before the first
            # request, so every plan this service builds prices strategy
            # offers with the same (measured) units — a persisted profile
            # makes this a microsecond file read, zero re-measurement
            from repro.calibrate import warm

            warm()

    # ------------------------------------------------------------------ #
    # Cache plumbing
    # ------------------------------------------------------------------ #

    def _tenant(self, name: str) -> _TenantCache:
        cache = self._tenants.get(name)
        if cache is None:
            cache = self._tenants.setdefault(name, _TenantCache())
        return cache

    def _structure_lock(self, fingerprint: str) -> threading.Lock:
        with self._lock:
            lock = self._structure_locks.get(fingerprint)
            if lock is None:
                lock = self._structure_locks[fingerprint] = threading.Lock()
            return lock

    def _cache_size(self) -> int:
        return sum(len(t.entries) for t in self._tenants.values())

    def _cache_bytes(self) -> int:
        return sum(t.bytes for t in self._tenants.values())

    def _evict_locked(self, cache: _TenantCache) -> None:
        """Enforce both LRU bounds (caller holds ``self._lock``): the
        per-tenant entry count, then the global byte budget — bytes evict
        oldest-first from whichever tenant currently holds the most."""

        while len(cache.entries) > self.options.plan_cache_capacity:
            self._pop_oldest_locked(cache)
        while self._cache_bytes() > self.options.plan_cache_bytes:
            victim = max(
                (t for t in self._tenants.values() if t.entries),
                key=lambda t: t.bytes,
                default=None,
            )
            if victim is None:
                break
            self._pop_oldest_locked(victim)
        _metrics.gauge("plan_cache.size").set(self._cache_size())
        _metrics.gauge("plan_cache.bytes").set(self._cache_bytes())

    def _pop_oldest_locked(self, cache: _TenantCache) -> None:
        _, entry = cache.entries.popitem(last=False)
        cache.bytes -= entry.nbytes
        cache.evictions += 1
        _metrics.counter("plan_cache.evictions").inc()

    def resolve(
        self,
        program: LoopProgram,
        options: Optional[PlanOptions] = None,
        *,
        tenant: Optional[str] = None,
    ) -> Tuple[SyncPlan, bool]:
        """The synchronous core: per-tenant plan LRU with per-structure
        admission.  Returns ``(plan, cached)``; records ``serve.plan_ms``
        (every call, hits included — the latency a serving wave observes)
        and the per-tenant ``plan_cache.*`` counters."""

        plan_obj, cached, _ = self._resolve_entry(
            program, options, tenant=tenant
        )
        return plan_obj, cached

    def _resolve_entry(
        self,
        program: LoopProgram,
        options: Optional[PlanOptions] = None,
        *,
        tenant: Optional[str] = None,
    ) -> Tuple[SyncPlan, bool, Tuple[str, Tuple]]:
        """``resolve`` plus the ``(tenant, key)`` handle ``_handle`` needs
        to find the entry again when attaching a compiled artifact."""

        tenant = tenant if tenant is not None else self.options.default_tenant
        options = options if options is not None else PlanOptions()
        t0 = time.perf_counter()
        from repro.compile.structure import program_fingerprint

        fp = program_fingerprint(program)
        key = (fp, program.bounds, _options_key(options))
        with self._lock:
            cache = self._tenant(tenant)
            cached = cache.entries.get(key)
            if cached is not None:
                cache.entries.move_to_end(key)
                cache.hits += 1
        if cached is not None:
            _metrics.counter("plan_cache.hits").inc()
            _metrics.histogram("serve.plan_ms").observe(
                (time.perf_counter() - t0) * 1e3
            )
            return cached.plan, True, (tenant, key)
        # per-structure admission: one planner per structure at a time, so
        # racing submitters of a cold structure queue here instead of
        # planning (and structurally compiling) the same thing twice
        with self._structure_lock(fp):
            with self._lock:
                cached = cache.entries.get(key)
                if cached is not None:
                    cache.entries.move_to_end(key)
                    cache.hits += 1
            if cached is not None:
                _metrics.counter("plan_cache.hits").inc()
                _metrics.histogram("serve.plan_ms").observe(
                    (time.perf_counter() - t0) * 1e3
                )
                return cached.plan, True, (tenant, key)
            built = _plan(program, options)
            entry = _CacheEntry(built, _approx_nbytes(built))
            with self._lock:
                cache.misses += 1
                cache.entries[key] = entry
                cache.bytes += entry.nbytes
                self._evict_locked(cache)
        _metrics.counter("plan_cache.misses").inc()
        _metrics.histogram("serve.plan_ms").observe(
            (time.perf_counter() - t0) * 1e3
        )
        return built, False, (tenant, key)

    # ------------------------------------------------------------------ #
    # The public request surface
    # ------------------------------------------------------------------ #

    def submit(
        self,
        program: LoopProgram,
        options: Optional[PlanOptions] = None,
        *,
        tenant: Optional[str] = None,
        store: Optional[Mapping[str, dict]] = None,
        run: bool = False,
        deadline_ms: Optional[float] = None,
    ) -> "concurrent.futures.Future[ServiceResult]":
        """Admit one request: plan (through the tenant's LRU), compile for
        the service backend, optionally execute.

        Returns a future of :class:`ServiceResult`.  ``store``/``run=True``
        execute the compiled artifact (``store`` is copied, not mutated).
        Raises ``RuntimeError`` when the service is closed or the admission
        bound (``max_queue_depth``) is reached.

        ``deadline_ms`` bounds the *queueing* delay: a request still waiting
        for a worker past its deadline is dropped at dequeue — its future
        fails with ``RuntimeError`` and ``serve.deadline_drops`` counts it —
        instead of occupying a worker to produce a result the caller has
        already abandoned.  A request that *starts* before the deadline runs
        to completion (the deadline is admission control, not preemption).
        """

        if deadline_ms is not None:
            if (
                isinstance(deadline_ms, bool)
                or not isinstance(deadline_ms, (int, float))
                or not deadline_ms > 0
            ):
                raise ValueError(
                    f"deadline_ms must be a positive number of milliseconds,"
                    f" got {deadline_ms!r}"
                )
        deadline = (
            None
            if deadline_ms is None
            else time.perf_counter() + deadline_ms / 1e3
        )
        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "PlanService is closed — create a new service to submit"
                )
            if len(self._outstanding) >= self.options.max_queue_depth:
                raise RuntimeError(
                    f"admission rejected: {len(self._outstanding)} requests "
                    f"outstanding >= max_queue_depth="
                    f"{self.options.max_queue_depth}"
                )
            self._submitted += 1
        future = self._pool.submit(
            self._handle, program, options, tenant, store, run, deadline
        )
        with self._lock:
            self._outstanding.add(future)
            _metrics.gauge("serve.queue_depth").set(len(self._outstanding))
        future.add_done_callback(self._settle)
        return future

    def _settle(self, future) -> None:
        with self._lock:
            self._outstanding.discard(future)
            self._completed += 1
            _metrics.gauge("serve.queue_depth").set(len(self._outstanding))

    def _handle(
        self,
        program: LoopProgram,
        options: Optional[PlanOptions],
        tenant: Optional[str],
        store: Optional[Mapping[str, dict]],
        run: bool,
        deadline: Optional[float] = None,
    ) -> ServiceResult:
        tenant = tenant if tenant is not None else self.options.default_tenant
        t0 = time.perf_counter()
        if deadline is not None and t0 > deadline:
            _metrics.counter("serve.deadline_drops").inc()
            raise RuntimeError(
                f"request dropped at dequeue: queued "
                f"{(t0 - deadline) * 1e3:.1f}ms past its deadline "
                f"(deadline_ms admission control)"
            )
        plan_obj, cached, (tenant, key) = self._resolve_entry(
            program, options, tenant=tenant
        )
        tc = time.perf_counter()
        executable = None
        with self._lock:
            entry = self._tenant(tenant).entries.get(key)
            if entry is not None and entry.executable is not None:
                executable = entry.executable
        if executable is not None:
            _metrics.counter("plan_cache.artifact_hits").inc()
        else:
            # compile under the same per-structure admission lock as
            # planning: get_or_compile counts a lost race as a second
            # structural miss, so without this two workers handling the same
            # cold structure would both lower it and the miss count would
            # exceed #distinct structures
            from repro.compile.structure import program_fingerprint

            with self._structure_lock(program_fingerprint(program)):
                executable = plan_obj.compile(self.options.backend)
            extra = _approx_nbytes(executable)
            with self._lock:
                cache = self._tenant(tenant)
                entry = cache.entries.get(key)
                # attach the artifact so later requests skip compile();
                # entry may have been evicted (or replaced by a racing
                # re-plan) since resolve — then the artifact is just not
                # cached, which is correct
                if entry is not None and entry.plan is plan_obj:
                    if entry.executable is None:
                        entry.executable = executable
                        entry.nbytes += extra
                        cache.bytes += extra
                        self._evict_locked(cache)
                    else:
                        executable = entry.executable
        _metrics.histogram("serve.compile_ms").observe(
            (time.perf_counter() - tc) * 1e3
        )
        out = None
        if run or store is not None:
            init = {
                a: dict(c)
                for a, c in (store or program.initial_store()).items()
            }
            out = executable.run(store=init)
        latency = (time.perf_counter() - t0) * 1e3
        _metrics.histogram(f"serve.latency_ms.{tenant}").observe(latency)
        return ServiceResult(
            tenant=tenant,
            plan=plan_obj,
            executable=executable,
            store=out,
            plan_cached=cached,
            latency_ms=latency,
        )

    def drain(self, timeout: Optional[float] = None) -> dict:
        """Block until every outstanding request settles; returns
        :meth:`stats`.  Raises ``TimeoutError`` if ``timeout`` (seconds)
        elapses first."""

        with self._lock:
            pending = tuple(self._outstanding)
        done, not_done = concurrent.futures.wait(pending, timeout=timeout)
        if not_done:
            raise TimeoutError(
                f"drain timed out with {len(not_done)} requests outstanding"
            )
        return self.stats()

    def stats(self) -> dict:
        """A JSON-able snapshot: per-tenant cache traffic, queue state, and
        the trace/bucket counters behind the re-trace rate (this is the
        ``SERVE_sync`` artifact the bench job uploads)."""

        snap = _metrics.snapshot()
        with self._lock:
            tenants = {
                name: {
                    "size": len(t.entries),
                    "bytes": t.bytes,
                    "hits": t.hits,
                    "misses": t.misses,
                    "evictions": t.evictions,
                }
                for name, t in sorted(self._tenants.items())
            }
            out = {
                "backend": self.options.backend,
                "workers": self.options.workers,
                "tenants": tenants,
                "plan_cache": {
                    "size": self._cache_size(),
                    "bytes": self._cache_bytes(),
                    "bytes_budget": self.options.plan_cache_bytes,
                    "capacity_per_tenant": self.options.plan_cache_capacity,
                    "hits": sum(t.hits for t in self._tenants.values()),
                    "misses": sum(t.misses for t in self._tenants.values()),
                    "evictions": sum(
                        t.evictions for t in self._tenants.values()
                    ),
                },
                "queue_depth": len(self._outstanding),
                "submitted": self._submitted,
                "completed": self._completed,
            }
        out["deadline_drops"] = snap.get("serve.deadline_drops", 0)
        out["traces"] = snap.get("xla.traces", 0)
        out["bucket_hits"] = snap.get("xla.bucket_hits", 0)
        out["bucket_misses"] = snap.get("xla.bucket_misses", 0)
        out["latency_ms"] = {
            name.split("serve.latency_ms.", 1)[1]: snap[name]
            for name in snap
            if name.startswith("serve.latency_ms.")
        }
        return out

    def close(self) -> None:
        """Drain the pool and reject further submits (idempotent)."""

        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------- #
# The process-default service (what the launch/serve demo client rides)
# ---------------------------------------------------------------------- #

_DEFAULT: Optional[PlanService] = None
_DEFAULT_LOCK = threading.Lock()


def default_service() -> PlanService:
    """The lazily created process-global service instance."""

    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = PlanService()
        return _DEFAULT


def reset_default_service() -> None:
    """Close and discard the default service (``obs.reset_all()`` hook —
    the next ``default_service()`` call starts cold)."""

    global _DEFAULT
    with _DEFAULT_LOCK:
        svc, _DEFAULT = _DEFAULT, None
    if svc is not None:
        svc.close()
