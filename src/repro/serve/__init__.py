"""repro.serve — the multi-tenant plan service.

Public surface of the serving layer (ROADMAP "Serving layer" item): a
:class:`PlanService` admits requests for many program structures
concurrently and resolves each through the full cache hierarchy — per-tenant
plan LRU → structural compile cache → trace bucket → per-bounds tables — so
steady-state traffic never re-analyzes *or re-traces*.

    from repro.serve import PlanService, ServiceOptions

    svc = PlanService(ServiceOptions(workers=4, plan_cache_capacity=8))
    fut = svc.submit(prog, PlanOptions(method="isd"), tenant="decode",
                     run=True)
    result = fut.result()          # ServiceResult: plan, executable, store
    svc.drain()                    # block until the queue is empty
    snap = svc.stats()             # the SERVE_sync artifact snapshot
    svc.close()

The wave helpers the demo client (``repro.launch.serve``) uses —
``plan_wave_sync`` / ``plan_scan_sync`` / ``plan_route_sync`` /
``plan_rescore_sync`` / ``plan_wave`` / ``run_nonaffine_wave`` — live here
too, riding the process-default service instance (:func:`default_service`).
"""

from repro.serve.options import ServiceOptions
from repro.serve.service import (
    PlanService,
    ServiceResult,
    default_service,
    reset_default_service,
)
from repro.serve.waves import (
    decode_program,
    plan_rescore_sync,
    plan_route_sync,
    plan_scan_sync,
    plan_wave,
    plan_wave_sync,
    run_nonaffine_wave,
    scan_program,
)

__all__ = [
    "PlanService",
    "ServiceOptions",
    "ServiceResult",
    "default_service",
    "reset_default_service",
    "decode_program",
    "scan_program",
    "plan_wave_sync",
    "plan_scan_sync",
    "plan_route_sync",
    "plan_rescore_sync",
    "run_nonaffine_wave",
    "plan_wave",
]
