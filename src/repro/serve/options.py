"""``ServiceOptions`` — the frozen, validated configuration of a
:class:`~repro.serve.service.PlanService`.

Mirrors the contract of :class:`repro.core.parallelizer.PlanOptions`: frozen
and hashable so a service configuration is a legitimate cache-key component,
and validated *eagerly* so a bad knob fails at construction with a message
naming the accepted set — including unknown knob *names*, which
``PlanOptions`` leaves to the dataclass ``TypeError`` but a service (whose
callers typically forward a config dict) must reject with the same
ValueError-naming-the-accepted-set shape the backend capability contracts
use (:func:`repro.core.parallelizer._check_backend_options`).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, init=False)
class ServiceOptions:
    """Typed knobs of a :class:`~repro.serve.service.PlanService`.

    ``backend``: the execution backend every submitted request compiles for
    (checked against the parallelizer's backend registry, lazy providers
    included).
    ``workers``: worker-pool width — how many requests resolve concurrently
    (per-structure admission still serializes same-structure requests, so
    one cold structure never plans twice).
    ``plan_cache_capacity``: per-tenant bound of the plan/artifact LRU
    (evictions surface as ``plan_cache.evictions`` in ``obs.metrics``).
    ``plan_cache_bytes``: byte budget over ALL tenants' cached entries —
    each entry carries an estimated footprint of its plan plus compiled
    artifact, the total rides the ``plan_cache.bytes`` gauge, and the LRU
    evicts past-budget entries oldest-first (count bound still applies).
    ``max_queue_depth``: admission bound — ``submit()`` beyond this many
    outstanding requests is rejected instead of queueing without limit.
    ``default_tenant``: tenant used when ``submit()``/``resolve()`` are not
    given one.
    ``warm_profile``: warm the host's cost-calibration profile
    (:func:`repro.calibrate.warm`) once at service construction — a
    persisted profile loads in microseconds, a cold host pays the
    microbenchmark once *before* traffic instead of never (plans then price
    strategy offers with measured units).
    """

    backend: str = "xla"
    workers: int = 2
    plan_cache_capacity: int = 16
    plan_cache_bytes: int = 64 * 1024 * 1024
    max_queue_depth: int = 64
    default_tenant: str = "default"
    warm_profile: bool = False

    def __init__(self, **knobs: object) -> None:
        accepted = tuple(f.name for f in dataclasses.fields(self))
        unknown = sorted(k for k in knobs if k not in accepted)
        if unknown:
            raise ValueError(
                f"ServiceOptions does not accept knob(s) "
                f"{', '.join(repr(k) for k in unknown)}; the accepted set is "
                f"{sorted(accepted)} — drop the knob or check its spelling"
            )
        for f in dataclasses.fields(self):
            object.__setattr__(self, f.name, knobs.get(f.name, f.default))
        self._validate()

    def _validate(self) -> None:
        from repro.core.parallelizer import get_backend

        if not isinstance(self.backend, str) or not self.backend:
            raise ValueError(
                f"backend must be a non-empty backend name, got "
                f"{self.backend!r}"
            )
        get_backend(self.backend)  # raises naming the registered set
        for knob in (
            "workers",
            "plan_cache_capacity",
            "plan_cache_bytes",
            "max_queue_depth",
        ):
            v = getattr(self, knob)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(
                    f"{knob} must be a positive integer, got {v!r} — a "
                    "service with zero capacity cannot admit requests"
                )
        if not isinstance(self.default_tenant, str) or not self.default_tenant:
            raise ValueError(
                f"default_tenant must be a non-empty string, got "
                f"{self.default_tenant!r}"
            )
        if not isinstance(self.warm_profile, bool):
            raise ValueError(
                f"warm_profile must be a bool, got {self.warm_profile!r}"
            )
