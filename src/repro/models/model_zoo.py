"""Unified model API over decoder / encoder-decoder families.

``batch`` dict contract (all modes):
  tokens (B,S) int32            — text tokens (decoder input for encdec)
  labels (B,S) int32            — next-token targets (train)
  frame_embeds (B,F,d)          — audio frontend stub (whisper)
  patch_embeds (B,P,d)          — vision frontend stub (llava)

``loss_fn`` is the training objective (mean NLL + MoE aux), ``prefill`` /
``decode_step`` the serving path.  All functions are functional and jit/pjit
friendly; sharding is attached at the launch layer.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLP_MOE, ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import softmax_cross_entropy

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------- #
# init / forward / loss
# ---------------------------------------------------------------------- #

def init(key: jax.Array, cfg: ModelConfig) -> dict:
    if cfg.family == "encdec":
        return encdec.init_encdec(key, cfg)
    return transformer.init_decoder(key, cfg)


def forward_logits(
    params: dict, batch: Dict[str, jax.Array], cfg: ModelConfig,
    act_constrain=None,
) -> Tuple[jax.Array, jax.Array]:
    if cfg.family == "encdec":
        return encdec.forward(params, batch["frame_embeds"], batch["tokens"], cfg)
    return transformer.forward(
        params, batch["tokens"], cfg, prefix_embeds=batch.get("patch_embeds"),
        act_constrain=act_constrain,
    )


def loss_fn(
    params: dict, batch: Dict[str, jax.Array], cfg: ModelConfig,
    act_constrain=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_logits(params, batch, cfg, act_constrain)
    labels = batch["labels"]
    if cfg.frontend == "vision" and cfg.num_patches:
        # loss over text positions only (patch prefix produces no targets)
        logits = logits[:, cfg.num_patches :, :]
    nll = softmax_cross_entropy(logits, labels, batch.get("loss_mask"))
    loss = nll + AUX_LOSS_WEIGHT * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------- #
# serving
# ---------------------------------------------------------------------- #

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len)
    return transformer.init_cache(cfg, batch, max_len)


def prefill(
    params: dict, batch: Dict[str, jax.Array], cfg: ModelConfig, cache: dict
) -> Tuple[jax.Array, dict]:
    if cfg.family == "encdec":
        return encdec.prefill(
            params, batch["frame_embeds"], batch["tokens"], cfg, cache
        )
    return transformer.prefill(
        params, batch["tokens"], cfg, cache,
        prefix_embeds=batch.get("patch_embeds"),
    )


def decode_step(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    cache: dict,
    cache_len: jax.Array,
) -> Tuple[jax.Array, dict]:
    if cfg.family == "encdec":
        return encdec.decode_step(params, tokens, cfg, cache, cache_len)
    return transformer.decode_step(params, tokens, cfg, cache, cache_len)


# ---------------------------------------------------------------------- #
# accounting (roofline's MODEL_FLOPS)
# ---------------------------------------------------------------------- #

def param_count(params: dict) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(params: dict, cfg: ModelConfig) -> int:
    """Parameters touched per token: routed experts scaled by top_k/E."""

    if not cfg.has_moe:
        return param_count(params)
    assert cfg.moe is not None
    total = 0
    frac = cfg.moe.top_k / cfg.moe.num_experts

    def walk(tree: Any, inside_moe: bool) -> int:
        n = 0
        if isinstance(tree, dict):
            for k, v in tree.items():
                if k == "moe":
                    # routed expert weights scale by top_k/E; router+shared full
                    for kk, vv in v.items():
                        leaves = jax.tree.leaves(vv)
                        size = sum(x.size for x in leaves)
                        if kk in ("w_gate", "w_up", "w_down"):
                            n += int(size * frac)
                        else:
                            n += size
                else:
                    n += walk(v, inside_moe)
        else:
            n += sum(x.size for x in jax.tree.leaves(tree))
        return n

    return walk(params, False)


def model_flops_per_token(params: dict, cfg: ModelConfig) -> float:
    """6·N(active)·1 per token (the §Roofline MODEL_FLOPS convention)."""

    return 6.0 * active_param_count(params, cfg)


def abstract_params(cfg: ModelConfig, key: Optional[jax.Array] = None):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""

    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init(k, cfg), key)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
