"""Mamba2 (state-space duality) mixer — chunked SSD prefill + O(1) decode.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks; intra-chunk terms are dense matmuls (MXU-friendly — this
is the whole point of SSD on TPU), inter-chunk state is carried by a short
``lax.scan``.  Decode updates the (B, H, P, N) state in O(1) per token.

Projections are split per component (z, x, B, C, dt) rather than fused, so
tensor-parallel sharding maps cleanly: z/x/dt/head dims shard over ``model``;
the small B/C projections stay replicated.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig

NEG_INF = -1e30


def mamba_init(key: jax.Array, cfg: ModelConfig) -> dict:
    assert cfg.mamba is not None
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.d_inner(d)
    H = mc.num_heads(d)
    N, G = mc.d_state, 1
    ks = jax.random.split(key, 8)
    s = d**-0.5
    dt = jnp.exp(
        jax.random.uniform(ks[6], (H,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    dtype = jnp.dtype(cfg.dtype)
    return {
        "wz": (jax.random.normal(ks[0], (d, di), jnp.float32) * s).astype(dtype),
        "wx": (jax.random.normal(ks[1], (d, di), jnp.float32) * s).astype(dtype),
        "wB": (jax.random.normal(ks[2], (d, G * N), jnp.float32) * s).astype(dtype),
        "wC": (jax.random.normal(ks[3], (d, G * N), jnp.float32) * s).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (d, H), jnp.float32) * s).astype(dtype),
        "out": (jax.random.normal(ks[5], (di, d), jnp.float32) * di**-0.5).astype(dtype),
        "conv_x": (jax.random.normal(ks[7], (mc.d_conv, di), jnp.float32) * 0.2).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv.  x (B,S,C), w (K,C).  ``tail`` (B,K-1,C) is the
    running state for decode/prefill-continuation; returns (y, new_tail)."""

    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, k : k + x.shape[1], :] * w[k][None, None, :] for k in range(K))
    new_tail = xp[:, x.shape[1] :, :]  # last K-1 inputs
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_tail


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., Q) → (..., Q, Q) lower-triangular segment sums: out[i,j] =
    sum a[j+1..i] for j<=i, -inf above the diagonal."""

    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    x: jax.Array,  # (B,S,H,P)
    dt: jax.Array,  # (B,S,H) post-softplus
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B,S,N)   (single group)
    Cm: jax.Array,  # (B,S,N)
    chunk: int,
    h0: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,S,H,P), final state (B,H,P,N))."""

    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # padded steps have dt=0: decay exp(0)=1 and zero state contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // Q

    xa = (x * dt[..., None]).astype(jnp.float32)  # fold dt into x
    dA = (dt * A[None, None, :]).astype(jnp.float32)  # (B,S,H)

    # chunked views
    xc = xa.reshape(B_, nc, Q, H, P)
    dAc = dA.reshape(B_, nc, Q, H).transpose(0, 3, 1, 2)  # (B,H,nc,Q)
    Bc = Bm.reshape(B_, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, Q, N).astype(jnp.float32)

    cum = jnp.cumsum(dAc, axis=-1)  # (B,H,nc,Q)
    L = jnp.exp(_segsum(dAc))  # (B,H,nc,Q,Q)

    # 1. intra-chunk output
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)  # (B,nc,Q,Q)
    y_diag = jnp.einsum(
        "bcqs,bhcqs,bcshp->bcqhp", scores, L, xc
    )

    # 2. per-chunk input → state contribution
    decay_states = jnp.exp(cum[..., -1:] - cum)  # (B,H,nc,Q)
    states = jnp.einsum("bcqn,bhcq,bcqhp->bchpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])  # (B,H,nc)
    h_init = (
        jnp.zeros((B_, H, P, N), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def scan_fn(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_out = h  # state *entering* this chunk
        h_next = h * dec[..., None, None] + st
        return h_next, h_out

    states_t = states.transpose(1, 0, 2, 3, 4)  # (nc,B,H,P,N)
    decay_t = chunk_decay.transpose(2, 0, 1)  # (nc,B,H)
    h_final, h_enter = jax.lax.scan(scan_fn, h_init, (states_t, decay_t))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # 4. state → output within each chunk
    state_decay = jnp.exp(cum)  # (B,H,nc,Q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", Cc, h_enter, state_decay)

    y = (y_diag + y_off).reshape(B_, S_pad, H, P)
    if pad:
        y = y[:, :S]
    return y, h_final


def mamba_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: dict | None = None,
) -> Tuple[jax.Array, dict]:
    """Full-sequence (train/prefill) Mamba2 mixer.  Returns (y, new_state)."""

    assert cfg.mamba is not None
    mc = cfg.mamba
    B_, S, d = x.shape
    H, P, N = mc.num_heads(d), mc.head_dim, mc.d_state

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xin = jnp.einsum("bsd,de->bse", x, params["wx"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["wdt"])
    Bm = jnp.einsum("bsd,dn->bsn", x, params["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, params["wC"])

    conv_tail = state["conv"] if state is not None else None
    xin, new_tail = _causal_conv(xin, params["conv_x"], conv_tail)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    A = -jnp.exp(params["A_log"])
    xh = xin.reshape(B_, S, H, P)
    h0 = state["ssm"] if state is not None else None
    y, h = ssd_chunked(xh, dt, A, Bm, Cm, mc.chunk, h0)
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B_, S, H * P)

    # gated RMS norm (mamba2's pre-out-proj norm)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm"]
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out"])
    return out, {"ssm": h, "conv": new_tail}


def mamba_decode_step(
    params: dict, x: jax.Array, cfg: ModelConfig, state: dict
) -> Tuple[jax.Array, dict]:
    """One-token step.  x (B,1,d); state {'ssm': (B,H,P,N), 'conv': (B,K-1,di)}."""

    assert cfg.mamba is not None
    mc = cfg.mamba
    B_, _, d = x.shape
    H, P, N = mc.num_heads(d), mc.head_dim, mc.d_state

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xin = jnp.einsum("bsd,de->bse", x, params["wx"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["wdt"])
    Bm = jnp.einsum("bsd,dn->bsn", x, params["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, params["wC"])

    xin, new_tail = _causal_conv(xin, params["conv_x"], state["conv"])

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])  # (B,H)
    xh = xin.reshape(B_, H, P).astype(jnp.float32)
    Bf = Bm[:, 0].astype(jnp.float32)  # (B,N)
    Cf = Cm[:, 0].astype(jnp.float32)

    h = state["ssm"].astype(jnp.float32)
    h = h * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bf
    )
    y = jnp.einsum("bhpn,bn->bhp", h, Cf) + xh * params["D"][None, :, None]
    y = y.reshape(B_, 1, H * P)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm"]
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out"])
    return out, {"ssm": h, "conv": new_tail}


def mamba_init_state(cfg: ModelConfig, batch: int) -> dict:
    assert cfg.mamba is not None
    mc = cfg.mamba
    d = cfg.d_model
    H, P, N = mc.num_heads(d), mc.head_dim, mc.d_state
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, mc.d_inner(d)), jnp.dtype(cfg.dtype)),
    }


def ssd_reference(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    h0: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential-recurrence oracle for the chunked SSD (tests)."""

    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    h = (
        jnp.zeros((B_, H, P, N), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])  # (B,H)
        h = h * dA[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn",
            dt[:, t],
            x[:, t].astype(jnp.float32),
            Bm[:, t].astype(jnp.float32),
        )
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cm[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1), h
