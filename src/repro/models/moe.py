"""Mixture-of-Experts MLP: top-k router + GShard-style grouped dispatch.

Dispatch is capacity-based within token groups of ``group_size`` so the
one-hot dispatch/combine einsums cost O(tokens · group · d) instead of
O(tokens · seq · d) — the standard TPU formulation (einsums lower to MXU
matmuls; no dynamic shapes, SPMD-friendly).  Supports deepseek-style shared
experts (always-on dense experts added to the routed output).

Expert parallelism: the expert-stacked weights carry an ``experts`` logical
axis that the sharding rules map onto the ``model`` mesh axis when the
expert count divides it (deepseek 64, jamba 16); otherwise tensor-parallel
sharding of ``d_ff_expert`` applies (mixtral 8 experts on a 16-way axis).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import mlp, mlp_init


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    mc = cfg.moe
    d, ff, E = cfg.d_model, mc.d_ff_expert, mc.num_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = d**-0.5, ff**-0.5
    params = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * s_in).astype(
            jnp.float32
        ),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * s_in).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * s_in).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) * s_out).astype(cfg.dtype),
    }
    if mc.num_shared:
        params["shared"] = mlp_init(ks[4], d, ff * mc.num_shared, jnp.dtype(cfg.dtype))
    return params


def _capacity(mc: MoEConfig, group: int) -> int:
    cap = int(group * mc.top_k * mc.capacity_factor / mc.num_experts)
    return max(cap, mc.top_k)


def moe_apply(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) → (y (B,S,d), aux_loss scalar).

    Returns the load-balancing auxiliary loss (Shazeer-style: mean fraction
    of tokens per expert × mean router prob per expert × E²·coef)."""

    assert cfg.moe is not None
    mc = cfg.moe
    B, S, d = x.shape
    E, K = mc.num_experts, mc.top_k
    tokens = B * S
    G = min(mc.group_size, tokens)
    n_groups = tokens // G
    assert n_groups * G == tokens, (tokens, G)
    C = _capacity(mc, G)

    xg = x.reshape(n_groups, G, d)
    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (n,G,E)

    # top-k selection per token
    top_p, top_e = jax.lax.top_k(probs, K)  # (n,G,K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert's capacity, by token order
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # (n,G,K,E)
    flat = onehot.reshape(n_groups, G * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (n,G*K,E) slots before this one
    pos = jnp.einsum("nse,nse->ns", pos, flat).reshape(n_groups, G, K)
    keep = pos < C
    top_p = top_p * keep

    # dispatch (n,G,E,C) / combine weights
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)  # (n,G,K,C)
    dispatch = jnp.einsum("ngke,ngkc->ngec", onehot, pos_oh * keep[..., None])
    combine = jnp.einsum("ngke,ngkc,ngk->ngec", onehot, pos_oh, top_p)

    expert_in = jnp.einsum(
        "ngec,ngd->negcd".replace("negcd", "encd"),
        dispatch.astype(x.dtype),
        xg,
    )  # (E,n,C,d)
    gate = jnp.einsum("encd,edf->encf", expert_in, params["w_gate"])
    up = jnp.einsum("encd,edf->encf", expert_in, params["w_up"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    expert_out = jnp.einsum("encf,efd->encd", act, params["w_down"])
    yg = jnp.einsum("ngec,encd->ngd", combine.astype(x.dtype), expert_out)

    y = yg.reshape(B, S, d)
    if mc.num_shared:
        y = y + mlp(params["shared"], x)

    # aux load-balancing loss
    density = jnp.mean(onehot.sum(axis=2), axis=1)         # (n,E) token frac
    router_prob = jnp.mean(probs, axis=1)                  # (n,E)
    aux = jnp.mean(jnp.sum(density * router_prob, axis=-1)) * E
    return y, aux.astype(jnp.float32)


def moe_reference(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dense oracle: every token through its top-k experts exactly (no
    capacity drops).  Used by tests on small configs."""

    assert cfg.moe is not None
    mc = cfg.moe
    B, S, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, mc.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    def per_expert(e):
        w = {k: params[k][e] for k in ("w_gate", "w_up", "w_down")}
        gate = jnp.einsum("bsd,df->bsf", x, w["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, w["w_up"])
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        return jnp.einsum("bsf,fd->bsd", act, w["w_down"])

    all_out = jnp.stack([per_expert(e) for e in range(mc.num_experts)])  # (E,B,S,d)
    sel = jnp.take_along_axis(
        all_out.transpose(1, 2, 0, 3),  # (B,S,E,d)
        top_e[..., None].astype(jnp.int32),
        axis=2,
    )  # (B,S,K,d)
    y = jnp.sum(sel * top_p[..., None].astype(x.dtype), axis=2)
    if mc.num_shared:
        y = y + mlp(params["shared"], x)
    return y
