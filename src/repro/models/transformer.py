"""Decoder LM over repeating layer blocks, with scan-over-blocks.

Supports every assigned decoder-family architecture through the block
pattern in :class:`repro.configs.base.ModelConfig`:

  * dense GQA (yi, granite, internlm2) — block = [attn+dense]
  * 5:1 local:global (gemma3) — block = [local×5, global], remainder layers
  * MoE (deepseek: 64e top-6 + 2 shared; mixtral: 8e top-2 + SWA)
  * hybrid (jamba: mamba×7 : attn×1, MoE every other layer)
  * pure SSM (mamba2) — attention-free
  * VLM (llava) — patch-embedding prefix from the stubbed vision frontend

Three entry modes share the layer code: ``train`` (full seq, no cache),
``prefill`` (full seq, builds cache), ``decode`` (one token against cache).
Parameters for the ``num_blocks`` repeats are stacked on a leading axis and
consumed by ``lax.scan`` so HLO size is depth-independent; remainder layers
(depth % block) are unrolled at the end.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN,
    ATTN_LOCAL,
    MAMBA,
    MLP_DENSE,
    MLP_MOE,
    MLP_NONE,
    LayerPos,
    ModelConfig,
)
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import (
    embed,
    embed_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)

# ---------------------------------------------------------------------- #
# init
# ---------------------------------------------------------------------- #

def _layer_init(key: jax.Array, pos: LayerPos, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model)}
    if pos.mixer in (ATTN, ATTN_LOCAL):
        p["attn"] = attn_lib.attn_init(k1, cfg)
    elif pos.mixer == MAMBA:
        p["mamba"] = mamba_lib.mamba_init(k1, cfg)
    else:
        raise ValueError(pos.mixer)
    if pos.mlp == MLP_DENSE and cfg.d_ff > 0:
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype))
    elif pos.mlp == MLP_MOE:
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["moe"] = moe_lib.moe_init(k2, cfg)
    return p


def _block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.block))
    return {
        f"pos{i}": _layer_init(keys[i], pos, cfg)
        for i, pos in enumerate(cfg.block)
    }


def init_decoder(key: jax.Array, cfg: ModelConfig) -> dict:
    k_embed, k_blocks, k_rem = jax.random.split(key, 3)
    params: Dict[str, Any] = {"embed": embed_init(k_embed, cfg)}
    if cfg.num_blocks:
        block_keys = jax.random.split(k_blocks, cfg.num_blocks)
        params["blocks"] = jax.vmap(lambda k: _block_init(k, cfg))(block_keys)
    rem_keys = jax.random.split(k_rem, max(cfg.remainder_layers, 1))
    params["rem"] = {
        f"layer{i}": _layer_init(rem_keys[i], cfg.block[i], cfg)
        for i in range(cfg.remainder_layers)
    }
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    return params


# ---------------------------------------------------------------------- #
# caches
# ---------------------------------------------------------------------- #

def _layer_cache(pos: LayerPos, cfg: ModelConfig, batch: int, max_len: int) -> dict:
    if pos.mixer in (ATTN, ATTN_LOCAL):
        shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        if cfg.kv_quant:
            sshape = shape[:-1] + (1,)
            return {
                "k_q": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.float32),
                "v_q": jnp.zeros(shape, jnp.int8),
                "v_s": jnp.zeros(sshape, jnp.float32),
            }
        return {
            "k": jnp.zeros(shape, jnp.dtype(cfg.dtype)),
            "v": jnp.zeros(shape, jnp.dtype(cfg.dtype)),
        }
    return mamba_lib.mamba_init_state(cfg, batch)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    cache: Dict[str, Any] = {}
    if cfg.num_blocks:
        per_block = {
            f"pos{i}": _layer_cache(pos, cfg, batch, max_len)
            for i, pos in enumerate(cfg.block)
        }
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.num_blocks,) + x.shape
            ).copy(),
            per_block,
        )
    cache["rem"] = {
        f"layer{i}": _layer_cache(cfg.block[i], cfg, batch, max_len)
        for i in range(cfg.remainder_layers)
    }
    return cache


# ---------------------------------------------------------------------- #
# layer application (shared by all modes)
# ---------------------------------------------------------------------- #

def _apply_layer(
    p: dict,
    x: jax.Array,
    pos: LayerPos,
    cfg: ModelConfig,
    mode: str,
    cache: Optional[dict],
    cache_len: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""

    aux = jnp.zeros((), jnp.float32)
    window = cfg.sliding_window if pos.mixer == ATTN_LOCAL else None

    def pin(t: jax.Array) -> jax.Array:
        # Pin the residual stream to bf16 at layer boundaries: without this
        # the SPMD partitioner sinks the downstream rmsnorm's f32 convert
        # underneath the tensor-parallel all-reduce and reduces in f32 —
        # doubling the dominant collective traffic (measured: gemma3 train
        # 197 GB/chip → 99 GB/chip; EXPERIMENTS.md §Perf iteration 1).
        return jax.lax.optimization_barrier(t) if cfg.pin_collective_dtype else t

    # --- mixer ---
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if pos.mixer in (ATTN, ATTN_LOCAL):
        q, k, v = attn_lib.qkv_project(p["attn"], h)
        if mode == "decode":
            positions = cache_len.reshape(1)
        else:
            positions = jnp.arange(x.shape[1])
        q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
        k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
        new_cache = cache
        if mode == "train":
            o = attn_lib.chunked_attention(
                q, k, v, causal=True, window=window, chunk=cfg.attn_chunk
            )
        elif mode == "prefill":
            if cfg.kv_quant:
                new_cache = attn_lib.update_kv_cache_q(cache, k, v, 0)
            else:
                kc, vc = attn_lib.update_kv_cache(
                    cache["k"], cache["v"], k, v, 0
                )
                new_cache = {"k": kc, "v": vc}
            o = attn_lib.chunked_attention(
                q, k, v, causal=True, window=window, chunk=cfg.attn_chunk
            )
        else:  # decode
            if cfg.kv_quant:
                new_cache = attn_lib.update_kv_cache_q(cache, k, v, cache_len)
                o = attn_lib.decode_attention_q(
                    q, new_cache, cache_len + 1, window=window
                )
            else:
                kc, vc = attn_lib.update_kv_cache(
                    cache["k"], cache["v"], k, v, cache_len
                )
                new_cache = {"k": kc, "v": vc}
                o = attn_lib.decode_attention(
                    q, kc, vc, cache_len + 1, window=window
                )
        x = pin(x + attn_lib.out_project(p["attn"], o))
    else:  # mamba
        if mode == "train":
            o, _ = mamba_lib.mamba_apply(p["mamba"], h, cfg, None)
            new_cache = cache
        elif mode == "prefill":
            o, new_cache = mamba_lib.mamba_apply(p["mamba"], h, cfg, cache)
        else:
            o, new_cache = mamba_lib.mamba_decode_step(p["mamba"], h, cfg, cache)
        x = pin(x + o)

    # --- mlp ---
    if pos.mlp == MLP_DENSE and "mlp" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = pin(x + mlp(p["mlp"], h))
    elif pos.mlp == MLP_MOE:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = moe_lib.moe_apply(p["moe"], h, cfg)
        x = pin(x + y)
    return x, new_cache, aux


def _apply_block(
    bp: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mode: str,
    bc: Optional[dict],
    cache_len: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    new_bc: Dict[str, Any] = {}
    for i, pos in enumerate(cfg.block):
        pc = bc[f"pos{i}"] if bc is not None else None
        x, npc, aux = _apply_layer(
            bp[f"pos{i}"], x, pos, cfg, mode, pc, cache_len
        )
        new_bc[f"pos{i}"] = npc
        aux_total = aux_total + aux
    return x, (new_bc if bc is not None else None), aux_total


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims


def _run_stack(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mode: str,
    cache: Optional[dict],
    cache_len: Optional[jax.Array],
    act_constrain=None,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Scan the stacked blocks, then unroll remainder layers.

    ``act_constrain`` (optional, launch-layer injected): sharding constraint
    applied to the residual-stream carry at block boundaries — with a
    sequence-parallel spec this shrinks the saved per-block carries (the
    dominant training-memory term) by the model-axis degree, at the cost of
    per-block gather traffic (Megatron-SP trade; see EXPERIMENTS.md §Perf).
    """

    aux0 = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {"rem": {}}
    if act_constrain is not None:
        x = act_constrain(x)

    if cfg.num_blocks:
        def body(carry, inputs):
            xc, aux = carry
            if cache is not None:
                bp, bc = inputs
            else:
                bp, bc = inputs, None
            xc, nbc, a = _apply_block(bp, xc, cfg, mode, bc, cache_len)
            if act_constrain is not None:
                xc = act_constrain(xc)
            return (xc, aux + a), nbc

        if mode == "train" and cfg.remat != "none":
            body = jax.checkpoint(body, policy=_remat_policy(cfg))

        xs = (
            (params["blocks"], cache["blocks"])
            if cache is not None
            else params["blocks"]
        )
        (x, aux0), scanned_cache = jax.lax.scan(body, (x, aux0), xs)
        if cache is not None:
            new_cache["blocks"] = scanned_cache

    for i in range(cfg.remainder_layers):
        pc = cache["rem"][f"layer{i}"] if cache is not None else None
        x, npc, a = _apply_layer(
            params["rem"][f"layer{i}"], x, cfg.block[i], cfg, mode, pc, cache_len
        )
        if cache is not None:
            new_cache["rem"][f"layer{i}"] = npc
        aux0 = aux0 + a

    return x, (new_cache if cache is not None else None), aux0


# ---------------------------------------------------------------------- #
# public entry points
# ---------------------------------------------------------------------- #

def forward(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    prefix_embeds: Optional[jax.Array] = None,
    act_constrain=None,
) -> Tuple[jax.Array, jax.Array]:
    """Train-mode forward.  Returns (logits (B,S,V), aux_loss).

    ``prefix_embeds`` (B,P,d) are prepended (VLM patch embeddings)."""

    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x, _, aux = _run_stack(
        params, x, cfg, "train", None, None, act_constrain=act_constrain
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), aux


def prefill(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    cache: dict,
    *,
    prefix_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """Fill the cache from a full prompt.  Returns (last-position logits, cache)."""

    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x, new_cache, _ = _run_stack(params, x, cfg, "prefill", cache, None)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), new_cache


def decode_step(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    cache: dict,
    cache_len: jax.Array,
) -> Tuple[jax.Array, dict]:
    """One decode step.  tokens (B,1); cache_len = tokens already cached."""

    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x, new_cache, _ = _run_stack(params, x, cfg, "decode", cache, cache_len)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), new_cache
