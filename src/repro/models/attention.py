"""Attention: GQA projections + chunked (flash-style) softmax attention.

Training/prefill run a streaming log-sum-exp over KV chunks — memory
O(S·chunk) instead of O(S²) — in pure jnp so the same code path lowers for
the CPU dry-run and for TPUs.  (The Pallas flash kernel in
``repro.kernels.flash_attention`` implements the same contract and is
validated against :func:`attention_reference`; the jnp path here is the
portable oracle.)

Decode attends one query step against the running KV cache; with the cache's
sequence dimension sharded (long-context decode), XLA's SPMD partitioner
turns the softmax statistics into the flash-decoding all-reduce pattern.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope

NEG_INF = -1e30


def attn_init(key: jax.Array, cfg: ModelConfig, cross: bool = False) -> dict:
    d, KV, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    H, Hp = cfg.num_heads, cfg.padded_num_heads
    ks = jax.random.split(key, 4)
    s = d**-0.5
    so = (H * hd) ** -0.5
    wq = jax.random.normal(ks[0], (d, Hp, hd), jnp.float32) * s
    wo = jax.random.normal(ks[3], (Hp, hd, d), jnp.float32) * so
    if Hp != H:
        # padded query heads: zero wo columns → exactly no contribution
        mask = (jnp.arange(Hp) < H).astype(jnp.float32)
        wo = wo * mask[:, None, None]
    return {
        "wq": wq.astype(cfg.dtype),
        "wk": (jax.random.normal(ks[1], (d, KV, hd), jnp.float32) * s).astype(cfg.dtype),
        "wv": (jax.random.normal(ks[2], (d, KV, hd), jnp.float32) * s).astype(cfg.dtype),
        "wo": wo.astype(cfg.dtype),
    }


def qkv_project(
    params: dict, x: jax.Array, kv_x: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
    return q, k, v


def out_project(params: dict, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """GQA: repeat KV heads to match query heads (B,S,KV,hd)→(B,S,H,hd)."""

    kv = k.shape[2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=2)


# ---------------------------------------------------------------------- #
# chunked flash-style attention (train / prefill)
# ---------------------------------------------------------------------- #

def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Streaming-softmax attention.

    q (B,Sq,H,hd); k,v (B,Sk,KV,hd).  ``window`` enables sliding-window
    masking (keys within [pos-window+1, pos]).  ``q_offset`` positions the
    query block inside the key space (prefill continuation).
    """

    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = hd**-0.5
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    kc = k.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        # the chunk index lives in the CARRY (not the scan xs) so the per-
        # chunk masks cannot be hoisted out of the loop and materialized as
        # a stacked (n_chunks, B, H, Sq, chunk) buffer by XLA's invariant
        # code motion — observed 0.5 GB/layer before this change.
        m, l, acc, idx = carry
        kb, vb = inputs
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhk,bchk->bhqc", q, kb).astype(jnp.float32) * scale
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < Sk)[None, :]  # padding
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchk->bhqk", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(
        body, (m0, l0, a0, jnp.zeros((), jnp.int32)), (kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,hd)


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Quadratic oracle (used by tests and the Pallas kernel's ref.py)."""

    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    s = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * hd**-0.5
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshk->bqhk", p.astype(v.dtype), v)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------- #
# decode attention against a KV cache
# ---------------------------------------------------------------------- #

def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-step attention: q (B,1,H,hd) vs cache (B,Smax,KV,hd).

    ``cache_len`` (scalar or (B,)) marks the filled prefix (the new token's
    KV must already be written at cache_len-1).  With the cache's S dim
    sharded across chips the softmax max/sum lower to the flash-decoding
    all-reduce pattern under SPMD.
    """

    B, _, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    # grouped-GQA contraction — no jnp.repeat KV→H expansion of the cache
    # (for a 32k cache the repeat materializes a 2-8× copy of the largest
    # tensor in the serving step)
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    s = s * hd**-0.5  # (B,KV,G,1,S)
    pos = jnp.arange(Smax)
    cache_len = jnp.asarray(cache_len)
    valid = pos[None, :] < cache_len.reshape(-1, 1)  # (B,Smax) or (1,Smax)
    if window is not None:
        valid &= pos[None, :] > (cache_len.reshape(-1, 1) - 1 - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def update_kv_cache(
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    start: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Write k_new/v_new (B,Sn,KV,hd) into the caches at position ``start``."""

    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, start, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, start, 0, 0)
    )
    return k_cache, v_cache


# ---------------------------------------------------------------------- #
# int8-quantized KV cache (beyond-paper: halves decode HBM traffic + fit)
# ---------------------------------------------------------------------- #

def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(B,S,KV,hd) → (int8 values, per-(token,head) f32 scales (B,S,KV,1))."""

    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), -1, keepdims=True), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def decode_attention_q(
    q: jax.Array,
    cache: dict,
    cache_len: jax.Array,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-step attention against the int8 cache WITHOUT materializing a
    dequantized copy: the per-(token,head) scales factor out of the head_dim
    contraction, so

        scores(b,h,s) = Σ_d q·k_q × k_s(b,s,h)      (scale applied to scores)
        out(b,h,d)    = Σ_s (p × v_s)(b,h,s) · v_q   (scale folded into probs)

    — algebraically exact w.r.t. dequantize-then-attend, with int8 reads all
    the way into the MXU (halved HBM traffic on the real target)."""

    B, _, H, hd = q.shape
    kq, ks = cache["k_q"], cache["k_s"]  # (B,S,KV,hd), (B,S,KV,1)
    vq, vs = cache["v_q"], cache["v_s"]
    Smax, KV = kq.shape[1], kq.shape[2]
    G = H // KV
    qg = q.astype(jnp.float32).reshape(B, 1, KV, G, hd)
    # grouped-GQA, no repeat; int8 operand converts lazily inside the dot
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kq.astype(jnp.float32))
    scale_k = ks[..., 0].transpose(0, 2, 1)[:, :, None, None, :]  # (B,KV,1,1,S)
    s = s * scale_k * hd**-0.5
    pos = jnp.arange(Smax)
    cache_len = jnp.asarray(cache_len)
    valid = pos[None, :] < cache_len.reshape(-1, 1)
    if window is not None:
        valid &= pos[None, :] > (cache_len.reshape(-1, 1) - 1 - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    scale_v = vs[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    p_scaled = p * scale_v
    o = jnp.einsum("bkgqs,bskd->bqkgd", p_scaled, vq.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def update_kv_cache_q(
    cache: dict, k_new: jax.Array, v_new: jax.Array, start: jax.Array
) -> dict:
    """Quantized-cache update: cache holds k_q/v_q int8 + k_s/v_s scales."""

    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    return {
        "k_q": jax.lax.dynamic_update_slice(cache["k_q"], kq, (0, start, 0, 0)),
        "k_s": jax.lax.dynamic_update_slice(
            cache["k_s"], ks.astype(cache["k_s"].dtype), (0, start, 0, 0)
        ),
        "v_q": jax.lax.dynamic_update_slice(cache["v_q"], vq, (0, start, 0, 0)),
        "v_s": jax.lax.dynamic_update_slice(
            cache["v_s"], vs.astype(cache["v_s"].dtype), (0, start, 0, 0)
        ),
    }
