"""Whisper-style encoder–decoder backbone.

The conv/mel audio frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, F, d_model).  The encoder is a
bidirectional transformer over frames; the decoder adds cross-attention to
the encoder output.  Cross-attention K/V are computed once at prefill and
cached (they never change during decode) — one of the dependences the
pipeline sync planner recognizes as coverable by the stage chain.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.layers import (
    embed,
    embed_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- #
# init
# ---------------------------------------------------------------------- #

def _enc_layer_init(key: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(cfg.d_model),
        "attn": attn_lib.attn_init(k1, cfg),
        "norm2": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype)),
    }


def _dec_layer_init(key: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": rmsnorm_init(cfg.d_model),
        "self_attn": attn_lib.attn_init(k1, cfg),
        "norm_x": rmsnorm_init(cfg.d_model),
        "cross_attn": attn_lib.attn_init(k2, cfg),
        "norm2": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype)),
    }


def init_encdec(key: jax.Array, cfg: ModelConfig) -> dict:
    assert cfg.encoder is not None
    ke, kd, kt, kn = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.encoder.num_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": embed_init(kt, cfg),
        "enc_blocks": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": rmsnorm_init(cfg.d_model),
    }


# ---------------------------------------------------------------------- #
# encoder
# ---------------------------------------------------------------------- #

def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames (B,F,d) — stubbed conv-frontend output.  Bidirectional stack."""

    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)[None]

    def body(xc, lp):
        h = rmsnorm(lp["norm1"], xc, cfg.norm_eps)
        q, k, v = attn_lib.qkv_project(lp["attn"], h)
        o = attn_lib.chunked_attention(
            q, k, v, causal=False, chunk=cfg.attn_chunk
        )
        xc = xc + attn_lib.out_project(lp["attn"], o)
        h = rmsnorm(lp["norm2"], xc, cfg.norm_eps)
        return xc + mlp(lp["mlp"], h), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------- #
# decoder
# ---------------------------------------------------------------------- #

def _dec_layer(
    lp: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mode: str,
    cache: Optional[dict],
    cache_len: Optional[jax.Array],
    enc_out: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[dict]]:
    # self attention
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    q, k, v = attn_lib.qkv_project(lp["self_attn"], h)
    positions = (
        cache_len.reshape(1) if mode == "decode" else jnp.arange(x.shape[1])
    )
    q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
    k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
    new_cache = dict(cache) if cache is not None else None
    if mode == "decode":
        kc, vc = attn_lib.update_kv_cache(cache["k"], cache["v"], k, v, cache_len)
        new_cache["k"], new_cache["v"] = kc, vc
        o = attn_lib.decode_attention(q, kc, vc, cache_len + 1)
    else:
        if cache is not None:  # prefill
            kc, vc = attn_lib.update_kv_cache(cache["k"], cache["v"], k, v, 0)
            new_cache["k"], new_cache["v"] = kc, vc
        o = attn_lib.chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    x = x + attn_lib.out_project(lp["self_attn"], o)

    # cross attention
    h = rmsnorm(lp["norm_x"], x, cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
    if mode == "decode":
        ck, cv = cache["ck"], cache["cv"]
    else:
        assert enc_out is not None
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
        if new_cache is not None:
            new_cache["ck"], new_cache["cv"] = (
                ck.astype(new_cache["ck"].dtype),
                cv.astype(new_cache["cv"].dtype),
            )
    o = attn_lib.chunked_attention(qx, ck, cv, causal=False, chunk=cfg.attn_chunk)
    x = x + attn_lib.out_project(lp["cross_attn"], o)

    # mlp
    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    x = x + mlp(lp["mlp"], h)
    return x, new_cache


def _run_decoder(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mode: str,
    cache: Optional[dict],
    cache_len: Optional[jax.Array],
    enc_out: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[dict]]:
    def body(carry, inputs):
        xc = carry
        if cache is not None:
            lp, lc = inputs
        else:
            lp, lc = inputs, None
        xc, nlc = _dec_layer(lp, xc, cfg, mode, lc, cache_len, enc_out)
        return xc, nlc

    if mode == "train" and cfg.remat != "none":
        body = jax.checkpoint(body)
    xs = (params["dec_blocks"], cache) if cache is not None else params["dec_blocks"]
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, (new_cache if cache is not None else None)


# ---------------------------------------------------------------------- #
# public API
# ---------------------------------------------------------------------- #

def forward(
    params: dict, frames: jax.Array, tokens: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Training forward: (logits (B,S,V), aux=0)."""

    enc_out = encode(params, frames, cfg)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x, _ = _run_decoder(params, x, cfg, "train", None, None, enc_out)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    assert cfg.encoder is not None
    L = cfg.num_layers
    kv = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    xkv = (batch, cfg.encoder.num_frames, cfg.num_kv_heads, cfg.head_dim)
    dt = jnp.dtype(cfg.dtype)
    one = {
        "k": jnp.zeros(kv, dt),
        "v": jnp.zeros(kv, dt),
        "ck": jnp.zeros(xkv, dt),
        "cv": jnp.zeros(xkv, dt),
    }
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one
    )


def prefill(
    params: dict,
    frames: jax.Array,
    tokens: jax.Array,
    cfg: ModelConfig,
    cache: dict,
) -> Tuple[jax.Array, dict]:
    enc_out = encode(params, frames, cfg)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x, new_cache = _run_decoder(params, x, cfg, "prefill", cache, None, enc_out)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), new_cache


def decode_step(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    cache: dict,
    cache_len: jax.Array,
) -> Tuple[jax.Array, dict]:
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x, new_cache = _run_decoder(params, x, cfg, "decode", cache, cache_len, None)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), new_cache
