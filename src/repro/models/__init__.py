"""Model substrate: layers, attention, MoE, Mamba2, decoder/enc-dec stacks."""
