"""Primitive layers: RMSNorm, rotary embeddings, token embedding, SwiGLU MLP.

Pure-functional: every layer is ``init(key, cfg) -> params`` plus
``apply(params, x, ...) -> y`` over plain dict pytrees.  All compute runs in
``cfg.dtype`` (bf16 by default) with fp32 accumulations where it matters
(norm statistics, softmax, losses)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------- #
# RMSNorm
# ---------------------------------------------------------------------- #

def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------- #
# Rotary position embeddings
# ---------------------------------------------------------------------- #

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim/2,)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""

    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (...,S,1,hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# Embedding / unembedding
# ---------------------------------------------------------------------- #

def embed_init(key: jax.Array, cfg: ModelConfig) -> dict:
    V = cfg.padded_vocab_size
    emb = jax.random.normal(key, (V, cfg.d_model), jnp.float32)
    params = {"tok": (emb * 0.02).astype(cdtype(cfg))}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        head = jax.random.normal(k2, (cfg.d_model, V), jnp.float32)
        params["head"] = (head * 0.02).astype(cdtype(cfg))
    return params


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits over the PADDED vocab; padded positions masked to -inf so they
    never win argmax and carry ~0 softmax mass."""

    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["tok"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["head"])
    V, Vp = cfg.vocab_size, cfg.padded_vocab_size
    if Vp != V:
        valid = jnp.arange(Vp) < V
        logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


# ---------------------------------------------------------------------- #
# SwiGLU MLP
# ---------------------------------------------------------------------- #

def mlp_init(key: jax.Array, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model**-0.5
    s_out = d_ff**-0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out).astype(dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("...f,fd->...d", act, params["w_down"])


# ---------------------------------------------------------------------- #
# losses
# ---------------------------------------------------------------------- #

def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean next-token loss in fp32.  logits (..., V), labels (...) int."""

    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
