"""Analytic FLOP/byte floors per (arch × shape) cell.

XLA:CPU's ``cost_analysis`` mis-scales loop trip counts on scanned programs
(measured both under- and over-counting vs hand calculation — see
EXPERIMENTS.md §Roofline), so the roofline table reports BOTH the HLO-derived
terms and these analytic floors.  The floors follow the standard conventions:

  * linear/projection FLOPs: 2·N_active per token (6·N with backward);
  * attention: 4·Sq·Sk_eff·H·hd per layer per sequence (QKᵀ + PV), with
    Sk_eff halved for causal masks and clamped to the sliding window;
  * SSD mixer: intra-chunk dual form + state path per token;
  * HBM bytes: per-chip resident parameter reads, KV-cache traffic (decode),
    microbatch activation I/O at the remat=full checkpoint boundaries, and
    optimizer state traffic (train).
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    ATTN,
    ATTN_LOCAL,
    MAMBA,
    MLP_MOE,
    ModelConfig,
    ShapeConfig,
)


def _attn_layer_flops_fwd(
    cfg: ModelConfig, S_q: int, S_k: int, causal: bool, window
) -> float:
    H, hd = cfg.padded_num_heads, cfg.head_dim
    if window is not None:
        sk_eff = min(window, S_k)
    elif causal and S_q == S_k:
        sk_eff = S_k / 2
    else:
        sk_eff = S_k
    return 4.0 * S_q * sk_eff * H * hd


def _ssd_layer_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    mc = cfg.mamba
    H = mc.num_heads(cfg.d_model)
    P, N, Q = mc.head_dim, mc.d_state, mc.chunk
    per_token_head = 2.0 * Q * (N + P) + 4.0 * N * P
    return per_token_head * H * tokens


def _layer_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Per-model counts of each mixer kind across all layers."""

    n_block = cfg.num_blocks
    counts = {ATTN: 0.0, ATTN_LOCAL: 0.0, MAMBA: 0.0}
    for i, pos in enumerate(cfg.block):
        reps = n_block + (1 if i < cfg.remainder_layers else 0)
        counts[pos.mixer] += reps
    return counts


def forward_flops(cfg: ModelConfig, shape: ShapeConfig, n_active: int) -> float:
    """Total forward FLOPs for one step of the cell (all chips)."""

    B, S = shape.global_batch, shape.seq_len
    counts = _layer_counts(cfg)

    if shape.kind == "decode":
        tokens = float(B)  # one new token per sequence
        lin = 2.0 * n_active * tokens
        attn = B * (
            counts[ATTN] * _attn_layer_flops_fwd(cfg, 1, S, False, None)
            + counts[ATTN_LOCAL]
            * _attn_layer_flops_fwd(cfg, 1, S, False, cfg.sliding_window)
        )
        ssd = counts[MAMBA] * _ssd_layer_flops_fwd(cfg, tokens) if cfg.has_mamba else 0.0
        extra = 0.0
        if cfg.family == "encdec":
            # cross-attention over cached encoder K/V
            extra = B * cfg.num_layers * _attn_layer_flops_fwd(
                cfg, 1, cfg.encoder.num_frames, False, None
            )
        return lin + attn + ssd + extra

    tokens = float(B) * S
    lin = 2.0 * n_active * tokens
    attn = B * (
        counts[ATTN] * _attn_layer_flops_fwd(cfg, S, S, True, None)
        + counts[ATTN_LOCAL]
        * _attn_layer_flops_fwd(cfg, S, S, True, cfg.sliding_window)
    )
    ssd = counts[MAMBA] * _ssd_layer_flops_fwd(cfg, tokens) if cfg.has_mamba else 0.0
    extra = 0.0
    if cfg.family == "encdec":
        F = cfg.encoder.num_frames
        # encoder self-attention (bidirectional) + decoder cross-attention
        extra = B * cfg.encoder.num_layers * _attn_layer_flops_fwd(
            cfg, F, F, False, None
        ) + B * cfg.num_layers * _attn_layer_flops_fwd(cfg, S, F, False, None)
    return lin + attn + ssd + extra


def step_flops(cfg: ModelConfig, shape: ShapeConfig, n_active: int) -> float:
    fwd = forward_flops(cfg, shape, n_active)
    if shape.kind != "train":
        return fwd
    # fwd + bwd(2x) + full-remat recompute (+1 fwd when remat='full')
    remat_extra = 1.0 if cfg.remat == "full" else 0.0
    return (3.0 + remat_extra) * fwd


# ---------------------------------------------------------------------- #
# bytes
# ---------------------------------------------------------------------- #

def _params_bytes_per_chip(cfg: ModelConfig, n_params: int, chips_model: int) -> float:
    return 2.0 * n_params / chips_model  # bf16, tensor-parallel resident


def _cache_bytes_total(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    counts = _layer_counts(cfg)
    # bf16 cache: 2 B/elem; int8-quantized: 1 B + f32 scale per head_dim group
    kv_bytes = (1.0 + 4.0 / cfg.head_dim) if cfg.kv_quant else 2.0
    kv = (counts[ATTN] + counts[ATTN_LOCAL]) * B * S * cfg.num_kv_heads * cfg.head_dim * kv_bytes * 2
    ssm = 0.0
    if cfg.has_mamba:
        mc = cfg.mamba
        ssm = counts[MAMBA] * B * mc.num_heads(cfg.d_model) * mc.head_dim * mc.d_state * 4
    if cfg.family == "encdec":
        kv += cfg.num_layers * B * cfg.encoder.num_frames * cfg.num_kv_heads * cfg.head_dim * 2 * 2
    return kv + ssm


def step_bytes_per_chip(
    cfg: ModelConfig,
    shape: ShapeConfig,
    n_params: int,
    n_active: int,
    chips: int,
    microbatches: int = 8,
) -> float:
    """Per-chip HBM traffic floor for one step."""

    model_shard = 16  # model axis size in both production meshes
    B, S = shape.global_batch, shape.seq_len
    p_chip = _params_bytes_per_chip(cfg, n_params, model_shard)

    if shape.kind == "decode":
        cache_chip = _cache_bytes_total(cfg, shape) / chips
        # all resident (active for MoE) weights + the full cache are read once
        active_chip = 2.0 * n_active / model_shard
        return active_chip + cache_chip

    act_io = B * S * cfg.d_model * 2.0 * cfg.num_layers * 4.0 / chips  # carry r/w
    if shape.kind == "prefill":
        return p_chip + act_io + _cache_bytes_total(cfg, shape) / chips
    # train: fwd+bwd weight reads, f32 grad write+read, ZeRO moments traffic
    grads = 4.0 * n_params / model_shard
    opt = 3.0 * 8.0 * n_params / chips  # mu+nu f32 read+write (ZeRO-1)
    return 2.0 * p_chip * microbatches + grads + opt + 3.0 * act_io


def analytic_record(
    cfg: ModelConfig,
    shape: ShapeConfig,
    n_params: int,
    n_active: int,
    chips: int,
    microbatches: int = 8,
) -> dict:
    flops = step_flops(cfg, shape, n_active)
    bytes_chip = step_bytes_per_chip(
        cfg, shape, n_params, n_active, chips, microbatches
    )
    return {
        "flops_total": flops,
        "flops_per_chip": flops / chips,
        "bytes_per_chip": bytes_chip,
    }
