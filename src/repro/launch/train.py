"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --steps 100 \
        --ckpt-dir /tmp/run1 [--smoke]

On real hardware this runs the full published config on the production mesh
(the launcher sets the latency-hiding-scheduler flags below); on this CPU
container ``--smoke`` (default when no accelerator is present) selects the
reduced config so the driver is actually runnable end-to-end — the full
configs are exercised by ``repro.launch.dryrun``.

Composes every substrate: deterministic data pipeline, AdamW + schedule,
microbatched sync-batched gradient accumulation, optional error-feedback
int8 gradient compression, async checkpointing, heartbeat/straggler/elastic
fault handling.
"""

from __future__ import annotations

import argparse

# Overlap-friendly XLA flags for real TPU deployments (harmless elsewhere):
# async collectives + latency-hiding scheduler are what let the roofline's
# max(compute, collective) model hold in practice.
TPU_PERF_FLAGS = (
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_data_parallel_all_reduce_opt=true "
    "--xla_latency_hiding_scheduler_rerun=2 "
)


def main() -> None:
    import jax

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import ARCHITECTURES, get_config, get_smoke_config
    from repro.data.pipeline import DataConfig
    from repro.optim.compression import Int8Compressor
    from repro.optim.optimizer import AdamW
    from repro.runtime.trainer import train_loop

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi_6b", choices=ARCHITECTURES)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--smoke", action="store_true", default=None,
                    help="reduced config (default on CPU)")
    args = ap.parse_args()

    on_accel = jax.default_backend() != "cpu"
    smoke = (not on_accel) if args.smoke is None else args.smoke
    cfg = get_smoke_config(args.arch) if smoke else get_config(args.arch)
    print(f"config: {cfg.name} (smoke={smoke}, backend={jax.default_backend()})")

    data_cfg = DataConfig(
        global_batch=args.global_batch, seq_len=args.seq, seed=args.seed
    )
    opt = AdamW(
        learning_rate=args.lr, warmup_steps=args.warmup, total_steps=args.steps
    )
    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None

    hook = None
    if args.compress_grads:
        comp = Int8Compressor()
        state = {"res": None}

        def hook(grads, opt_state):  # noqa: F811
            if state["res"] is None:
                state["res"] = comp.init(grads)
            out, state["res"] = comp.apply(grads, state["res"])
            return out, opt_state

    res = train_loop(
        cfg,
        data_cfg,
        total_steps=args.steps,
        ckpt=ckpt,
        ckpt_every=args.ckpt_every,
        opt=opt,
        microbatches=args.microbatches,
        seed=args.seed,
        grad_compressor=hook,
    )
    print(
        f"finished: step={res.final_step} restarts={res.restarts} "
        f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}"
    )
    if ckpt:
        ckpt.close()


if __name__ == "__main__":
    main()
