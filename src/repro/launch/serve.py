"""Production serving driver: continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        --requests 12 --max-new 16

A minimal continuous-batching scheduler over the framework's prefill/decode
steps: a fixed pool of decode slots; finished sequences (EOS or length
budget) are evicted and replaced by newly prefillable requests each
iteration, so the decode batch stays full — the serving pattern the
decode_32k/long_500k dry-run cells size.  Uses the int8 KV cache when
``--kv-quant`` is set.

This module is a thin demo *client* of the plan service: the wave workloads
and their caching live in :mod:`repro.serve` (per-tenant bounded plan LRUs
on the process-default :class:`~repro.serve.PlanService`, replacing the
unbounded ``functools.lru_cache`` memos that used to sit here).  Each batch
wave resolves its synchronization through the staged pipeline — ``plan()``
once per program *structure* (tenant plan LRU), then a fresh
``SyncPlan.compile("xla")`` per wave — resolved *concurrently* (planner
threads per wave, the way a real server overlaps scheduling work), all
riding the structural compile cache (:mod:`repro.compile`):

  * the acyclic decode chain (DECODE extends the KV cache with Δ=1, SAMPLE
    reads it at Δ=0),
  * a recurrence-bearing cross-slot rescoring scan whose mixed-sign carried
    dependence makes the plan a *hybrid* artifact — the scheduling-policy
    engine (:mod:`repro.core.policy`) picks a strategy per SCC through the
    xla backend's ``level_cost`` capability hook, and
  * the two non-affine wave workloads (inspector-routed histogram,
    speculative sparse rescore).

The dependence structures are identical from wave to wave, so every wave
after the first is a plan-LRU hit AND a structural-cache hit for every
compile — the serving loop never re-analyzes or re-lowers; with the
shape-bucketed traced artifacts it never re-*traces* either.  The hit/miss
counters are printed with the throughput summary.
"""

from __future__ import annotations

import argparse
import concurrent.futures
import dataclasses
import time
from typing import List

# the wave workloads' public home is repro.serve; re-exported here so the
# demo client's historical surface (serve.plan_wave etc.) keeps working
from repro.serve import (  # noqa: F401  (re-exported helper surface)
    default_service,
    plan_rescore_sync,
    plan_route_sync,
    plan_scan_sync,
    plan_wave,
    plan_wave_sync,
    run_nonaffine_wave,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: "object"
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.obs import metrics
    from repro.core import inspector_cache_stats
    from repro.configs import ARCHITECTURES, get_smoke_config
    from repro.launch.steps import make_prefill_step, make_serve_step
    from repro.models import model_zoo as zoo

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi_6b", choices=ARCHITECTURES)
    ap.add_argument("--slots", type=int, default=4, help="decode batch size")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.kv_quant:
        cfg = cfg.scaled(kv_quant=True)
    key = jax.random.PRNGKey(0)
    params = zoo.init(key, cfg)
    npfx = cfg.num_patches if cfg.frontend == "vision" else 0
    max_len = npfx + args.prompt_len + args.max_new

    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))

    # request queue (synthetic prompts)
    queue = [
        Request(
            rid=i,
            prompt=jax.random.randint(
                jax.random.fold_in(key, i), (args.prompt_len,), 0, cfg.vocab_size
            ),
        )
        for i in range(args.requests)
    ]
    done: List[Request] = []

    # one cache per slot (slot-batched prefill keeps the demo simple; a real
    # server prefills in a second batch dimension and swaps pages)
    B = args.slots
    t0 = time.perf_counter()
    decoded_tokens = 0
    waves = 0
    sync_plan = scan_plan = None
    route_exe = rescore_exe = None
    with concurrent.futures.ThreadPoolExecutor(
        max_workers=2, thread_name_prefix="sync-planner"
    ) as planner:
        while queue:
            active = queue[:B]
            queue = queue[B:]
            # re-plan this wave's sync concurrently (acyclic decode chain +
            # the recurrence-bearing rescoring scan): structural-cache hits
            # on every wave after the first (same dependence structures)
            sync_plan, scan_plan, route_exe, rescore_exe = plan_wave(
                args.max_new, B, pool=planner
            )
            waves += 1
            t_run = time.perf_counter()
            while len(active) < B:  # pad the batch with a dummy copy
                active.append(
                    Request(rid=-1, prompt=active[0].prompt, done=True)
                )
            batch = {"tokens": jnp.stack([r.prompt for r in active])}
            if cfg.family == "encdec":
                batch["frame_embeds"] = jax.random.normal(
                    key, (B, cfg.encoder.num_frames, cfg.d_model)
                )
            if cfg.frontend == "vision":
                batch["patch_embeds"] = 0.1 * jax.random.normal(
                    key, (B, cfg.num_patches, cfg.d_model)
                )
            cache = zoo.init_cache(cfg, B, max_len)
            logits, cache = prefill(params, batch, cache)
            cur = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
            cache_len = npfx + args.prompt_len
            for r, t in zip(active, cur[:, 0].tolist()):
                if r.rid >= 0:
                    r.generated.append(int(t))
            for _ in range(args.max_new - 1):
                cur, cache = serve(params, cur, cache, jnp.int32(cache_len))
                cache_len += 1
                for r, t in zip(active, cur[:, 0].tolist()):
                    if r.rid >= 0 and not r.done:
                        r.generated.append(int(t))
                        decoded_tokens += 1
            # non-affine wave workloads: route this wave's sampled tokens
            # through the inspector-planned histogram and the speculative
            # sparse rescore, index contents = actual runtime values
            run_nonaffine_wave(
                route_exe, rescore_exe, cur[:, 0].tolist(), bins=B
            )
            metrics.histogram("serve.run_ms").observe(
                (time.perf_counter() - t_run) * 1e3
            )
            done.extend(r for r in active if r.rid >= 0)

    dt = time.perf_counter() - t0
    print(
        f"served {len(done)} requests, {decoded_tokens} decode tokens in "
        f"{dt:.2f}s ({decoded_tokens/max(dt,1e-9):.0f} tok/s batched, "
        f"kv_quant={cfg.kv_quant})"
    )
    # per-wave latency distributions (repro.obs histograms) instead of a
    # lone end-to-end total: plan/compile are per planner call (4 per
    # wave), run is the wave's decode + non-affine execution
    def _pct(name: str) -> str:
        h = metrics.histogram(name)
        p50, p99 = h.percentile(50), h.percentile(99)
        if p50 is None:
            return f"{name.split('.')[-1]}: n=0"
        return (
            f"{name.split('.')[-1]}: n={h.count} "
            f"p50={p50:.2f}ms p99={p99:.2f}ms"
        )

    rollbacks = metrics.counter("speculation.rollbacks").value
    reinspections = inspector_cache_stats()["misses"]
    print(
        f"per-wave latency ({waves} waves): {_pct('serve.plan_ms')} | "
        f"{_pct('serve.compile_ms')} | {_pct('serve.run_ms')}"
    )
    print(
        f"speculation rollbacks: {rollbacks}, inspector re-inspections "
        f"(memo misses): {reinspections}"
    )
    if sync_plan is not None and sync_plan.compiled is not None:
        cc = sync_plan.compiled.cache_stats()
        print(
            f"decode sync plan: {waves} waves -> compile cache "
            f"{cc.get('hits', 0)} hits / {cc.get('misses', 0)} misses "
            f"(key {sync_plan.compiled.key[:12]}, retained="
            f"{[d.pretty() for d in sync_plan.elimination.retained]})"
        )
    if scan_plan is not None and scan_plan.compiled is not None:
        (rec,) = scan_plan.summary()["scc"]["recurrences"]
        print(
            f"cyclic scan plan: {waves} waves -> hybrid artifact "
            f"(key {scan_plan.compiled.key[:12]}, strategy={rec['strategy']}, "
            f"statements={rec['statements']})"
        )
    if route_exe is not None and rescore_exe is not None:
        print(
            f"non-affine wave workloads: routing histogram "
            f"(deps='inspect', key {route_exe.compiled.key[:12]}) + sparse "
            f"rescore (deps='speculate', key {rescore_exe.compiled.key[:12]})"
            f", inspector memo {inspector_cache_stats()}"
        )
    print("sample:", done[0].rid, done[0].generated[:10])


if __name__ == "__main__":
    main()
