"""Production serving driver: continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        --requests 12 --max-new 16

A minimal continuous-batching scheduler over the framework's prefill/decode
steps: a fixed pool of decode slots; finished sequences (EOS or length
budget) are evicted and replaced by newly prefillable requests each
iteration, so the decode batch stays full — the serving pattern the
decode_32k/long_500k dry-run cells size.  Uses the int8 KV cache when
``--kv-quant`` is set.

Each batch wave resolves its synchronization through the staged pipeline —
``plan()`` once per program *structure* (memoized below), then a fresh
``SyncPlan.compile("xla")`` per wave — two compiles, resolved *concurrently*
(two planner threads per wave, the way a real server overlaps scheduling
work), both riding the structural compile cache (:mod:`repro.compile`):

  * the acyclic decode chain (DECODE extends the KV cache with Δ=1, SAMPLE
    reads it at Δ=0), and
  * a recurrence-bearing cross-slot rescoring scan whose mixed-sign carried
    dependence makes the plan a *hybrid* artifact — the scheduling-policy
    engine (:mod:`repro.core.policy`) picks a strategy per SCC through the
    xla backend's ``level_cost`` capability hook (the NumPy interpreter
    would skew this scan; the compiled level loop's near-flat narrow-step
    cost can resolve it differently), so the serving path exercises hybrid
    artifacts under concurrent re-planning, not just DOALL waves.

The dependence structures are identical from wave to wave, so every wave
after the first is a plan-memo hit AND a structural-cache hit for both
compiles — the serving loop never re-analyzes or re-lowers.  The hit/miss
counters are printed with the throughput summary.
"""

from __future__ import annotations

import argparse
import concurrent.futures
import dataclasses
import functools
import time
from typing import List, Optional


@dataclasses.dataclass
class Request:
    rid: int
    prompt: "object"
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@functools.lru_cache(maxsize=16)
def _decode_plan(max_new: int):
    """The decode chain's backend-independent SyncPlan, analyzed once.

    The per-slot decode chain is the paper's loop in miniature: DECODE
    extends the KV cache from the previous step's cache (flow, Δ=1), SAMPLE
    reads the fresh cache (flow, Δ=0).  The structure is independent of
    which requests occupy the slots, so the plan (and below it, the
    compiled artifact — bounds are not part of the structural key) is
    shared by every wave at this ``max_new``.
    """

    from repro.core import ArrayRef, LoopProgram, Statement, plan

    prog = LoopProgram(
        statements=(
            Statement("DECODE", ArrayRef("kv", 0), (ArrayRef("kv", -1),)),
            Statement("SAMPLE", ArrayRef("tok", 0), (ArrayRef("kv", 0),)),
        ),
        bounds=((1, max(2, max_new)),),
    )
    return plan(prog, method="isd")


@functools.lru_cache(maxsize=16)
def _scan_plan(slots: int, horizon: int):
    """The cross-slot rescoring scan's SyncPlan — a *cyclic* wave shape.

    RESCORE folds each slot's running score with the previous step's score
    of the same slot (reads ``score[s, t-1]``: flow, Δ=(0,1)) and borrows
    the neighboring slot's one-step-newer score (reads ``score[s-1, t+1]``:
    flow, Δ=(1,-1)) — a mixed-sign recurrence SCC, the request shape the
    acyclic decode plan never produces.  EMIT reads the settled score
    (DOALL, pipelined against the scan).  The (0,1) carried dependence pins
    DOACROSS chunks to 1, and the per-backend cost model decides between
    the unimodular skew and unit chunks at compile time — either way a
    *hybrid* artifact served from the structural cache wave after wave.
    """

    from repro.core import ArrayRef, LoopProgram, Statement, plan

    prog = LoopProgram(
        statements=(
            Statement(
                "RESCORE",
                ArrayRef("score", (0, 0)),
                (ArrayRef("score", (0, -1)), ArrayRef("score", (-1, 1))),
            ),
            Statement(
                "EMIT", ArrayRef("beam", (0, 0)), (ArrayRef("score", (0, 0)),)
            ),
        ),
        bounds=((0, max(2, slots)), (0, max(2, horizon))),
    )
    return plan(prog, method="isd")


@functools.lru_cache(maxsize=16)
def _route_plan(tokens: int):
    """Expert-routing histogram — the serving loop's *non-affine* shape.

    Each decoded token scatters into its expert's bin: ``h[bin[i]] += w[i]``
    with ``bin`` only known at runtime (it is this wave's sampled tokens).
    Planned under ``deps="inspect"``: the static analyzer can only emit the
    serializing proxy chain, the inspector resolves the actual conflicts per
    wave.  One structural artifact serves every wave (the deps mode is part
    of the structural key); each distinct routing pattern adds one
    content-keyed per-bounds table entry beside it.
    """

    from repro.core import PlanOptions, histogram, plan

    return plan(histogram(max(2, tokens)), PlanOptions(deps="inspect"))


@functools.lru_cache(maxsize=16)
def _rescore_plan(tokens: int):
    """Sparse-matvec rescore ``y[row[k]] += v[k]*x[col[k]]`` under
    ``deps="speculate"``: waves whose rows happen to be conflict-free keep
    the optimistic doall result; a conflicting wave validates against the
    inspector graph, rolls back, and re-runs conservatively."""

    from repro.core import PlanOptions, plan, sparse_matvec

    return plan(sparse_matvec(max(2, tokens)), PlanOptions(deps="speculate"))


def _timed(hist_name: str, fn, *args):
    """Run ``fn`` and record its latency (ms) in the named obs histogram."""

    from repro.obs import metrics

    t0 = time.perf_counter()
    out = fn(*args)
    metrics.histogram(hist_name).observe((time.perf_counter() - t0) * 1e3)
    return out


def plan_wave_sync(max_new: int):
    """One wave's decode-chain report: plan memo + structural compile cache."""

    p = _timed("serve.plan_ms", _decode_plan, max_new)
    return _timed("serve.compile_ms", p.compile, "xla").report()


def plan_scan_sync(slots: int, horizon: int):
    """One wave's rescoring-scan report (hybrid artifact, see _scan_plan)."""

    p = _timed("serve.plan_ms", _scan_plan, slots, horizon)
    return _timed("serve.compile_ms", p.compile, "xla").report()


def plan_route_sync(tokens: int):
    """One wave's routing-histogram Executable (non-affine, deps="inspect")."""

    p = _timed("serve.plan_ms", _route_plan, tokens)
    return _timed("serve.compile_ms", p.compile, "xla")


def plan_rescore_sync(tokens: int):
    """One wave's sparse-rescore Executable (non-affine, deps="speculate")."""

    p = _timed("serve.plan_ms", _rescore_plan, tokens)
    return _timed("serve.compile_ms", p.compile, "xla")


def run_nonaffine_wave(route_exe, rescore_exe, sampled: List[int], bins: int):
    """Execute the wave's non-affine workloads with this wave's runtime
    index contents; returns (route store, rescore store) after asserting
    both bit-equal the sequential oracle."""

    from repro.core import indexed_store, run_sequential

    route_prog = route_exe.plan.program
    (lo, hi), = route_prog.bounds
    n = hi - lo
    pattern = [sampled[k % len(sampled)] % bins for k in range(n)]
    store = indexed_store(route_prog, {"bin": pattern})
    init = {a: dict(c) for a, c in store.items()}
    routed = route_exe.run(store=init)
    assert routed == run_sequential(route_prog, init)

    rescore_prog = rescore_exe.plan.program
    (lo, hi), = rescore_prog.bounds
    n = hi - lo
    rows = [sampled[k % len(sampled)] % max(2, n // 2) for k in range(n)]
    store = indexed_store(
        rescore_prog, {"row": rows, "col": list(range(n))}
    )
    init = {a: dict(c) for a, c in store.items()}
    rescored = rescore_exe.run(store=init)
    assert rescored == run_sequential(rescore_prog, init)
    return routed, rescored


def plan_wave(
    max_new: int,
    slots: int,
    pool: Optional[concurrent.futures.ThreadPoolExecutor] = None,
):
    """Resolve both wave plans concurrently (decode chain + rescoring scan).

    Two planner threads race through ``SyncPlan.compile("xla")`` into the
    structural compile cache — the concurrency the cache's locking
    discipline is built for, now exercised by a cyclic workload on every
    serving wave.  Pass a long-lived ``pool`` from the serving loop: warm
    waves plan in sub-millisecond cache hits, which per-wave executor setup
    would dwarf.
    """

    if pool is None:
        with concurrent.futures.ThreadPoolExecutor(max_workers=2) as own:
            return plan_wave(max_new, slots, pool=own)
    f_decode = pool.submit(plan_wave_sync, max_new)
    f_scan = pool.submit(plan_scan_sync, slots, max_new)
    f_route = pool.submit(plan_route_sync, 2 * slots)
    f_rescore = pool.submit(plan_rescore_sync, 2 * slots)
    return (
        f_decode.result(),
        f_scan.result(),
        f_route.result(),
        f_rescore.result(),
    )


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.obs import metrics
    from repro.core import inspector_cache_stats
    from repro.configs import ARCHITECTURES, get_smoke_config
    from repro.launch.steps import make_prefill_step, make_serve_step
    from repro.models import model_zoo as zoo

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi_6b", choices=ARCHITECTURES)
    ap.add_argument("--slots", type=int, default=4, help="decode batch size")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.kv_quant:
        cfg = cfg.scaled(kv_quant=True)
    key = jax.random.PRNGKey(0)
    params = zoo.init(key, cfg)
    npfx = cfg.num_patches if cfg.frontend == "vision" else 0
    max_len = npfx + args.prompt_len + args.max_new

    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))

    # request queue (synthetic prompts)
    queue = [
        Request(
            rid=i,
            prompt=jax.random.randint(
                jax.random.fold_in(key, i), (args.prompt_len,), 0, cfg.vocab_size
            ),
        )
        for i in range(args.requests)
    ]
    done: List[Request] = []

    # one cache per slot (slot-batched prefill keeps the demo simple; a real
    # server prefills in a second batch dimension and swaps pages)
    B = args.slots
    t0 = time.perf_counter()
    decoded_tokens = 0
    waves = 0
    sync_plan = scan_plan = None
    route_exe = rescore_exe = None
    with concurrent.futures.ThreadPoolExecutor(
        max_workers=2, thread_name_prefix="sync-planner"
    ) as planner:
        while queue:
            active = queue[:B]
            queue = queue[B:]
            # re-plan this wave's sync concurrently (acyclic decode chain +
            # the recurrence-bearing rescoring scan): structural-cache hits
            # on every wave after the first (same dependence structures)
            sync_plan, scan_plan, route_exe, rescore_exe = plan_wave(
                args.max_new, B, pool=planner
            )
            waves += 1
            t_run = time.perf_counter()
            while len(active) < B:  # pad the batch with a dummy copy
                active.append(
                    Request(rid=-1, prompt=active[0].prompt, done=True)
                )
            batch = {"tokens": jnp.stack([r.prompt for r in active])}
            if cfg.family == "encdec":
                batch["frame_embeds"] = jax.random.normal(
                    key, (B, cfg.encoder.num_frames, cfg.d_model)
                )
            if cfg.frontend == "vision":
                batch["patch_embeds"] = 0.1 * jax.random.normal(
                    key, (B, cfg.num_patches, cfg.d_model)
                )
            cache = zoo.init_cache(cfg, B, max_len)
            logits, cache = prefill(params, batch, cache)
            cur = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
            cache_len = npfx + args.prompt_len
            for r, t in zip(active, cur[:, 0].tolist()):
                if r.rid >= 0:
                    r.generated.append(int(t))
            for _ in range(args.max_new - 1):
                cur, cache = serve(params, cur, cache, jnp.int32(cache_len))
                cache_len += 1
                for r, t in zip(active, cur[:, 0].tolist()):
                    if r.rid >= 0 and not r.done:
                        r.generated.append(int(t))
                        decoded_tokens += 1
            # non-affine wave workloads: route this wave's sampled tokens
            # through the inspector-planned histogram and the speculative
            # sparse rescore, index contents = actual runtime values
            run_nonaffine_wave(
                route_exe, rescore_exe, cur[:, 0].tolist(), bins=B
            )
            metrics.histogram("serve.run_ms").observe(
                (time.perf_counter() - t_run) * 1e3
            )
            done.extend(r for r in active if r.rid >= 0)

    dt = time.perf_counter() - t0
    print(
        f"served {len(done)} requests, {decoded_tokens} decode tokens in "
        f"{dt:.2f}s ({decoded_tokens/max(dt,1e-9):.0f} tok/s batched, "
        f"kv_quant={cfg.kv_quant})"
    )
    # per-wave latency distributions (repro.obs histograms) instead of a
    # lone end-to-end total: plan/compile are per planner call (4 per
    # wave), run is the wave's decode + non-affine execution
    def _pct(name: str) -> str:
        h = metrics.histogram(name)
        p50, p99 = h.percentile(50), h.percentile(99)
        if p50 is None:
            return f"{name.split('.')[-1]}: n=0"
        return (
            f"{name.split('.')[-1]}: n={h.count} "
            f"p50={p50:.2f}ms p99={p99:.2f}ms"
        )

    rollbacks = metrics.counter("speculation.rollbacks").value
    reinspections = inspector_cache_stats()["misses"]
    print(
        f"per-wave latency ({waves} waves): {_pct('serve.plan_ms')} | "
        f"{_pct('serve.compile_ms')} | {_pct('serve.run_ms')}"
    )
    print(
        f"speculation rollbacks: {rollbacks}, inspector re-inspections "
        f"(memo misses): {reinspections}"
    )
    if sync_plan is not None and sync_plan.compiled is not None:
        cc = sync_plan.compiled.cache_stats()
        print(
            f"decode sync plan: {waves} waves -> compile cache "
            f"{cc.get('hits', 0)} hits / {cc.get('misses', 0)} misses "
            f"(key {sync_plan.compiled.key[:12]}, retained="
            f"{[d.pretty() for d in sync_plan.elimination.retained]})"
        )
    if scan_plan is not None and scan_plan.compiled is not None:
        (rec,) = scan_plan.summary()["scc"]["recurrences"]
        print(
            f"cyclic scan plan: {waves} waves -> hybrid artifact "
            f"(key {scan_plan.compiled.key[:12]}, strategy={rec['strategy']}, "
            f"statements={rec['statements']})"
        )
    if route_exe is not None and rescore_exe is not None:
        print(
            f"non-affine wave workloads: routing histogram "
            f"(deps='inspect', key {route_exe.compiled.key[:12]}) + sparse "
            f"rescore (deps='speculate', key {rescore_exe.compiled.key[:12]})"
            f", inspector memo {inspector_cache_stats()}"
        )
    print("sample:", done[0].rid, done[0].generated[:10])


if __name__ == "__main__":
    main()
