"""Production serving driver: continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        --requests 12 --max-new 16

A minimal continuous-batching scheduler over the framework's prefill/decode
steps: a fixed pool of decode slots; finished sequences (EOS or length
budget) are evicted and replaced by newly prefillable requests each
iteration, so the decode batch stays full — the serving pattern the
decode_32k/long_500k dry-run cells size.  Uses the int8 KV cache when
``--kv-quant`` is set.

Each batch wave resolves its synchronization through the staged pipeline —
``plan()`` once per program *structure* (memoized below), then a fresh
``SyncPlan.compile("xla")`` per wave — two compiles, resolved *concurrently*
(two planner threads per wave, the way a real server overlaps scheduling
work), both riding the structural compile cache (:mod:`repro.compile`):

  * the acyclic decode chain (DECODE extends the KV cache with Δ=1, SAMPLE
    reads it at Δ=0), and
  * a recurrence-bearing cross-slot rescoring scan whose mixed-sign carried
    dependence makes the plan a *hybrid* artifact — the scheduling-policy
    engine (:mod:`repro.core.policy`) picks a strategy per SCC through the
    xla backend's ``level_cost`` capability hook (the NumPy interpreter
    would skew this scan; the compiled level loop's near-flat narrow-step
    cost can resolve it differently), so the serving path exercises hybrid
    artifacts under concurrent re-planning, not just DOALL waves.

The dependence structures are identical from wave to wave, so every wave
after the first is a plan-memo hit AND a structural-cache hit for both
compiles — the serving loop never re-analyzes or re-lowers.  The hit/miss
counters are printed with the throughput summary.
"""

from __future__ import annotations

import argparse
import concurrent.futures
import dataclasses
import functools
import time
from typing import List, Optional


@dataclasses.dataclass
class Request:
    rid: int
    prompt: "object"
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@functools.lru_cache(maxsize=16)
def _decode_plan(max_new: int):
    """The decode chain's backend-independent SyncPlan, analyzed once.

    The per-slot decode chain is the paper's loop in miniature: DECODE
    extends the KV cache from the previous step's cache (flow, Δ=1), SAMPLE
    reads the fresh cache (flow, Δ=0).  The structure is independent of
    which requests occupy the slots, so the plan (and below it, the
    compiled artifact — bounds are not part of the structural key) is
    shared by every wave at this ``max_new``.
    """

    from repro.core import ArrayRef, LoopProgram, Statement, plan

    prog = LoopProgram(
        statements=(
            Statement("DECODE", ArrayRef("kv", 0), (ArrayRef("kv", -1),)),
            Statement("SAMPLE", ArrayRef("tok", 0), (ArrayRef("kv", 0),)),
        ),
        bounds=((1, max(2, max_new)),),
    )
    return plan(prog, method="isd")


@functools.lru_cache(maxsize=16)
def _scan_plan(slots: int, horizon: int):
    """The cross-slot rescoring scan's SyncPlan — a *cyclic* wave shape.

    RESCORE folds each slot's running score with the previous step's score
    of the same slot (reads ``score[s, t-1]``: flow, Δ=(0,1)) and borrows
    the neighboring slot's one-step-newer score (reads ``score[s-1, t+1]``:
    flow, Δ=(1,-1)) — a mixed-sign recurrence SCC, the request shape the
    acyclic decode plan never produces.  EMIT reads the settled score
    (DOALL, pipelined against the scan).  The (0,1) carried dependence pins
    DOACROSS chunks to 1, and the per-backend cost model decides between
    the unimodular skew and unit chunks at compile time — either way a
    *hybrid* artifact served from the structural cache wave after wave.
    """

    from repro.core import ArrayRef, LoopProgram, Statement, plan

    prog = LoopProgram(
        statements=(
            Statement(
                "RESCORE",
                ArrayRef("score", (0, 0)),
                (ArrayRef("score", (0, -1)), ArrayRef("score", (-1, 1))),
            ),
            Statement(
                "EMIT", ArrayRef("beam", (0, 0)), (ArrayRef("score", (0, 0)),)
            ),
        ),
        bounds=((0, max(2, slots)), (0, max(2, horizon))),
    )
    return plan(prog, method="isd")


def plan_wave_sync(max_new: int):
    """One wave's decode-chain report: plan memo + structural compile cache."""

    return _decode_plan(max_new).compile("xla").report()


def plan_scan_sync(slots: int, horizon: int):
    """One wave's rescoring-scan report (hybrid artifact, see _scan_plan)."""

    return _scan_plan(slots, horizon).compile("xla").report()


def plan_wave(
    max_new: int,
    slots: int,
    pool: Optional[concurrent.futures.ThreadPoolExecutor] = None,
):
    """Resolve both wave plans concurrently (decode chain + rescoring scan).

    Two planner threads race through ``SyncPlan.compile("xla")`` into the
    structural compile cache — the concurrency the cache's locking
    discipline is built for, now exercised by a cyclic workload on every
    serving wave.  Pass a long-lived ``pool`` from the serving loop: warm
    waves plan in sub-millisecond cache hits, which per-wave executor setup
    would dwarf.
    """

    if pool is None:
        with concurrent.futures.ThreadPoolExecutor(max_workers=2) as own:
            return plan_wave(max_new, slots, pool=own)
    f_decode = pool.submit(plan_wave_sync, max_new)
    f_scan = pool.submit(plan_scan_sync, slots, max_new)
    return f_decode.result(), f_scan.result()


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHITECTURES, get_smoke_config
    from repro.launch.steps import make_prefill_step, make_serve_step
    from repro.models import model_zoo as zoo

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi_6b", choices=ARCHITECTURES)
    ap.add_argument("--slots", type=int, default=4, help="decode batch size")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.kv_quant:
        cfg = cfg.scaled(kv_quant=True)
    key = jax.random.PRNGKey(0)
    params = zoo.init(key, cfg)
    npfx = cfg.num_patches if cfg.frontend == "vision" else 0
    max_len = npfx + args.prompt_len + args.max_new

    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))

    # request queue (synthetic prompts)
    queue = [
        Request(
            rid=i,
            prompt=jax.random.randint(
                jax.random.fold_in(key, i), (args.prompt_len,), 0, cfg.vocab_size
            ),
        )
        for i in range(args.requests)
    ]
    done: List[Request] = []

    # one cache per slot (slot-batched prefill keeps the demo simple; a real
    # server prefills in a second batch dimension and swaps pages)
    B = args.slots
    t0 = time.perf_counter()
    decoded_tokens = 0
    waves = 0
    sync_plan = scan_plan = None
    with concurrent.futures.ThreadPoolExecutor(
        max_workers=2, thread_name_prefix="sync-planner"
    ) as planner:
        while queue:
            active = queue[:B]
            queue = queue[B:]
            # re-plan this wave's sync concurrently (acyclic decode chain +
            # the recurrence-bearing rescoring scan): structural-cache hits
            # on every wave after the first (same dependence structures)
            sync_plan, scan_plan = plan_wave(args.max_new, B, pool=planner)
            waves += 1
            while len(active) < B:  # pad the batch with a dummy copy
                active.append(
                    Request(rid=-1, prompt=active[0].prompt, done=True)
                )
            batch = {"tokens": jnp.stack([r.prompt for r in active])}
            if cfg.family == "encdec":
                batch["frame_embeds"] = jax.random.normal(
                    key, (B, cfg.encoder.num_frames, cfg.d_model)
                )
            if cfg.frontend == "vision":
                batch["patch_embeds"] = 0.1 * jax.random.normal(
                    key, (B, cfg.num_patches, cfg.d_model)
                )
            cache = zoo.init_cache(cfg, B, max_len)
            logits, cache = prefill(params, batch, cache)
            cur = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
            cache_len = npfx + args.prompt_len
            for r, t in zip(active, cur[:, 0].tolist()):
                if r.rid >= 0:
                    r.generated.append(int(t))
            for _ in range(args.max_new - 1):
                cur, cache = serve(params, cur, cache, jnp.int32(cache_len))
                cache_len += 1
                for r, t in zip(active, cur[:, 0].tolist()):
                    if r.rid >= 0 and not r.done:
                        r.generated.append(int(t))
                        decoded_tokens += 1
            done.extend(r for r in active if r.rid >= 0)

    dt = time.perf_counter() - t0
    print(
        f"served {len(done)} requests, {decoded_tokens} decode tokens in "
        f"{dt:.2f}s ({decoded_tokens/max(dt,1e-9):.0f} tok/s batched, "
        f"kv_quant={cfg.kv_quant})"
    )
    if sync_plan is not None and sync_plan.compiled is not None:
        cc = sync_plan.compiled.cache_stats()
        print(
            f"decode sync plan: {waves} waves -> compile cache "
            f"{cc.get('hits', 0)} hits / {cc.get('misses', 0)} misses "
            f"(key {sync_plan.compiled.key[:12]}, retained="
            f"{[d.pretty() for d in sync_plan.elimination.retained]})"
        )
    if scan_plan is not None and scan_plan.compiled is not None:
        (rec,) = scan_plan.summary()["scc"]["recurrences"]
        print(
            f"cyclic scan plan: {waves} waves -> hybrid artifact "
            f"(key {scan_plan.compiled.key[:12]}, strategy={rec['strategy']}, "
            f"statements={rec['statements']})"
        )
    print("sample:", done[0].rid, done[0].generated[:10])


if __name__ == "__main__":
    main()
