"""jit-able train / prefill / decode steps.

``make_train_step`` closes over (config, optimizer) and returns the function
to ``jax.jit`` with shardings; gradient all-reduce over the data axes falls
out of SPMD (batch sharded, params replicated along data).  Optional
microbatch gradient accumulation runs a ``lax.scan`` over microbatches —
with a SINGLE optimizer update at the end, i.e. one gradient synchronization
for k microbatch dependences: the paper's send/wait-merging optimization
lifted to data parallelism (see DESIGN.md §4).

``make_serve_step`` returns the one-token decode step (the thing lowered for
the decode_* and long_* dry-run cells) and ``make_prefill_step`` the prompt
ingestion step.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model_zoo as zoo
from repro.optim.optimizer import AdamW, AdamWState, global_norm


def make_train_step(
    cfg: ModelConfig,
    opt: AdamW,
    *,
    microbatches: int = 1,
    grad_compressor=None,
    mesh=None,
    seq_shard: bool = False,
    grad_shardings=None,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``mesh``: when given, microbatch slices are sharding-constrained to keep
    the batch dim on the data axes — without this, XLA's propagation through
    the microbatch reshape is free to pick a pathological layout (observed:
    batch/2 × d_model/8 on a 16-way axis, 6× the activation footprint).

    ``seq_shard``: Megatron-style sequence parallelism on the residual
    stream at block boundaries — shrinks the saved scan carries by the
    model-axis degree for per-block gather traffic.

    ``grad_shardings``: ZeRO-2-style NamedSharding tree for the f32 gradient
    accumulator (params spec + 'data').  The accumulated mean gradient is
    data-replicated in value, so constraining it to a data-sharded layout is
    exact and costs one all-gather of the updated params per step — it
    removes the f32 full-gradient residency (6.75 GB/chip for a 27B model).
    """

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import data_axes

    def constrain_mb(x):
        if mesh is None:
            return x
        dp = data_axes(mesh)
        bdim = x.shape[1]
        n = 1
        for a in dp:
            n *= mesh.shape[a]
        spec = P(None, dp if bdim % n == 0 else None, *(None,) * (x.ndim - 2))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def act_constrain(x):
        if mesh is None or not seq_shard or x.ndim != 3:
            return x
        dp = data_axes(mesh)
        n = 1
        for a in dp:
            n *= mesh.shape[a]
        b = dp if x.shape[0] % n == 0 else None
        s = "model" if x.shape[1] % mesh.shape.get("model", 1) == 0 else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(b, s, None))
        )

    def grads_of(params, batch):
        def loss(p):
            l, metrics = zoo.loss_fn(
                p, batch, cfg, act_constrain if seq_shard else None
            )
            return l, metrics

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        return l, metrics, grads

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            l, metrics, grads = grads_of(params, batch)
        else:
            # split batch leading dim into microbatches and accumulate grads;
            # ONE optimizer update (and thus one DP all-reduce point) at the
            # end — the transitively-reduced synchronization schedule.
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return constrain_mb(
                    x.reshape((microbatches, b // microbatches) + x.shape[1:])
                )

            mb = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if grad_shardings is not None:
                zero = jax.tree.map(
                    jax.lax.with_sharding_constraint, zero, grad_shardings
                )

            def body(carry, mbatch):
                acc, lsum = carry
                l, _, g = grads_of(params, mbatch)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / microbatches,
                    acc,
                    g,
                )
                if grad_shardings is not None:
                    acc = jax.tree.map(
                        jax.lax.with_sharding_constraint, acc, grad_shardings
                    )
                return (acc, lsum + l / microbatches), None

            (grads, l), _ = jax.lax.scan(body, (zero, jnp.zeros(())), mb)
            metrics = {"nll": l, "aux": jnp.zeros(())}

        if grad_compressor is not None:
            grads, opt_state = grad_compressor(grads, opt_state)

        gnorm = global_norm(grads)
        new_params, new_opt = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(loss=l, grad_norm=gnorm, lr=opt.schedule(new_opt.step))
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch, cache):
        return zoo.prefill(params, batch, cfg, cache)

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True) -> Callable:
    """(params, tokens (B,1), cache, cache_len) -> (next_tokens, cache)."""

    def serve_step(params, tokens, cache, cache_len):
        logits, cache = zoo.decode_step(params, tokens, cfg, cache, cache_len)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step
