"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

No device allocation — everything is abstract (``jax.eval_shape`` for param
and cache trees), sharded with the rules in :mod:`repro.launch.sharding`.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import sharding as shard_lib
from repro.models import model_zoo as zoo
from repro.optim.optimizer import AdamW


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract training/prefill batch for the cell."""

    B, S = shape.global_batch, shape.seq_len
    d = jnp.dtype(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "encdec":
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.num_frames, cfg.d_model), d
        )
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif cfg.frontend == "vision":
        # patch prefix + text fill the assigned sequence length
        text = S - cfg.num_patches
        assert text > 0
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), d
        )
        specs["tokens"] = jax.ShapeDtypeStruct((B, text), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(specs["tokens"].shape, jnp.int32)
    return specs


def abstract_state(cfg: ModelConfig, opt: AdamW):
    params = zoo.abstract_params(cfg)
    opt_state = jax.eval_shape(lambda p: opt.init(p), params)
    return params, opt_state


def decode_inputs(
    cfg: ModelConfig, shape: ShapeConfig
) -> Tuple[jax.ShapeDtypeStruct, Any, jax.ShapeDtypeStruct]:
    """(tokens, cache, cache_len) stand-ins for a decode cell: one new token
    against a KV cache filled to seq_len."""

    B, S = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache = zoo.abstract_cache(cfg, B, S)
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, cache, cache_len


def cell_shardings(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    opt: AdamW,
):
    """All in/out shardings for the cell's step function.

    Returns a dict with 'params', 'opt_state', 'batch', 'cache', etc. as
    NamedSharding pytrees.
    """

    out: Dict[str, Any] = {}
    params = zoo.abstract_params(cfg)

    # parameters: tensor-parallel resident (XLA:CPU hoists FSDP all-gathers
    # out of the block scan, exploding temp memory — measured 120-550 GB —
    # so full ZeRO-3 params stay a TPU-only option; see EXPERIMENTS.md §Perf)
    pspec = shard_lib.params_pspecs(cfg, mesh, params)
    out["params_abstract"] = params
    out["params"] = shard_lib.named(mesh, pspec)

    if shape.kind == "train":
        # ZeRO-1: optimizer moments shard over 'data' on top of the
        # tensor-parallel specs; step is a replicated scalar
        from repro.optim.optimizer import AdamWState

        zspec = shard_lib.zero1_pspecs(cfg, mesh, params)
        opt_state = jax.eval_shape(lambda p: opt.init(p), params)
        ospec = AdamWState(step=P(), mu=zspec, nu=zspec)
        out["opt_state_abstract"] = opt_state
        out["opt_state"] = shard_lib.named(mesh, ospec)
        out["grad_shardings"] = shard_lib.named(mesh, zspec)

    b = batch_specs(cfg, shape)
    out["batch_abstract"] = b
    out["batch"] = shard_lib.named(mesh, shard_lib.batch_pspecs(cfg, mesh, b))

    if shape.kind in ("prefill", "decode"):
        cache = zoo.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        out["cache_abstract"] = cache
        out["cache"] = shard_lib.named(
            mesh, shard_lib.cache_pspecs(cfg, mesh, cache)
        )
    return out
