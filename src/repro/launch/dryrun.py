import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

MUST be the process entry point (``python -m repro.launch.dryrun``): the
XLA_FLAGS assignment above runs before any other import so the 512
placeholder host devices exist before jax initializes.  Smoke tests and
benches never import this module.

Per cell it records, into ``experiments/dryrun/<arch>__<shape>__<mesh>.json``:
  * compile wall time, memory_analysis (bytes/device), cost_analysis
    (FLOPs, bytes accessed),
  * collective op counts + ICI traffic (parsed from the optimized HLO),
  * the roofline terms of EXPERIMENTS.md §Roofline.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCHITECTURES,
    SHAPES,
    cell_is_applicable,
    get_config,
    shape_by_name,
)
from repro.launch import hlo_analysis, input_specs, steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model_zoo as zoo  # noqa: E402
from repro.optim.optimizer import AdamW  # noqa: E402

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _cost_get(cost: dict, key: str) -> float:
    try:
        return float(cost.get(key, 0.0))
    except Exception:
        return 0.0


def _memory_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(m, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(m, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(m, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(m, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(m, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: pathlib.Path,
    microbatches: int = 8,
    kv_quant: bool = False,
) -> dict:
    cfg = get_config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = shape_by_name(shape_name)
    ok, why = cell_is_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record: dict = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "kv_quant": kv_quant,
    }
    if not ok:
        record["skipped"] = why
        out_dir.mkdir(parents=True, exist_ok=True)
        fname = f"{cfg.name}__{shape_name}__{mesh_name}.json".replace("/", "_")
        (out_dir / fname).write_text(json.dumps(record, indent=2))
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    opt = AdamW()
    cell = input_specs.cell_shardings(cfg, shape, mesh, opt)

    record["microbatches"] = microbatches if shape.kind == "train" else None
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            # deployment compile (microbatched, donated buffers) for the
            # memory-fit proof; XLA's cost model loses trip counts on nested
            # scans, so the cost/roofline compile below uses microbatches=1.
            fn = steps.make_train_step(
                cfg, opt, microbatches=microbatches, mesh=mesh,
                grad_shardings=cell.get("grad_shardings"),
            )
            jitted = jax.jit(
                fn,
                in_shardings=(cell["params"], cell["opt_state"], cell["batch"]),
                out_shardings=(cell["params"], cell["opt_state"], None),
                donate_argnums=(0, 1),
            )
            mem_compiled = jitted.lower(
                cell["params_abstract"],
                cell["opt_state_abstract"],
                cell["batch_abstract"],
            ).compile()
            record["memory_deploy"] = _memory_dict(mem_compiled)
            del mem_compiled

            fn = steps.make_train_step(
                cfg, opt, microbatches=1, mesh=mesh,
                grad_shardings=cell.get("grad_shardings"),
            )
            jitted = jax.jit(
                fn,
                in_shardings=(cell["params"], cell["opt_state"], cell["batch"]),
                out_shardings=(cell["params"], cell["opt_state"], None),
            )
            lowered = jitted.lower(
                cell["params_abstract"],
                cell["opt_state_abstract"],
                cell["batch_abstract"],
            )
        elif shape.kind == "prefill":
            fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(cell["params"], cell["batch"], cell["cache"]),
                out_shardings=(None, cell["cache"]),
                donate_argnums=(2,),  # cache updated in place (serving)
            )
            lowered = jitted.lower(
                cell["params_abstract"],
                cell["batch_abstract"],
                cell["cache_abstract"],
            )
        else:  # decode
            fn = steps.make_serve_step(cfg)
            tokens, cache_abs, cl = input_specs.decode_inputs(cfg, shape)
            tok_shard = NamedSharding(mesh, P(None, None))
            bdim = tokens.shape[0]
            from repro.launch.sharding import _pick
            from repro.launch.mesh import data_axes

            b_axis = _pick(mesh, bdim, data_axes(mesh), "data")
            tok_shard = NamedSharding(mesh, P(b_axis, None))
            jitted = jax.jit(
                fn,
                in_shardings=(
                    cell["params"],
                    tok_shard,
                    cell["cache"],
                    NamedSharding(mesh, P()),
                ),
                out_shardings=(tok_shard, cell["cache"]),
                donate_argnums=(2,),  # cache updated in place (serving)
            )
            lowered = jitted.lower(
                cell["params_abstract"], tokens, cell["cache_abstract"], cl
            )
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = _memory_dict(compiled)
    print(f"[{cfg.name} × {shape_name} × {mesh_name}] memory_analysis:", mem)
    try:
        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    except Exception as e:
        cost = {"error": repr(e)}
    flops = _cost_get(cost, "flops")
    bytes_accessed = _cost_get(cost, "bytes accessed")
    print(
        f"[{cfg.name} × {shape_name} × {mesh_name}] cost_analysis: "
        f"flops/chip={flops:.3e} bytes/chip={bytes_accessed:.3e}"
    )

    coll = hlo_analysis.parse_collectives(compiled.as_text())

    # MODEL_FLOPS: 6·N_active per token × tokens in the step (train counts
    # fwd+bwd via the 6× convention; decode/prefill use 2·N_active — fwd only)
    n_active = _active_params(cfg)
    if shape.kind == "train":
        tokens_per_step = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens_per_step
    elif shape.kind == "prefill":
        tokens_per_step = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens_per_step
    else:
        tokens_per_step = shape.global_batch  # one token per sequence
        model_flops = 2.0 * n_active * tokens_per_step

    terms = hlo_analysis.roofline(
        flops_per_chip=flops,
        bytes_per_chip=bytes_accessed,
        collective_bytes_per_chip=coll.tpu_adjusted_bytes,
        model_flops=model_flops,
        chips=chips,
    )

    # analytic floors (XLA:CPU cost analysis mis-scales scan trip counts —
    # report both; see EXPERIMENTS.md §Roofline for the methodology note)
    from repro.launch import analytic

    n_params = _total_params(cfg)
    ana = analytic.analytic_record(
        cfg, shape, n_params, n_active, chips, microbatches
    )
    ana_terms = hlo_analysis.roofline(
        flops_per_chip=ana["flops_per_chip"],
        bytes_per_chip=ana["bytes_per_chip"],
        collective_bytes_per_chip=coll.tpu_adjusted_bytes,
        model_flops=model_flops,
        chips=chips,
    )

    record.update(
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        chips=chips,
        memory=mem,
        cost={"flops_per_chip": flops, "bytes_per_chip": bytes_accessed},
        collectives=coll.as_dict(),
        n_active_params=n_active,
        n_total_params=n_params,
        tokens_per_step=tokens_per_step,
        roofline=terms.as_dict(),
        analytic=ana,
        roofline_analytic=ana_terms.as_dict(),
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{cfg.name}__{shape_name}__{mesh_name}.json".replace("/", "_")
    (out_dir / fname).write_text(json.dumps(record, indent=2))
    print(
        f"[{cfg.name} × {shape_name} × {mesh_name}] roofline(hlo): "
        f"compute={terms.compute_s:.4f}s memory={terms.memory_s:.4f}s "
        f"collective={terms.collective_s:.4f}s dominant={terms.dominant} "
        f"mfu={terms.mfu:.3f} (compile {t_compile:.1f}s)"
    )
    print(
        f"[{cfg.name} × {shape_name} × {mesh_name}] roofline(analytic): "
        f"compute={ana_terms.compute_s:.4f}s memory={ana_terms.memory_s:.4f}s "
        f"collective={ana_terms.collective_s:.4f}s dominant={ana_terms.dominant} "
        f"mfu={ana_terms.mfu:.3f}"
    )
    return record


def _total_params(cfg) -> int:
    import numpy as np

    shapes = zoo.abstract_params(cfg)
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


def _active_params(cfg) -> int:
    shapes = zoo.abstract_params(cfg)
    import numpy as np

    frac = cfg.moe.top_k / cfg.moe.num_experts if cfg.has_moe else 1.0

    def walk(tree, routed):
        n = 0
        if isinstance(tree, dict):
            for k, v in tree.items():
                if k == "moe":
                    for kk, vv in v.items():
                        size = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(vv))
                        n += int(size * frac) if kk in ("w_gate", "w_up", "w_down") else size
                else:
                    n += walk(v, routed)
        else:
            n += sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
        return n

    return walk(shapes, False)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or 'all')")
    ap.add_argument("--shape", default=None, help="shape name (or 'all')")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (optimized serving variant)")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = ARCHITECTURES if args.arch in (None, "all") else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape in (None, "all") else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape_name, mp, out_dir, args.microbatches, args.kv_quant)
                except Exception:
                    failures.append((arch, shape_name, mp))
                    traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run: all requested cells compiled")


if __name__ == "__main__":
    main()
