"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests and benches must keep seeing a
single CPU device; only ``dryrun.py`` forces 512 host devices.

Mesh geometry (TPU v5e-class pods):
  * single pod: (16, 16)   axes ("data", "model")    — 256 chips
  * multi pod:  (2, 16, 16) axes ("pod", "data", "model") — 512 chips

Data parallelism runs over ("pod", "data") — the pod axis only ever carries
DP gradient all-reduces (DCN-friendly), while "model" (tensor/expert
parallel) stays inside the pod's ICI, which is the standard 1000+-node
layout: scale pods out on the slow axis, keep collectives-heavy sharding on
the fast axis.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """A small mesh over however many local devices exist (tests)."""

    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def data_parallel_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
