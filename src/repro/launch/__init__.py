"""Launch layer: production meshes, sharding rules, jit steps, dry-run."""
