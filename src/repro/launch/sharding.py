"""Sharding rules: parameter/cache/batch PartitionSpecs per (config, mesh).

MaxText-style logical rules resolved against the concrete mesh: an axis gets
a mesh axis only when the dimension size divides the mesh axis size —
otherwise the next candidate (or replication) applies.  This is what makes
one rule set serve GQA models whose kv_heads (4, 8, 16) may or may not
divide the 16-way model axis, MoE models with 8/16/64 experts, and the
long-context decode cells where the KV-cache *sequence* dimension takes the
spare mesh axes (flash-decoding layout).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import data_axes, model_axis_size


def _fits(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _pick(mesh: Mesh, dim: int, *candidates):
    """First candidate mesh axis (or tuple) that divides ``dim``.

    A 1-tuple collapses to its bare axis name: newer jax no longer
    normalizes ``P(("data",))`` to ``P("data")``, and the two compare
    unequal even though they shard identically.
    """

    for c in candidates:
        if c is None:
            continue
        if _fits(dim, _axis_size(mesh, c)):
            if isinstance(c, tuple) and len(c) == 1:
                return c[0]
            return c
    return None


# ---------------------------------------------------------------------- #
# parameters
# ---------------------------------------------------------------------- #

def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""

    m = "model"
    ms = model_axis_size(mesh)
    name = path[-1]
    stacked = any(p in ("blocks", "enc_blocks", "dec_blocks") for p in path)
    lead: Tuple[Optional[str], ...] = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    def spec(*dims):
        return P(*lead, *dims)

    # shared experts are a plain dense MLP (not expert-stacked)
    in_moe = "moe" in path and "shared" not in path
    in_mamba = "mamba" in path

    if name == "tok":  # (V, d)
        return spec(_pick(mesh, body[0], m), None)
    if name == "head":  # (d, V)
        return spec(None, _pick(mesh, body[1], m))
    if name in ("wq",):  # (d, H, hd)
        return spec(None, _pick(mesh, body[1], m), None)
    if name in ("wk", "wv"):  # (d, KV, hd)
        return spec(None, _pick(mesh, body[1], m), None)
    if name == "wo":  # (H, hd, d)
        return spec(_pick(mesh, body[0], m), None, None)
    if in_moe and name in ("w_gate", "w_up"):  # (E, d, ff)
        mode = cfg.moe.shard if cfg.moe else "auto"
        if mode != "tp" and _fits(body[0], ms):
            return spec(m, None, None)          # expert-parallel
        return spec(None, None, _pick(mesh, body[2], m))  # TP within experts
    if in_moe and name == "w_down":  # (E, ff, d)
        mode = cfg.moe.shard if cfg.moe else "auto"
        if mode != "tp" and _fits(body[0], ms):
            return spec(m, None, None)
        return spec(None, _pick(mesh, body[1], m), None)
    if name == "router":  # (d, E)
        return spec(None, None)
    if name in ("w_gate", "w_up"):  # dense mlp (d, ff)
        return spec(None, _pick(mesh, body[1], m))
    if name == "w_down":  # (ff, d)
        return spec(_pick(mesh, body[0], m), None)
    if in_mamba and name in ("wz", "wx"):  # (d, di)
        return spec(None, _pick(mesh, body[1], m))
    if in_mamba and name == "wdt":  # (d, H)
        return spec(None, _pick(mesh, body[1], m))
    if in_mamba and name in ("wB", "wC"):  # (d, G*N) — small, replicate
        return spec(None, None)
    if in_mamba and name == "out":  # (di, d)
        return spec(_pick(mesh, body[0], m), None)
    if in_mamba and name == "conv_x":  # (K, di)
        return spec(None, _pick(mesh, body[1], m))
    if in_mamba and name in ("A_log", "D", "dt_bias"):  # (H,)
        return spec(_pick(mesh, body[0], m))
    if in_mamba and name == "norm":  # (di,)
        return spec(_pick(mesh, body[0], m))
    # norms / scalars: replicated
    return spec(*(None,) * len(body))


def params_pspecs(cfg: ModelConfig, mesh: Mesh, params_shapes: Any):
    """PartitionSpec pytree matching an (abstract) params tree."""

    def walk(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        return param_spec(names, tuple(leaf.shape), cfg, mesh)

    return jax.tree_util.tree_map_with_path(walk, params_shapes)


def fsdp_pspecs(cfg: ModelConfig, mesh: Mesh, params_shapes: Any):
    """FSDP/ZeRO sharding: the parameter spec plus the 'data' axis on the
    first still-unsharded *weight* dimension that divides it.  Used for the
    training cells' parameters AND optimizer moments: cuts per-chip
    parameter, moment and gradient-accumulator residency by the DP degree —
    required to fit the 27B+ archs on 16 GB chips.  XLA inserts the
    per-block all-gather (fwd/bwd) and reduce-scatter (grad) traffic
    automatically from the sharding mismatch.

    The leading stack dimension of scanned block parameters is never
    sharded — slicing a scan's xs along a sharded axis would serialize every
    iteration through one chip's memory.
    """

    if "data" not in mesh.axis_names:
        return params_pspecs(cfg, mesh, params_shapes)
    ds = mesh.shape["data"]

    def walk(path, leaf):
        names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        base = param_spec(names, tuple(leaf.shape), cfg, mesh)
        spec = list(base) + [None] * (len(leaf.shape) - len(base))
        stacked = any(
            p in ("blocks", "enc_blocks", "dec_blocks") for p in names
        )
        start = 1 if stacked else 0
        for i in range(start, len(leaf.shape)):
            dim, ax = leaf.shape[i], spec[i]
            if ax is None and dim % ds == 0 and dim >= ds:
                spec[i] = "data"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(walk, params_shapes)


# backwards-compatible alias (moments-only use)
zero1_pspecs = fsdp_pspecs


# ---------------------------------------------------------------------- #
# batches
# ---------------------------------------------------------------------- #

def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shapes: Any):
    dp = data_axes(mesh)

    def walk(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        bdim = leaf.shape[0]
        b = _pick(mesh, bdim, dp, "data")
        rest = (None,) * (len(leaf.shape) - 1)
        return P(b, *rest)

    return jax.tree_util.tree_map_with_path(walk, batch_shapes)


# ---------------------------------------------------------------------- #
# KV / state caches
# ---------------------------------------------------------------------- #

def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shapes: Any):
    """Cache sharding: batch→data when divisible; kv_heads→model when
    divisible, else the sequence dim takes the model axis (flash-decoding);
    with batch=1 (long-context) the sequence dim takes every leftover axis."""

    dp = data_axes(mesh)

    def walk(path, leaf):
        names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        name = names[-1]
        # stacked caches: scan-over-blocks (decoder) or the enc-dec cache
        # whose leaves are (L, B, S, KV, hd) without a 'blocks' path entry
        kv_names = ("k", "v", "ck", "cv", "k_q", "v_q", "k_s", "v_s")
        stacked = "blocks" in names or (
            name in kv_names and leaf.ndim == 5
        ) or (name == "ssm" and leaf.ndim == 5) or (
            name == "conv" and leaf.ndim == 4
        )
        shape = leaf.shape[1:] if stacked else leaf.shape
        lead = (None,) if stacked else ()

        if name in kv_names:  # (B, S, KV, hd|1)
            Bdim, Sdim, KV, hd = shape
            b = _pick(mesh, Bdim, dp, "data")
            kvh = _pick(mesh, KV, "model")
            seq_axes = []
            if b is None:
                seq_axes.extend(dp)
            if kvh is None:
                seq_axes.append("model")
            s = _pick(mesh, Sdim, tuple(seq_axes) if seq_axes else None)
            return P(*lead, b, s, kvh, None)
        if name == "ssm":  # (B, H, P, N)
            Bdim, H = shape[0], shape[1]
            b = _pick(mesh, Bdim, dp, "data")
            h = _pick(mesh, H, "model")
            return P(*lead, b, h, None, None)
        if name == "conv":  # (B, K-1, di)
            Bdim, _, di = shape
            b = _pick(mesh, Bdim, dp, "data")
            return P(*lead, b, None, _pick(mesh, di, "model"))
        return P(*lead, *(None,) * len(shape))

    return jax.tree_util.tree_map_with_path(walk, cache_shapes)


# ---------------------------------------------------------------------- #
# helpers
# ---------------------------------------------------------------------- #

def named(mesh: Mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def validate_divisibility(pspec_tree, shapes_tree, mesh: Mesh) -> list:
    """Return a list of (path, shape, spec) that do NOT divide — must be
    empty before lowering (tested)."""

    bad = []

    def walk(path, spec, leaf):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            if dim % _axis_size(mesh, ax) != 0:
                bad.append((jax.tree_util.keystr(path), leaf.shape, spec))

    jax.tree_util.tree_map_with_path(
        walk, pspec_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return bad
