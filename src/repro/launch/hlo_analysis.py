"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``cost_analysis()`` supplies HLO FLOPs and bytes but NOT collective bytes —
those are summed here from the optimized (per-device) HLO text.  Per-chip
ICI traffic heuristics per op (ring algorithms, n shards):

  all-reduce        2 × operand bytes   (reduce-scatter + all-gather phases)
  all-gather        output bytes        (each chip receives the full gather)
  reduce-scatter    operand bytes
  all-to-all        operand bytes
  collective-permute  operand bytes

Hardware constants are TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# e.g.:  %x = bf16[128,4096]{1,0} all-reduce(bf16[128,4096]{1,0} %y), ...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\s*\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]   # per-chip ICI traffic heuristic
    total_bytes: int
    # XLA:CPU legalizes every bf16 dot as f32-dot+convert, so activation
    # collectives parse as f32 — 2x what the TPU target moves (verified by
    # operand inspection: all big ARs feed from convert_bitcast_fusion of
    # bf16 dots).  tpu_adjusted halves f32 collective traffic accordingly.
    f32_bytes: int = 0

    @property
    def tpu_adjusted_bytes(self) -> int:
        return self.total_bytes - self.f32_bytes // 2

    def as_dict(self) -> dict:
        return {
            "counts": self.counts,
            "bytes_by_kind": self.bytes_by_kind,
            "total_bytes": self.total_bytes,
            "f32_bytes": self.f32_bytes,
            "tpu_adjusted_bytes": self.tpu_adjusted_bytes,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    traffic = {k: 0 for k in _COLLECTIVES}
    f32_traffic = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_out, single_out, kind = m.groups()
        if kind.endswith("-done"):
            continue
        counts[kind] += 1
        out_text = tuple_out or single_out or ""
        out_bytes = _shape_bytes(out_text)
        # operand bytes: shapes inside the call parentheses
        paren = line[m.end():]
        operand_text = paren.split("),", 1)[0]
        operand_bytes = _shape_bytes(operand_text)
        if operand_bytes == 0:
            operand_bytes, operand_text = out_bytes, out_text
        if kind == "all-reduce":
            moved = 2 * operand_bytes
        elif kind == "all-gather":
            moved = out_bytes
        else:
            moved = operand_bytes
        traffic[kind] += moved
        if "f32[" in operand_text or "f32[" in out_text:
            f32_traffic += moved
    # the "-start" variants already counted; drop zero entries for brevity
    counts = {k: v for k, v in counts.items() if v}
    traffic = {k: v for k, v in traffic.items() if v}
    return CollectiveStats(
        counts=counts,
        bytes_by_kind=traffic,
        total_bytes=sum(traffic.values()),
        f32_bytes=f32_traffic,
    )


@dataclasses.dataclass
class RooflineTerms:
    """All terms in SECONDS (per step, per chip)."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float          # 6·N_active·tokens for the whole step
    useful_flops_fraction: float  # model_flops / (flops_per_chip × chips)
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline estimate: max of the three terms (perfect overlap)."""

        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline estimate."""

        total = self.step_time_s * self.chips * PEAK_FLOPS
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "step_time_s": self.step_time_s,
            "mfu": self.mfu,
            "chips": self.chips,
        }


def roofline(
    *,
    flops_per_chip: float,
    bytes_per_chip: float,
    collective_bytes_per_chip: float,
    model_flops: float,
    chips: int,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_chip / PEAK_FLOPS,
        memory_s=bytes_per_chip / HBM_BW,
        collective_s=collective_bytes_per_chip / ICI_BW,
        flops_per_chip=flops_per_chip,
        bytes_per_chip=bytes_per_chip,
        collective_bytes_per_chip=collective_bytes_per_chip,
        model_flops=model_flops,
        useful_flops_fraction=(
            model_flops / (flops_per_chip * chips)
            if flops_per_chip
            else 0.0
        ),
        chips=chips,
    )
