"""granite-3-2b — dense GQA.
[hf:ibm-granite/granite-3.0-2b-base; hf]  40L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=49155.  head_dim = 2048/32 = 64."""

from repro.configs.base import ATTN, LayerPos, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="decoder",
        num_layers=40,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49_155,
        block=(LayerPos(mixer=ATTN),),
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke",
        family="decoder",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=251,  # odd vocab (like 49155) exercises unaligned unembed
        block=(LayerPos(mixer=ATTN),),
        remat="none",
        attn_chunk=16,
    )
