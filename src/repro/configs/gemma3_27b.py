"""gemma3-27b — 5:1 local:global attention, 128k context, 256k vocab.
[hf:google/gemma-3-1b-pt; unverified]  62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144.  62 = 10 full blocks of [local x5, global] + 2
remainder local layers."""

from repro.configs.base import ATTN, ATTN_LOCAL, LayerPos, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="decoder",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262_144,
        block=(
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN),
        ),
        sliding_window=1024,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke",
        family="decoder",
        num_layers=8,  # one block of 6 + 2 remainder — exercises the remainder path
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block=(
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN_LOCAL),
            LayerPos(mixer=ATTN),
        ),
        sliding_window=8,
        remat="none",
        attn_chunk=16,
    )
