"""internlm2-20b — dense GQA.
[arXiv:2403.17297; hf]  48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544."""

from repro.configs.base import ATTN, LayerPos, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="decoder",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92_544,
        block=(LayerPos(mixer=ATTN),),
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-smoke",
        family="decoder",
        num_layers=3,
        d_model=48,
        num_heads=6,
        num_kv_heads=2,
        head_dim=8,
        d_ff=96,
        vocab_size=256,
        block=(LayerPos(mixer=ATTN),),
        remat="none",
        attn_chunk=16,
    )
