"""Model / parallelism configuration schema.

Every assigned architecture is expressed as a :class:`ModelConfig` built from
repeating *blocks* of layer positions.  A position specifies its sequence
mixer (full attention, sliding-window attention, or Mamba2 SSD) and its MLP
(dense or MoE).  Models scan over stacked block parameters, so HLO size — and
therefore AOT compile time at 512 devices — is O(block) not O(depth).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------- #
# layer-position specs
# ---------------------------------------------------------------------- #

ATTN = "attn"          # full causal attention
ATTN_LOCAL = "attn_local"  # sliding-window attention
MAMBA = "mamba"        # Mamba2 SSD mixer
MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_NONE = "none"      # mixer-only layers (pure SSM)


@dataclasses.dataclass(frozen=True)
class LayerPos:
    """One layer position inside the repeating block."""

    mixer: str = ATTN
    mlp: str = MLP_DENSE


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # deepseek-style always-on shared experts
    group_size: int = 256        # GShard dispatch group (tokens)
    capacity_factor: float = 1.25
    shard: str = "auto"          # 'auto'|'ep'|'tp' — expert-parallel vs
                                 # tensor-parallel expert weights (§Perf)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256             # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (conv/mel frontend is a stub: ``input_specs``
    supplies precomputed frame embeddings)."""

    num_layers: int
    num_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # 'decoder' | 'encdec'
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    block: Tuple[LayerPos, ...] = (LayerPos(),)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    encoder: Optional[EncoderConfig] = None
    sliding_window: int = 4096
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # modality frontend stub: 'none' | 'audio' | 'vision'
    frontend: str = "none"
    num_patches: int = 0         # vision stub: prefix patch embeddings
    # attention is quadratic in seq — archs whose every block contains a full
    # attention position cannot run long_500k (skip noted in DESIGN.md)
    dtype: str = "bfloat16"
    remat: str = "full"          # 'none' | 'dots' | 'full' (full measured best w/ scan)
    attn_chunk: int = 1024       # flash-style KV chunk for jnp attention
    # int8 KV cache with per-(token,head) scales: ~2x less decode HBM
    # traffic and residency (beyond-paper; §Perf deepseek decode iteration)
    kv_quant: bool = False
    # barrier after residual adds (tried to keep TP all-reduces in bf16;
    # refuted — the f32 ARs are XLA:CPU bf16-dot legalization, and the
    # barrier inflated temp memory 16->110 GB.  Kept for ablation; §Perf it.1)
    pin_collective_dtype: bool = False

    # ------------------------------------------------------------------ #
    def __post_init__(self) -> None:
        if self.num_layers < len(self.block):
            raise ValueError("num_layers smaller than one block")
        if self.family not in ("decoder", "encdec"):
            raise ValueError(self.family)

    @property
    def padded_num_heads(self) -> int:
        """Query heads padded to a multiple of 16 so the head dim shards on
        any model-axis size (llava's 56 → 64).  Padded heads have zeroed
        ``wo`` columns, so they contribute nothing to the output — exact."""

        if self.num_heads % 16 == 0 or self.num_heads < 16:
            return self.num_heads
        return ((self.num_heads + 15) // 16) * 16

    @property
    def padded_vocab_size(self) -> int:
        """Embedding-table rows, padded to a multiple of 512 so the vocab dim
        shards over any model-axis size (logits beyond ``vocab_size`` are
        masked to -inf; labels never reference them).  MaxText-style."""

        pad_to = 512
        return ((self.vocab_size + pad_to - 1) // pad_to) * pad_to

    @property
    def num_blocks(self) -> int:
        return self.num_layers // len(self.block)

    @property
    def remainder_layers(self) -> int:
        return self.num_layers % len(self.block)

    @property
    def sub_quadratic(self) -> bool:
        """True iff no position uses *full* attention (SSM or purely local) —
        the gate for the long_500k shape."""

        return all(p.mixer != ATTN for p in self.block)

    @property
    def has_attention(self) -> bool:
        return any(p.mixer in (ATTN, ATTN_LOCAL) for p in self.block)

    @property
    def has_mamba(self) -> bool:
        return any(p.mixer == MAMBA for p in self.block)

    @property
    def has_moe(self) -> bool:
        return any(p.mlp == MLP_MOE for p in self.block)

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests (same family/pattern, tiny dims)."""

        return dataclasses.replace(self, **overrides)


# ---------------------------------------------------------------------- #
# input shapes assigned to every LM architecture
# ---------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch × shape) is a runnable cell, and why not if skipped.

    long_500k needs sub-quadratic attention — run for SSM/hybrid (every
    attention position local or state-space *or* the hybrid jamba case where
    full-attention layers are a 1:7 minority with the KV cache sharded along
    sequence); skip for pure full-attention archs, per the assignment.
    """

    if shape.name == "long_500k":
        attn_frac = sum(p.mixer == ATTN for p in cfg.block) / len(cfg.block)
        if cfg.has_mamba or cfg.sub_quadratic:
            return True, ""
        return False, (
            f"long_500k skipped: {cfg.name} is full-attention "
            f"(attention fraction {attn_frac:.2f}, no state-space path)"
        )
    return True, ""
