"""jamba-v0.1-52b — hybrid Mamba + attention (1:7), MoE 16e top-2.
[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536.  Block of 8: attention at position 4, Mamba elsewhere; MoE on
odd positions (16 MoE layers total)."""

from repro.configs.base import (
    ATTN,
    MAMBA,
    MLP_DENSE,
    MLP_MOE,
    LayerPos,
    MambaConfig,
    ModelConfig,
    MoEConfig,
)


def _block(attn_pos: int = 4, size: int = 8):
    return tuple(
        LayerPos(
            mixer=ATTN if i == attn_pos else MAMBA,
            mlp=MLP_MOE if i % 2 == 1 else MLP_DENSE,
        )
        for i in range(size)
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="decoder",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65_536,
        block=_block(),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=64),
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        family="decoder",
        num_layers=8,  # one full hybrid block
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block=_block(),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, group_size=32),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2, head_dim=16, chunk=8),
        remat="none",
        attn_chunk=16,
    )
