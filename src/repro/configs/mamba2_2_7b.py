"""mamba2-2.7b — pure SSM, state-space duality (SSD), attention-free.
[arXiv:2405.21060; unverified]  64L d_model=2560 d_ff=0 vocab=50280
ssm_state=128.  d_inner=5120, head_dim=64 -> 80 SSD heads."""

from repro.configs.base import MAMBA, MLP_NONE, LayerPos, MambaConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="decoder",
        num_layers=64,
        d_model=2560,
        num_heads=1,       # attention-free; placeholders
        num_kv_heads=1,
        head_dim=64,
        d_ff=0,
        vocab_size=50_280,
        block=(LayerPos(mixer=MAMBA, mlp=MLP_NONE),),
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke",
        family="decoder",
        num_layers=3,
        d_model=64,
        num_heads=1,
        num_kv_heads=1,
        head_dim=16,
        d_ff=0,
        vocab_size=256,
        block=(LayerPos(mixer=MAMBA, mlp=MLP_NONE),),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2, head_dim=16, chunk=8),
        tie_embeddings=True,
        remat="none",
    )
