"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``get_smoke_config``
returns a reduced same-family config for CPU smoke tests."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_is_applicable,
    shape_by_name,
)

ARCHITECTURES: List[str] = [
    "deepseek_moe_16b",
    "mixtral_8x7b",
    "gemma3_27b",
    "yi_6b",
    "granite_3_2b",
    "internlm2_20b",
    "jamba_v01_52b",
    "mamba2_2_7b",
    "whisper_medium",
    "llava_next_34b",
]

_ALIASES: Dict[str, str] = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "gemma3-27b": "gemma3_27b",
    "yi-6b": "yi_6b",
    "granite-3-2b": "granite_3_2b",
    "internlm2-20b": "internlm2_20b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-medium": "whisper_medium",
    "llava-next-34b": "llava_next_34b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.config()


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke_config()


__all__ = [
    "ARCHITECTURES",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "canonical",
    "cell_is_applicable",
    "get_config",
    "get_smoke_config",
    "shape_by_name",
]
