"""yi-6b — llama-architecture dense GQA.
[arXiv:2403.04652; hf]  32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""

from repro.configs.base import ATTN, LayerPos, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        family="decoder",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64_000,
        block=(LayerPos(mixer=ATTN),),
        rope_theta=5_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke",
        family="decoder",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block=(LayerPos(mixer=ATTN),),
        remat="none",
        attn_chunk=16,
    )
