"""mixtral-8x7b — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000."""

from repro.configs.base import (
    ATTN_LOCAL,
    MLP_MOE,
    LayerPos,
    ModelConfig,
    MoEConfig,
)


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="decoder",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32_000,
        block=(LayerPos(mixer=ATTN_LOCAL, mlp=MLP_MOE),),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
        sliding_window=4096,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        family="decoder",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block=(LayerPos(mixer=ATTN_LOCAL, mlp=MLP_MOE),),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, group_size=32),
        sliding_window=8,
        remat="none",
        attn_chunk=16,
    )
