"""whisper-medium — encoder-decoder; conv/mel frontend stubbed (input_specs
supplies precomputed frame embeddings).
[arXiv:2212.04356; unverified]  24L(+24 enc) d_model=1024 16H d_ff=4096
vocab=51865, 1500 encoder frames (30 s audio)."""

from repro.configs.base import (
    ATTN,
    EncoderConfig,
    LayerPos,
    ModelConfig,
)


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="encdec",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51_865,
        block=(LayerPos(mixer=ATTN),),
        encoder=EncoderConfig(num_layers=24, num_frames=1500),
        frontend="audio",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke",
        family="encdec",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block=(LayerPos(mixer=ATTN),),
        encoder=EncoderConfig(num_layers=2, num_frames=24),
        frontend="audio",
        tie_embeddings=True,
        remat="none",
        attn_chunk=16,
    )
