"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6.
[arXiv:2401.06066; hf]  28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400."""

from repro.configs.base import (
    ATTN,
    MLP_MOE,
    LayerPos,
    ModelConfig,
    MoEConfig,
)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="decoder",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102_400,
        block=(LayerPos(mixer=ATTN, mlp=MLP_MOE),),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="decoder",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        block=(LayerPos(mixer=ATTN, mlp=MLP_MOE),),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96, num_shared=2,
                      group_size=32),
        remat="none",
        attn_chunk=16,
    )
