"""llava-next-34b — VLM backbone (yi-34b-class decoder); anyres vision tiling
stubbed (input_specs supplies pre-projected patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  60L d_model=7168 56H
(GQA kv=8) d_ff=20480 vocab=64000.  2880 patch positions (4 tiles + base
x 576, anyres)."""

from repro.configs.base import ATTN, LayerPos, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="decoder",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64_000,
        block=(LayerPos(mixer=ATTN),),
        frontend="vision",
        num_patches=2880,
        rope_theta=5_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-smoke",
        family="decoder",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block=(LayerPos(mixer=ATTN),),
        frontend="vision",
        num_patches=8,
        remat="none",
        attn_chunk=16,
    )
