"""Runtime control plane: fault tolerance, supervised training, pipeline executor."""
