"""Pipeline-parallel executor driven by the paper's optimized sync plan.

``PipelineRunner`` executes a stage-partitioned callable stack over
microbatches in the DSWP regime (paper §3.2): one worker thread per stage,
inter-stage hand-offs ONLY for the communication events that survived the
ISD transitive reduction (``core.schedule.plan_pipeline_sync``).  Events the
reduction eliminated (skip-connection fan-outs, redundant barriers,
grad-accumulation per-microbatch waits) piggyback on retained hand-offs: the
payload dict rides the chain, which is what a TPU lowering does by fusing
skip tensors into the neighbor ``ppermute`` payload.

The runner counts hand-off events so benchmarks can compare naive vs
optimized schedules on identical results — and it is validated against a
plain sequential execution of the same stages.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.schedule import (
    CommEvent,
    PipelineSyncPlan,
    StageGraph,
    plan_pipeline_sync,
    stage_of,
)

StageFn = Callable[[Any], Any]  # stage input -> stage output


@dataclasses.dataclass
class PipelineStats:
    handoffs: int
    microbatches: int
    stages: int

    @property
    def handoffs_per_microbatch(self) -> float:
        return self.handoffs / max(self.microbatches, 1)


class PipelineRunner:
    """Threaded DSWP execution of a stage chain with a minimal sync plan."""

    def __init__(
        self,
        stage_fns: Sequence[StageFn],
        *,
        skips: Tuple[Tuple[int, int], ...] = (),
        num_microbatches: int = 4,
    ) -> None:
        self.stage_fns = list(stage_fns)
        self.S = len(stage_fns)
        self.M = num_microbatches
        self.skips = skips
        self.plan: PipelineSyncPlan = plan_pipeline_sync(
            StageGraph(
                num_stages=self.S,
                num_microbatches=self.M,
                skips=skips,
            )
        )
        # retained forward hand-offs, grouped by source stage
        self.events_from: Dict[int, List[CommEvent]] = {}
        for e in self.plan.events:
            src, dst = stage_of(e.src_stmt), stage_of(e.dst_stmt)
            if src != dst:
                self.events_from.setdefault(src, []).append(e)

    def run(self, inputs: Sequence[Any]) -> Tuple[List[Any], PipelineStats]:
        """Process ``inputs`` (one per microbatch) through all stages."""

        assert len(inputs) == self.M
        S, M = self.S, self.M
        # one queue per retained (src→dst) channel
        channels: Dict[Tuple[int, int], "queue.Queue"] = {}
        for src, evs in self.events_from.items():
            for e in evs:
                channels[(src, stage_of(e.dst_stmt))] = queue.Queue()
        outputs: List[Any] = [None] * M
        handoffs = [0]
        lock = threading.Lock()
        errors: List[BaseException] = []

        def worker(s: int) -> None:
            try:
                for m in range(M):
                    if s == 0:
                        payload = {"x": inputs[m], "skips": {}}
                    else:
                        payload = channels[(s - 1, s)].get(timeout=30)
                    x = payload["x"]
                    skips = payload["skips"]
                    # skip-connection inputs ride the chain payload — the
                    # eliminated dependences cost no extra hand-off
                    skip_in = [skips[k] for k in sorted(skips) if k[1] == s]
                    y = self.stage_fns[s](
                        (x, *skip_in) if skip_in else x
                    )
                    new_skips = dict(skips)
                    for (src, dst) in self.skips:
                        if src == s:
                            new_skips[(src, dst)] = y
                    new_skips = {
                        k: v for k, v in new_skips.items() if k[1] > s
                    }
                    if s == S - 1:
                        outputs[m] = y
                    else:
                        channels[(s, s + 1)].put(
                            {"x": y, "skips": new_skips}
                        )
                        with lock:
                            handoffs[0] += 1
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [
            threading.Thread(target=worker, args=(s,), daemon=True)
            for s in range(S)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        if errors:
            raise errors[0]
        return outputs, PipelineStats(
            handoffs=handoffs[0], microbatches=M, stages=S
        )

    def run_reference(self, inputs: Sequence[Any]) -> List[Any]:
        """Sequential oracle: stage-by-stage, microbatch-by-microbatch."""

        outs = []
        for x in inputs:
            skip_vals: Dict[Tuple[int, int], Any] = {}
            for s, fn in enumerate(self.stage_fns):
                skip_in = [
                    skip_vals[k] for k in sorted(skip_vals) if k[1] == s
                ]
                x = fn((x, *skip_in) if skip_in else x)
                for (src, dst) in self.skips:
                    if src == s:
                        skip_vals[(src, dst)] = x
            outs.append(x)
        return outs

    def naive_handoffs_per_microbatch(self) -> int:
        """What a one-sync-per-dependence schedule would cost: every chain
        edge plus every skip edge is a separate cross-stage transfer."""

        return (self.S - 1) + len(self.skips)
