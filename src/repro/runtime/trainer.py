"""Supervised training loop: checkpoint/restart, failure recovery, straggler
accounting, deterministic data resume — the control plane a real fleet runs.

The loop is deliberately separable from jit'd math: ``train_loop`` drives
(data iterator → train_step → checkpoint → failure handling) and recovers
from :class:`WorkerFailure` by re-planning the mesh (elastic shrink),
restoring the newest snapshot and replaying the data stream from its saved
state.  On this container the mesh is 1 CPU device and failures are
injected; the recovery logic (restore + exact data replay + step continuity)
is what the integration tests pin down.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, Snapshot
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataIterator, DataState
from repro.launch.steps import make_train_step
from repro.models import model_zoo as zoo
from repro.optim.optimizer import AdamW
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    WorkerFailure,
    plan_elastic_mesh,
)


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: List[float]
    restarts: int
    straggler_reports: List[List[str]]
    state: dict


def train_loop(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    *,
    total_steps: int,
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 10,
    opt: Optional[AdamW] = None,
    microbatches: int = 1,
    seed: int = 0,
    failure_injector: Optional[Callable[[int], None]] = None,
    grad_compressor=None,
) -> TrainResult:
    """Run (or resume) training for ``total_steps`` optimizer steps."""

    # warmup is fixed (not scaled to total_steps) so that a resumed run with
    # a larger total_steps replays the identical LR schedule prefix
    opt = opt or AdamW(warmup_steps=10, total_steps=total_steps)
    step_fn = jax.jit(
        make_train_step(
            cfg, opt, microbatches=microbatches, grad_compressor=grad_compressor
        )
    )

    # ---- restore or init ------------------------------------------------ #
    params = zoo.init(jax.random.PRNGKey(seed), cfg)
    opt_state = opt.init(params)
    start_step = 0
    data_state = DataState(seed=data_cfg.seed, step=0)
    if ckpt is not None:
        snap = ckpt.restore(target={"params": params, "opt": opt_state})
        if snap is not None:
            params = jax.tree.map(jnp.asarray, snap.tree["params"])
            opt_state = jax.tree.map(jnp.asarray, snap.tree["opt"])
            start_step = snap.step
            data_state = snap.data_state or data_state

    it = DataIterator(data_cfg, cfg, state=data_state)
    monitor = HeartbeatMonitor([f"w{i}" for i in range(data_cfg.num_hosts)])
    stragglers = StragglerDetector()
    losses: List[float] = []
    reports: List[List[str]] = []
    restarts = 0

    step = start_step
    while step < total_steps:
        try:
            if failure_injector is not None:
                failure_injector(step)
            t0 = time.monotonic()
            batch = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.monotonic() - t0
            for w in monitor.alive():
                monitor.heartbeat(w)
                stragglers.record(w, dt)
            losses.append(float(metrics["loss"]))
            step += 1
            if stragglers.stragglers():
                reports.append(stragglers.stragglers())
            if ckpt is not None and step % ckpt_every == 0:
                ckpt.save(
                    Snapshot(
                        step=step,
                        tree={
                            "params": jax.tree.map(lambda x: x, params),
                            "opt": opt_state,
                        },
                        data_state=it.peek_state(),
                    )
                )
        except WorkerFailure as f:
            # ---- elastic recovery ---------------------------------------- #
            restarts += 1
            monitor.mark_failed(f.worker)
            healthy = len(monitor.alive())
            plan = plan_elastic_mesh(
                healthy * 256 // max(data_cfg.num_hosts, 1) or 256,
                global_batch=data_cfg.global_batch,
            )
            del plan  # on real hardware: rebuild mesh + device_put reshard
            if ckpt is None:
                raise
            ckpt.wait()
            snap = ckpt.restore(target={"params": params, "opt": opt_state})
            if snap is None:
                # no checkpoint yet: restart from scratch
                params = zoo.init(jax.random.PRNGKey(seed), cfg)
                opt_state = opt.init(params)
                step = 0
                it = DataIterator(data_cfg, cfg)
            else:
                params = jax.tree.map(jnp.asarray, snap.tree["params"])
                opt_state = jax.tree.map(jnp.asarray, snap.tree["opt"])
                step = snap.step
                it = DataIterator(data_cfg, cfg, state=snap.data_state)

    if ckpt is not None:
        ckpt.wait()
    return TrainResult(
        final_step=step,
        losses=losses,
        restarts=restarts,
        straggler_reports=reports,
        state={"params": params, "opt": opt_state},
    )
