import os

if __name__ == "__main__":  # entry-point guard: flags before jax init
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

"""Pipeline-parallel lowering on the production mesh.

Proves the sync-planned pipeline schedule lowers to real collectives: the
retained events of :func:`repro.core.schedule.plan_pipeline_sync` become
``jax.lax.ppermute`` hand-offs inside a ``shard_map`` over the mesh's
``model`` axis (16 stages on the 16×16 pod), and eliminated events become
payload fields riding the same permute — so the compiled HLO contains
exactly ONE collective-permute per microbatch step regardless of how many
skip/fan-out dependences the stage graph has.  ``python -m
repro.runtime.pp_lowering`` AOT-compiles it on the 512-placeholder-device
environment and asserts the collective count (also covered by
tests/test_dryrun_integration.py-style subprocess in tests/test_pp_lowering.py).
"""

import functools  # noqa: E402
from typing import Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.schedule import StageGraph, plan_pipeline_sync, stage_of  # noqa: E402


def build_pipeline_step(
    mesh,
    num_microbatches: int,
    d_model: int,
    skips: Tuple[Tuple[int, int], ...] = (),
    axis: str = "model",
):
    """A shard_map'd pipeline step: each chip along ``axis`` is one stage.

    Stage s applies its own weight matrix; the residual payload carries both
    the chain activation AND the skip values the transitive reduction proved
    can piggyback (a single f32 lane-block per eliminated producer).
    Returns (step_fn, plan).  step_fn(weights, inputs) -> outputs where
    weights (S, d, d) is stage-sharded and inputs (M, B, d) are replicated.
    """

    S = mesh.shape[axis]
    plan = plan_pipeline_sync(
        StageGraph(num_stages=S, num_microbatches=num_microbatches, skips=skips)
    )
    n_skip = len(skips)

    def stage_step(w, x, skip_vals, stage_idx):
        """One stage's compute: consume chain input + its skip inputs."""
        extra = jnp.zeros_like(x)
        for j, (src, dst) in enumerate(skips):
            extra = extra + jnp.where(stage_idx == dst, skip_vals[j], 0.0)
        y = jnp.tanh((x + extra) @ w)
        new_skips = []
        for j, (src, dst) in enumerate(skips):
            new_skips.append(jnp.where(stage_idx == src, y, skip_vals[j]))
        return y, jnp.stack(new_skips) if new_skips else skip_vals

    def pipelined(w_local, xs):
        # w_local: (1, d, d) this stage's weights; xs: (M, B, d) replicated
        stage_idx = jax.lax.axis_index(axis)
        M = xs.shape[0]
        B, d = xs.shape[1], xs.shape[2]
        w = w_local[0]

        def body(carry, m):
            x_in, skip_in, out_acc = carry
            # stage 0 injects microbatch m; others consume the permuted input
            x = jnp.where(stage_idx == 0, xs[m], x_in)
            y, skip_out = stage_step(w, x, skip_in, stage_idx)
            # ONE ppermute moves the chain value AND the piggybacked skips —
            # the eliminated dependences cost no extra collective
            payload = jnp.concatenate([y[None], skip_out], axis=0)
            moved = jax.lax.ppermute(
                payload,
                axis,
                [(i, (i + 1) % S) for i in range(S)],
            )
            x_next, skip_next = moved[0], moved[1:]
            # the last stage's outputs accumulate (shifted schedule: output
            # for microbatch m emerges after S steps; toy schedule runs the
            # fill phase only, enough for the collective-count proof)
            out_acc = out_acc.at[m].set(jnp.where(stage_idx == S - 1, y, 0.0))
            return (x_next, skip_next, out_acc), None

        x0 = jnp.zeros((B, d), xs.dtype)
        s0 = jnp.zeros((max(n_skip, 1), B, d), xs.dtype)
        o0 = jnp.zeros((M, B, d), xs.dtype)
        (x_fin, _, outs), _ = jax.lax.scan(
            body, (x0, s0[:n_skip] if n_skip else s0[:0], o0), jnp.arange(M)
        )
        return outs

    in_specs = (P(axis, None, None), P(None, None, None))
    out_specs = P(None, None, None)
    if hasattr(jax, "shard_map"):
        step = jax.shard_map(
            pipelined,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
    else:  # pre-0.6 jax: experimental API, replication check spelled check_rep
        from jax.experimental.shard_map import shard_map

        step = shard_map(
            pipelined,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False,
        )
    return step, plan


def main() -> None:
    from repro.launch.hlo_analysis import parse_collectives
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    S = mesh.shape["model"]
    skips = tuple((0, d) for d in range(2, 8))  # 6 fan-out edges
    M, B, d = 4, 8, 128
    step, plan = build_pipeline_step(mesh, M, d, skips)
    w = jax.ShapeDtypeStruct((S, d, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((M, B, d), jnp.float32)
    with mesh:
        compiled = jax.jit(step).lower(w, xs).compile()
    coll = parse_collectives(compiled.as_text())
    print("sync plan:", plan.summary())
    print("collective counts:", coll.counts)
    n_cp = coll.counts.get("collective-permute", 0)
    naive = (S - 1) + len(skips)
    print(
        f"collective-permutes in HLO: {n_cp} per microbatch step "
        f"(naive one-per-dependence schedule: {naive})"
    )
    assert n_cp <= 2, "piggybacked schedule must lower to O(1) permutes/step"
    print("pp lowering: OK")


if __name__ == "__main__":
    main()
