"""Fault tolerance for 1000+-node runs: failure detection, straggler
mitigation, elastic re-meshing.

Design (per-component, host-side control plane):

  * :class:`HeartbeatMonitor` — workers post heartbeats; a worker silent for
    ``timeout_s`` is declared failed.  On real pods the heartbeat transport
    is the cluster scheduler / ICI liveness; here it is injectable time for
    deterministic tests.
  * :class:`StragglerDetector` — per-worker EWMA of step durations; a worker
    slower than ``threshold`` × the fleet median is flagged.  Mitigation
    policy is pluggable: "flag" (report), "backup" (schedule a shadow
    replica — returned as an action), "exclude" (treat as failed → elastic
    shrink).
  * :func:`plan_elastic_mesh` — given the healthy chip count, the largest
    valid (data, model) mesh that preserves the model axis (TP degree is a
    property of the checkpoint) and keeps batch divisibility: data shrinks
    in powers of two; training resumes from the last checkpoint with the
    same global batch (more grad accumulation) or a proportionally smaller
    one.
  * :class:`TrainSupervisor` (see trainer.py) composes these with the
    checkpoint manager: detect → shrink → restore → continue.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    step_time_ewma: Optional[float] = None
    alive: bool = True


class HeartbeatMonitor:
    def __init__(
        self,
        workers: List[str],
        *,
        timeout_s: float = 60.0,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.timeout_s = timeout_s
        self.clock = clock
        now = clock()
        self.workers: Dict[str, WorkerState] = {
            w: WorkerState(last_heartbeat=now) for w in workers
        }

    def heartbeat(self, worker: str) -> None:
        self.workers[worker].last_heartbeat = self.clock()

    def check(self) -> List[str]:
        """Returns newly-failed workers and marks them dead."""

        now = self.clock()
        failed = []
        for name, st in self.workers.items():
            if st.alive and now - st.last_heartbeat > self.timeout_s:
                st.alive = False
                failed.append(name)
        return failed

    def alive(self) -> List[str]:
        return [w for w, st in self.workers.items() if st.alive]

    def mark_failed(self, worker: str) -> None:
        self.workers[worker].alive = False


class StragglerDetector:
    def __init__(
        self,
        *,
        alpha: float = 0.2,
        threshold: float = 1.5,
        min_samples: int = 5,
    ) -> None:
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples
        self._ewma: Dict[str, float] = {}
        self._count: Dict[str, int] = {}

    def record(self, worker: str, step_time_s: float) -> None:
        prev = self._ewma.get(worker)
        self._ewma[worker] = (
            step_time_s
            if prev is None
            else self.alpha * step_time_s + (1 - self.alpha) * prev
        )
        self._count[worker] = self._count.get(worker, 0) + 1

    def median_ewma(self) -> Optional[float]:
        vals = sorted(self._ewma.values())
        if not vals:
            return None
        return vals[len(vals) // 2]

    def stragglers(self) -> List[str]:
        med = self.median_ewma()
        if med is None or med <= 0:
            return []
        out = []
        for w, v in self._ewma.items():
            if self._count.get(w, 0) >= self.min_samples and v > self.threshold * med:
                out.append(w)
        return out


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    chips: int
    dropped_chips: int
    note: str


def plan_elastic_mesh(
    healthy_chips: int,
    *,
    model_axis: int = 16,
    global_batch: int = 256,
) -> ElasticPlan:
    """Largest (data, model) mesh on the healthy chips.

    TP degree (``model_axis``) is preserved — resharding weights to a new TP
    degree means a different checkpoint layout; DP shrinks to the largest
    power of two whose product fits and which divides the global batch (the
    difference is absorbed by gradient accumulation)."""

    max_data = healthy_chips // model_axis
    data = 1
    while data * 2 <= max_data and global_batch % (data * 2) == 0:
        data *= 2
    if max_data < 1:
        raise RuntimeError(
            f"only {healthy_chips} healthy chips < model axis {model_axis}"
        )
    used = data * model_axis
    return ElasticPlan(
        data=data,
        model=model_axis,
        chips=used,
        dropped_chips=healthy_chips - used,
        note=(
            f"data axis {data} (was shrunk to keep ×{model_axis} TP); "
            f"global batch {global_batch} → {global_batch // data} per replica "
            f"via gradient accumulation"
        ),
    )


class WorkerFailure(RuntimeError):
    """Raised by the (simulated) device layer when a worker dies mid-step."""

    def __init__(self, worker: str):
        super().__init__(f"worker {worker} failed")
        self.worker = worker
