"""AdamW + schedules, pure-pytree (no optax dependency).

Optimizer state lives in fp32 regardless of param dtype (bf16-safe master
moments); weight decay is decoupled.  ``scale_by_schedule`` implements
linear-warmup cosine decay.  State pytrees mirror params, so the parameter
PartitionSpecs apply verbatim to both moments — no extra sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1

    # ------------------------------------------------------------------ #
    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def schedule(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        frac = jnp.clip(
            (step - self.warmup_steps)
            / max(self.total_steps - self.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        decay = self.min_lr_ratio + (1 - self.min_lr_ratio) * cos
        return self.learning_rate * warm * decay

    def update(
        self, grads: Any, state: AdamWState, params: Any
    ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu,
            grads,
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1**t)
        nu_hat_scale = 1.0 / (1 - b2**t)
        lr = self.schedule(step)

        def upd(p, m, v):
            u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # no decay on norms/biases
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree: Any) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))
