"""Error-feedback gradient compression for the DP all-reduce.

Two compressors, both with error feedback (the residual between the true
and compressed gradient is carried into the next step, preserving
convergence — Karimireddy et al. style):

  * :class:`Int8Compressor` — per-tensor symmetric int8 quantization:
    4× fewer all-reduce bytes (f32→int8) at ~1/255 relative rounding,
    absorbed by the EF residual.
  * :class:`TopKCompressor` — magnitude top-k sparsification (k as a
    fraction): for k=1% the all-reduce payload drops ~50×(index+value).

``compressed_bytes`` reports the wire size so the roofline's collective
term can be re-derived under compression (used in §Perf of EXPERIMENTS.md).
The compressors are pure pytree→pytree functions with explicit state, so
they jit cleanly inside the train step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _zeros_like_f32(tree: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """Symmetric per-tensor int8 with error feedback."""

    def init(self, params: Any) -> Any:
        return _zeros_like_f32(params)

    def compress(self, grads: Any, residual: Any) -> Tuple[Any, Any, Any]:
        """→ (quantized int8 tree, scales, new residual)."""

        def one(g, r):
            g = g.astype(jnp.float32) + r
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return q, scale, g - deq

        flat, treedef = jax.tree.flatten(grads)
        rflat = jax.tree.leaves(residual)
        qs, scales, res = zip(*(one(g, r) for g, r in zip(flat, rflat)))
        return (
            jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, res),
        )

    def decompress(self, q: Any, scales: Any) -> Any:
        return jax.tree.map(
            lambda x, s: x.astype(jnp.float32) * s, q, scales
        )

    def apply(self, grads: Any, residual: Any) -> Tuple[Any, Any]:
        """grads → (dequantized grads as sent over the wire, new residual)."""

        q, scales, res = self.compress(grads, residual)
        return self.decompress(q, scales), res

    @staticmethod
    def compressed_bytes(grads: Any) -> int:
        return sum(x.size for x in jax.tree.leaves(grads))  # 1 B/elem

    @staticmethod
    def raw_bytes(grads: Any) -> int:
        return sum(x.size * 4 for x in jax.tree.leaves(grads))


@dataclasses.dataclass(frozen=True)
class TopKCompressor:
    """Magnitude top-k with error feedback.  k = fraction of entries kept."""

    fraction: float = 0.01

    def init(self, params: Any) -> Any:
        return _zeros_like_f32(params)

    def apply(self, grads: Any, residual: Any) -> Tuple[Any, Any]:
        def one(g, r):
            g = g.astype(jnp.float32) + r
            flat = g.reshape(-1)
            k = max(1, int(flat.size * self.fraction))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            mask = jnp.zeros_like(flat).at[idx].set(1.0)
            kept = flat * mask
            return kept.reshape(g.shape), (flat - kept).reshape(g.shape)

        flat, treedef = jax.tree.flatten(grads)
        rflat = jax.tree.leaves(residual)
        outs, res = zip(*(one(g, r) for g, r in zip(flat, rflat)))
        return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, res)

    def compressed_bytes(self, grads: Any) -> int:
        # value (4B) + index (4B) per kept entry
        return sum(
            8 * max(1, int(x.size * self.fraction))
            for x in jax.tree.leaves(grads)
        )
