"""Optimizer substrate: AdamW, schedules, gradient compression."""
