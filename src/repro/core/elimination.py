"""The paper's two synchronization-elimination algorithms (§4.2).

1. :func:`eliminate_transitive` — ISD transitive reduction (after Midkiff &
   Padua [10]): dependence δe is redundant if, for every placement of its
   source inside one shift-invariant window, a path of *other* enforced
   orders (intra-iteration program order + retained synchronized
   dependences) connects source(δe)(i) to sink(δe)(i+Δe).  Multiple retained
   dependences may cooperate to cover one eliminated dependence.

2. :func:`eliminate_pattern` — pattern matching (after Li & Abu-Sufah [25]):
   eliminate δe when there exists a retained δr with

     (i)   a path from source(δe) to source(δr)      [program flow],
     (ii)  sink(δr) reaches sink(δe)                 [program flow],
     (iii) δr lexically backward (sink precedes source in the program),
     (iv)  |Δr| = 1,
     (v)   sign(Δr) = sign(Δe).

   Unlike the ISD method this needs no constant-distance assumption for δe
   beyond its sign, which is why the paper presents it as the more general
   second approach.

Both return an :class:`EliminationResult` carrying retained/eliminated sets
and, for the ISD method, the witness paths (e.g. Fig. 6's
S1(2)→S2(2)→S3(2)→S2(3)→S3(3)→S2(4)→S3(4)).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.dependence import Dependence, loop_carried
from repro.core.ir import LoopProgram
from repro.core.isd import Instance, build_isd, isd_window


@dataclasses.dataclass(frozen=True)
class EliminationResult:
    retained: Tuple[Dependence, ...]
    eliminated: Tuple[Dependence, ...]
    # witness paths for eliminated deps (ISD method): dep → instance path
    witnesses: Dict[Dependence, Tuple[Instance, ...]]
    method: str

    @property
    def eliminated_fraction(self) -> float:
        total = len(self.retained) + len(self.eliminated)
        return len(self.eliminated) / total if total else 0.0


def _cost(dep: Dependence) -> Tuple:
    """Greedy ordering: try to eliminate the most expensive syncs first —
    longer distances mean more cross-processor traffic, and the paper's
    example eliminates the Δ=2 dependence using the Δ=1 one."""

    return (sum(abs(x) for x in dep.distance), dep.distance)


def _covered(
    prog: LoopProgram,
    dep: Dependence,
    retained: Sequence[Dependence],
    model: str = "doall",
    processors=None,
) -> Tuple[bool, Tuple[Instance, ...]]:
    """Is ``dep`` transitively enforced by ``retained`` + free orders?"""

    ndim = prog.ndim
    distances = [
        d for r in list(retained) + [dep] for d in r.distance
    ]
    w = isd_window(distances)
    reach = max(abs(x) for x in dep.distance) if dep.loop_carried else 1

    # window anchored at the real loop lower bounds (sound at the boundary);
    # extended by `reach` so target instances of every placement are present
    window = tuple((lo, lo + w + reach) for (lo, _hi) in prog.bounds[:ndim])
    try:
        isd = build_isd(
            prog, list(retained), window, model=model, processors=processors
        )
    except ValueError:
        return False, ()

    # every source placement within the first w iterations must be covered
    placements: List[Tuple[int, ...]] = [()]
    for lo, _ in prog.bounds:
        placements = [p + (i,) for p in placements for i in range(lo, lo + w)]

    witness: Tuple[Instance, ...] = ()
    for it in placements:
        dst_it = tuple(i + d for i, d in zip(it, dep.distance))
        ok, path = isd.has_path((dep.source, it), (dep.sink, dst_it))
        if not ok:
            return False, ()
        if not witness:
            witness = tuple(path)
    return True, witness


def synchronized_set(
    deps: Sequence[Dependence],
    model: str = "doall",
    processors=None,
) -> List[Dependence]:
    """The dependences that need explicit synchronization under ``model``.

    doall: loop-carried deps (Δ≠0) — Δ=0 is free via intra-iteration program
    order.  dswp: deps between *different* statements (any Δ, including 0 —
    statements live on different processors); self-deps are free via
    per-processor order.  procmap: deps between statements on different
    processors (same-processor deps are free via that processor's order).
    """

    if model == "doall":
        return list(loop_carried(deps))
    if model == "dswp":
        return [d for d in deps if d.source != d.sink]
    if model == "procmap":
        assert processors is not None
        return [d for d in deps if processors[d.source] != processors[d.sink]]
    raise ValueError(f"unknown execution model {model!r}")


def eliminate_transitive(
    prog: LoopProgram,
    deps: Sequence[Dependence],
    model: str = "doall",
    processors=None,
) -> EliminationResult:
    """ISD transitive reduction over the synchronized dependences."""

    retained: List[Dependence] = synchronized_set(deps, model, processors)
    eliminated: List[Dependence] = []
    witnesses: Dict[Dependence, Tuple[Instance, ...]] = {}

    for cand in sorted(retained, key=_cost, reverse=True):
        others = [r for r in retained if r is not cand]
        ok, path = _covered(
            prog, cand, others, model=model, processors=processors
        )
        if ok:
            retained.remove(cand)
            eliminated.append(cand)
            witnesses[cand] = path
    return EliminationResult(
        retained=tuple(retained),
        eliminated=tuple(eliminated),
        witnesses=witnesses,
        method=f"isd-transitive-reduction[{model}]",
    )


def _sign(x: int) -> int:
    return (x > 0) - (x < 0)


def pattern_matches(
    prog: LoopProgram, de: Dependence, dr: Dependence
) -> bool:
    """The five conditions of §4.2 for eliminating δe using δr (1-D)."""

    if len(de.distance) != 1 or len(dr.distance) != 1:
        return False
    if de is dr:
        return False
    # (iii) δr lexically backward
    if not dr.lexically_backward(prog):
        return False
    # (iv) |Δr| = 1
    if abs(dr.delta) != 1:
        return False
    # (v) same signs
    if _sign(de.delta) != _sign(dr.delta) or de.delta == 0:
        return False
    lex = prog.lexical_index
    if de.delta > 0:
        # (i) path (program flow) source(δe) → source(δr)
        if lex(de.source) > lex(dr.source):
            return False
        # (ii) sink(δr) reaches sink(δe)
        if lex(dr.sink) > lex(de.sink):
            return False
    else:
        # mirrored flow for negative-distance (reversed) loops
        if lex(de.source) < lex(dr.source):
            return False
        if lex(dr.sink) < lex(de.sink):
            return False
    return True


def eliminate_pattern(
    prog: LoopProgram, deps: Sequence[Dependence]
) -> EliminationResult:
    """Pattern-matching elimination over the loop-carried dependences."""

    retained: List[Dependence] = list(loop_carried(deps))
    eliminated: List[Dependence] = []
    for cand in sorted(retained, key=_cost, reverse=True):
        if abs(sum(cand.distance)) <= 1 and len(cand.distance) == 1:
            # a |Δ|≤1 dep can never be strictly covered by this pattern
            # without removing its own enabler; keep it
            continue
        for dr in retained:
            if dr is cand:
                continue
            if pattern_matches(prog, cand, dr):
                retained.remove(cand)
                eliminated.append(cand)
                break
    return EliminationResult(
        retained=tuple(retained),
        eliminated=tuple(eliminated),
        witnesses={},
        method="pattern-matching",
    )
