"""Loop fission / distribution (paper §3.1, Alg. 1 → Alg. 2 → Alg. 3).

After SCC condensation and topological sorting, each condensed node becomes
its own loop (Alg. 2).  The locality regrouping pass then merges adjacent-in-
topological-order nodes that are (a) independent (no path between them in the
condensation), (b) both parallel, and (c) read overlapping data — the paper's
step 5: "Group independent, unordered, nodes reading the same data and marked
as parallel into new nodes to optimize data reuse" (Alg. 3 keeps S1 and S4 in
one loop because both read ``b``).
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Sequence, Tuple

from repro.core.dependence import Dependence, analyze
from repro.core.graph import CondensedGraph, DepGraph, condense, topological_order
from repro.core.ir import LoopProgram, Statement


@dataclasses.dataclass(frozen=True)
class FissionedLoop:
    """One loop produced by fission: an ordered statement group."""

    statements: Tuple[Statement, ...]
    parallel: bool

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.statements)


@dataclasses.dataclass(frozen=True)
class FissionResult:
    loops: Tuple[FissionedLoop, ...]
    program: LoopProgram

    def loop_names(self) -> List[Tuple[str, ...]]:
        return [l.names for l in self.loops]

    def as_program(self) -> LoopProgram:
        """Flatten back into a LoopProgram whose statement order is the
        fissioned order — used for semantic-equivalence testing (legal
        fission never changes results when loops execute in sequence)."""

        stmts: List[Statement] = []
        for loop in self.loops:
            stmts.extend(loop.statements)
        return LoopProgram(statements=tuple(stmts), bounds=self.program.bounds)


def _reachable(graph: CondensedGraph, n: int) -> FrozenSet[int]:
    adj = {}
    for a, b, _ in graph.edges:
        adj.setdefault(a, set()).add(b)
    seen = set()
    work = [n]
    while work:
        x = work.pop()
        for y in adj.get(x, ()):  # type: ignore[arg-type]
            if y not in seen:
                seen.add(y)
                work.append(y)
    return frozenset(seen)


def _reads_of(prog: LoopProgram, stmts: FrozenSet[str]) -> FrozenSet[str]:
    arrays = set()
    for name in stmts:
        for r in prog.statement(name).reads:
            arrays.add(r.array)
    return frozenset(arrays)


def fission(
    prog: LoopProgram,
    deps: Sequence[Dependence] | None = None,
    regroup: bool = True,
) -> FissionResult:
    """Distribute ``prog`` into per-node loops (Alg. 2), optionally with the
    locality regrouping of Alg. 3 (``regroup=True``)."""

    deps = list(deps) if deps is not None else analyze(prog)
    graph = DepGraph.build(prog, deps)
    cond = condense(graph)
    order = topological_order(cond, prog)

    # groups of condensed-node indices, initially singleton per node
    groups: List[List[int]] = [[k] for k in order]

    if regroup:
        reach = {k: _reachable(cond, k) for k in order}
        merged = True
        while merged:
            merged = False
            for gi in range(len(groups)):
                for gj in range(gi + 1, len(groups)):
                    a_nodes, b_nodes = groups[gi], groups[gj]
                    if not all(
                        cond.nodes[k].is_parallel for k in a_nodes + b_nodes
                    ):
                        continue
                    # independence: no path in either direction
                    if any(
                        (b in reach[a]) or (a in reach[b])
                        for a in a_nodes
                        for b in b_nodes
                    ):
                        continue
                    reads_a = frozenset().union(
                        *(_reads_of(prog, cond.nodes[k].statements) for k in a_nodes)
                    )
                    reads_b = frozenset().union(
                        *(_reads_of(prog, cond.nodes[k].statements) for k in b_nodes)
                    )
                    if not (reads_a & reads_b):
                        continue
                    # legality: merging moves group gj up to gi's position;
                    # it must not jump over an intervening group that has a
                    # dependence path into it.
                    if any(
                        b in reach[m]
                        for gm in range(gi + 1, gj)
                        for m in groups[gm]
                        for b in b_nodes
                    ):
                        continue
                    groups[gi] = a_nodes + b_nodes
                    del groups[gj]
                    merged = True
                    break
                if merged:
                    break

    loops: List[FissionedLoop] = []
    for grp in groups:
        names = sorted(
            (s for k in grp for s in cond.nodes[k].statements),
            key=prog.lexical_index,
        )
        stmts = tuple(prog.statement(n) for n in names)
        loops.append(
            FissionedLoop(
                statements=stmts,
                parallel=all(cond.nodes[k].is_parallel for k in grp),
            )
        )
    return FissionResult(loops=tuple(loops), program=prog)
