"""Per-SCC scheduling-policy engine: pluggable strategies for recurrence SCCs.

The SCC-condensed hybrid (:mod:`repro.core.scc`) used to hard-code exactly
one treatment for every SCC carrying a mixed-sign internal dependence: the
chunked DOACROSS.  That is sound but serializes wide recurrences that a
unimodular change of basis would run fully parallel — the classic polyhedral
skewing result (Baghdadi et al., arXiv:1111.6756): a stencil carrying
Δ=(1,-1) admits a diagonal wavefront after the skew ``T = [[1,0],[1,1]]``,
because every transformed distance becomes per-dimension non-negative, which
is exactly the ISD precondition the plain longest-path layering needs.

This module makes that decision first-class.  Each recurrence SCC is planned
by a :class:`SchedulingPolicy` producing a :class:`StrategyPlan` record that
:func:`repro.core.scc.analyze_sccs` stores on the partition:

  * :class:`ChunkedDoacross` — the extracted PR-3 behavior: iterations in
    sequential order, ``chunk`` = the SCC's minimum carried linearized
    distance iterations batched per step (capped by the ``chunk_limit``
    knob; carried free orders of non-doall models pin the chunk to 1).
  * :class:`UnimodularSkew` — search small unimodular (det ±1) matrices
    ``T`` making every retained internal distance per-dimension non-negative
    in the transformed basis.  The SCC's instances are then layered by the
    existing longest-path machinery over the *transformed* instance space;
    because instance layering is basis-invariant (the enforced-order graph is
    isomorphic under the bijection ``i ↦ T·i``), the levels come out already
    carrying original coordinates — the index remapping the lowering would
    otherwise do per level is folded into the level tables for free.
  * :class:`PerSccModel` — run the recurrence SCC ``dswp``-style internally:
    one sequential lane per statement (per-statement lexicographic chains
    become enforced orders) while the surrounding program stays doall.
    Intra-iteration program order among the SCC's statements is *kept* — the
    upstream elimination assumed it, so the lanes may pipeline across
    iterations but may not reorder one iteration's statements.

  * :class:`CostModelPolicy` (the default, ``scc_policy=None``/``"auto"``)
    scores every feasible strategy by estimated batched-step cost — depth ×
    statement groups per level, with per-level width recorded for the report
    — and picks the cheapest, tie-broken toward ``chunk`` (the historical
    behavior).  ``parallelize(..., scc_policy="skew")`` forces one strategy;
    a forced strategy that is infeasible for some SCC (no legal skew matrix
    exists, non-doall execution model) falls back to ``chunk`` and says so
    in the plan's ``reason``.

Import-light on purpose (no numpy, no jax): :mod:`repro.compile.structure`
folds the resolved policy — canonicalized by its content-hashing
``_const_fp`` fingerprint machinery, full instance state included — into
the structural cache key, and :mod:`repro.core.scc` imports the vector
helpers from here, so this module must stay at the bottom of the
dependency stack (:func:`policy_signature` is the lighter, repr-based
identity used by reports and tests, not by the cache).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple, Union

from repro.core.dependence import Dependence

Matrix = Tuple[Tuple[int, ...], ...]

# A backend's per-SCC cost hook (``BackendSpec.level_cost``): estimate the
# execution cost of one strategy's offer on that backend.  ``None`` keeps the
# interpreter model (depth × statement groups) the offers are born with.
LevelCostFn = Callable[["StrategyPlan", "SccContext"], float]


# ---------------------------------------------------------------------- #
# Small vector/matrix helpers (shared with repro.core.scc)
# ---------------------------------------------------------------------- #

def strides_of(bounds: Sequence[Tuple[int, int]]) -> Tuple[List[int], int]:
    """Row-major strides of the iteration space + total iteration count."""

    extents = [hi - lo for lo, hi in bounds]
    strides = [0] * len(extents)
    acc = 1
    for k in range(len(extents) - 1, -1, -1):
        strides[k] = acc
        acc *= max(extents[k], 0)
    return strides, acc


def linearize(distance: Sequence[int], strides: Sequence[int]) -> int:
    return sum(d * s for d, s in zip(distance, strides))


def identity_matrix(ndim: int) -> Matrix:
    return tuple(
        tuple(1 if r == c else 0 for c in range(ndim)) for r in range(ndim)
    )


def mat_vec(mat: Matrix, vec: Sequence[int]) -> Tuple[int, ...]:
    return tuple(sum(m * v for m, v in zip(row, vec)) for row in mat)


def mat_mul(a: Matrix, b: Matrix) -> Matrix:
    n = len(a)
    return tuple(
        tuple(sum(a[r][k] * b[k][c] for k in range(n)) for c in range(n))
        for r in range(n)
    )


def mat_det(mat: Matrix) -> int:
    """Determinant by cofactor expansion — matrices here are tiny (ndim ≤ 3
    in practice, never beyond the loop-nest rank)."""

    n = len(mat)
    if n == 1:
        return mat[0][0]
    if n == 2:
        return mat[0][0] * mat[1][1] - mat[0][1] * mat[1][0]
    det = 0
    for c in range(n):
        minor = tuple(
            tuple(row[k] for k in range(n) if k != c) for row in mat[1:]
        )
        det += (-1) ** c * mat[0][c] * mat_det(minor)
    return det


def mat_inverse_unimodular(mat: Matrix) -> Matrix:
    """Exact integer inverse of a det-±1 matrix via the adjugate."""

    n = len(mat)
    det = mat_det(mat)
    if det not in (1, -1):
        raise ValueError(f"matrix {mat} is not unimodular (det={det})")
    if n == 1:
        return ((det,),)
    adj = []
    for r in range(n):
        row = []
        for c in range(n):
            minor = tuple(
                tuple(mat[i][j] for j in range(n) if j != r)
                for i in range(n)
                if i != c
            )
            row.append((-1) ** (r + c) * mat_det(minor) * det)
        adj.append(tuple(row))
    return tuple(adj)


def skew_point(mat: Matrix, point: Sequence[int]) -> Tuple[int, ...]:
    """Map an iteration point into the skewed basis (``i ↦ T·i``)."""

    return mat_vec(mat, point)


def unskew_point(mat: Matrix, point: Sequence[int]) -> Tuple[int, ...]:
    """Inverse map — exact because ``mat`` is unimodular; round-tripping any
    integer point is the bijectivity the property suite asserts."""

    return mat_vec(mat_inverse_unimodular(mat), point)


# ---------------------------------------------------------------------- #
# Unimodular skew search
# ---------------------------------------------------------------------- #

_SKEW_ENTRY_RANGE = range(-3, 4)


def _feasible(mat: Matrix, distances: Sequence[Tuple[int, ...]]) -> bool:
    return all(
        all(x >= 0 for x in mat_vec(mat, d)) for d in distances
    )


def _elementary_skews(ndim: int) -> List[Matrix]:
    """Row-operation generators: identity with one off-diagonal entry set
    (``row_r += m·row_c``) — each has det 1 by construction."""

    out: List[Matrix] = []
    for r in range(ndim):
        for c in range(ndim):
            if r == c:
                continue
            for m in _SKEW_ENTRY_RANGE:
                if m == 0:
                    continue
                mat = [list(row) for row in identity_matrix(ndim)]
                mat[r][c] = m
                out.append(tuple(tuple(row) for row in mat))
    return out


def find_unimodular_skew(
    distances: Sequence[Tuple[int, ...]], ndim: int
) -> Optional[Matrix]:
    """A small unimodular matrix making every distance per-dim non-negative.

    Returns the identity when the distances already satisfy the ISD
    precondition, the lowest-|entry| feasible matrix otherwise, or ``None``
    when the bounded search finds nothing (the caller falls back to
    chunking).  The search is exhaustive over entries in ``[-3, 3]`` for 2-D
    nests and over products of up to two elementary row operations for
    higher ranks — the determinant is ±1 for every candidate, so any hit is
    a legal change of basis (the instance map ``i ↦ T·i`` is bijective on
    ℤ^ndim, hence on any iteration space).

    Memoized: the search is pure in (distance set, rank) but costs ~1ms for
    a 2-D SCC (2401 candidates), and :func:`repro.core.scc.scc_signature`
    folds it into every structural-cache key — warm ``run_xla`` lookups and
    per-wave serving re-plans must not re-pay it.
    """

    return _find_skew_cached(
        tuple(sorted({tuple(d) for d in distances if any(x != 0 for x in d)})),
        ndim,
    )


@functools.lru_cache(maxsize=256)
def _find_skew_cached(
    dists: Tuple[Tuple[int, ...], ...], ndim: int
) -> Optional[Matrix]:
    ident = identity_matrix(ndim)
    if _feasible(ident, dists):
        return ident
    if ndim == 1:
        return None  # 1-D lex-positive distances are already non-negative
    if ndim == 2:
        best: Optional[Matrix] = None
        best_weight = None
        for a, b, c, d in itertools.product(_SKEW_ENTRY_RANGE, repeat=4):
            if a * d - b * c not in (1, -1):
                continue
            mat = ((a, b), (c, d))
            if not _feasible(mat, dists):
                continue
            weight = (abs(a) + abs(b) + abs(c) + abs(d), (a, b, c, d))
            if best_weight is None or weight < best_weight:
                best, best_weight = mat, weight
        return best
    gens = _elementary_skews(ndim)
    candidates = gens + [mat_mul(g, h) for g in gens for h in gens]
    best = None
    best_weight = None
    for mat in candidates:
        if mat_det(mat) not in (1, -1) or not _feasible(mat, dists):
            continue
        weight = (sum(abs(x) for row in mat for x in row), mat)
        if best_weight is None or weight < best_weight:
            best, best_weight = mat, weight
    return best


# ---------------------------------------------------------------------- #
# Strategy plans
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SccContext:
    """Everything a policy may condition on for one recurrence SCC."""

    statements: Tuple[str, ...]            # lexical order
    internal_deps: Tuple[Dependence, ...]  # non-vacuous retained deps inside
    bounds: Tuple[Tuple[int, int], ...]
    model: str                             # the *global* execution model
    chunk_limit: Optional[int] = None
    # the SCC contains a carried free-order edge of a non-doall model
    # (per-statement dswp chain, procmap wraparound) — batching may not
    # reorder it, so DOACROSS chunks collapse to 1
    carried_free: bool = False


@dataclasses.dataclass(frozen=True)
class StrategyPlan:
    """One strategy's offer for an SCC, with its cost-model estimate."""

    strategy: str            # "chunk" | "skew" | "dswp"
    cost: float              # estimated batched group evaluations
    depth: int               # estimated level-synchronous steps
    width: float             # estimated instances per (statement, level)
    chunk: Optional[int] = None
    carried_min: Optional[int] = None
    skew: Optional[Matrix] = None
    # widest (statement, level) batch the strategy would emit — what a
    # backend whose per-step cost scales with padded lane width (the XLA
    # level loop) conditions its ``level_cost`` hook on
    max_width: Optional[int] = None
    reason: str = ""
    # the full auction scoreboard the winner was picked from, as
    # (strategy, predicted cost) pairs — populated by CostModelPolicy so
    # the predicted-vs-measured profiler (repro.obs.profile) can line every
    # loser's prediction up against the winner's measured wall time; empty
    # for forced strategies (no auction happened)
    offers: Tuple[Tuple[str, float], ...] = ()
    # generation of the repro.calibrate profile whose units priced the
    # auction: 0 = hand-set defaults (or a forced strategy — no auction).
    # Provenance only; deliberately absent from scc_signature and every
    # structural cache key.
    profile_generation: int = 0


class SchedulingPolicy:
    """Protocol: plan one recurrence SCC (return ``None`` when infeasible).

    Concrete strategies subclass this; anything with a ``name`` and a
    ``plan(ctx) -> Optional[StrategyPlan]`` is accepted by
    ``parallelize(..., scc_policy=...)``.
    """

    name: str = "?"

    def plan(self, ctx: SccContext) -> Optional[StrategyPlan]:
        raise NotImplementedError


# The user-facing ``scc_policy`` knob everywhere it appears (``parallelize``,
# ``PlanOptions``, ``schedule_levels``, ...): ``None``/"auto" = cost model, a
# strategy name forces one, a SchedulingPolicy instance plugs in directly.
# (Defined after the class so the Union holds the real type, not a forward
# reference — typing.get_args(SccPolicyLike) must expose SchedulingPolicy.)
SccPolicyLike = Union[None, str, SchedulingPolicy]




def _scc_shape(ctx: SccContext, *, lanes: bool) -> Tuple[int, int]:
    """Exact (depth, max group width) of the SCC's standalone instance graph.

    Edges: intra-iteration program order among the SCC's statements, the
    internal retained dependences, and (``lanes=True``, the per-SCC dswp
    model) per-statement lexicographic-successor chains.  Exact beats an
    analytic bound here: chain length truncates at the iteration-space
    boundary, which closed-form extent formulas overestimate badly enough to
    mis-rank skew against chunking.  The pass is the same O(instances·edges)
    work the scheduler itself does, paid only when the cost model actually
    has competing candidates — and memoized on exactly the inputs the depth
    depends on (NOT the whole context: ``chunk_limit`` doesn't change this
    graph, and the chunk-knob sweep in the tests would otherwise defeat the
    memo), because report summaries and knob sweeps re-analyze the same SCC.
    The max group width — the widest (statement, level) batch — rides along
    for backend ``level_cost`` hooks whose per-step cost scales with padded
    lane width.
    """

    return _scc_shape_cached(
        ctx.statements, ctx.internal_deps, ctx.bounds, lanes
    )


@functools.lru_cache(maxsize=64)
def _scc_shape_cached(
    statements: Tuple[str, ...],
    internal_deps: Tuple[Dependence, ...],
    bounds: Tuple[Tuple[int, int], ...],
    lanes: bool,
) -> Tuple[int, int]:
    from repro.core.ir import iterations_of

    pts = iterations_of(bounds)
    if not pts:
        return 0, 0
    names = statements
    in_space = set(pts)
    nodes = [(s, it) for it in pts for s in names]
    adj: Dict[Tuple[str, Tuple[int, ...]], Set] = {}

    def add(u, v) -> None:
        if u != v:
            adj.setdefault(u, set()).add(v)

    nxt_of = {}
    if lanes:
        from repro.core.isd import _next_point

        nxt_of = {it: _next_point(it, bounds) for it in pts}
    for it in pts:
        for a, b in zip(names, names[1:]):
            add((a, it), (b, it))
        if lanes and nxt_of[it] is not None:
            for s in names:
                add((s, it), (s, nxt_of[it]))
        for d in internal_deps:
            dst = tuple(x + dd for x, dd in zip(it, d.distance))
            if dst in in_space:
                add((d.source, it), (d.sink, dst))

    indeg = {v: 0 for v in nodes}
    for u, succs in adj.items():
        for v in succs:
            indeg[v] += 1
    level = {}
    frontier = [v for v in nodes if indeg[v] == 0]
    for v in frontier:
        level[v] = 0
    while frontier:
        nxt: List = []
        for u in frontier:
            for v in adj.get(u, ()):
                level[v] = max(level.get(v, 0), level[u] + 1)
                indeg[v] -= 1
                if indeg[v] == 0:
                    nxt.append(v)
        frontier = nxt
    depth = max(level.values(), default=-1) + 1
    group_width: Dict[Tuple[str, int], int] = {}
    for (s, _it), lvl in level.items():
        key = (s, lvl)
        group_width[key] = group_width.get(key, 0) + 1
    return depth, max(group_width.values(), default=0)


class ChunkedDoacross(SchedulingPolicy):
    """The PR-3 behavior, extracted: sequential chunks of the SCC's minimum
    carried linearized distance (always feasible, always sound)."""

    name = "chunk"

    def plan(self, ctx: SccContext) -> Optional[StrategyPlan]:
        strides, total = strides_of(ctx.bounds)
        lins = [
            lin
            for d in ctx.internal_deps
            if (lin := linearize(d.distance, strides)) >= 1
        ]
        if ctx.carried_free:
            lins.append(1)
        # a recurrence SCC always carries something: its mixed-sign dep is
        # lexicographically positive and non-vacuous, hence lin ≥ 1
        carried_min = min(lins) if lins else 1
        chunk = carried_min
        if ctx.chunk_limit is not None:
            chunk = max(1, min(chunk, int(ctx.chunk_limit)))
        n_chunks = -(-total // chunk) if total else 0
        n_stmts = len(ctx.statements)
        return StrategyPlan(
            strategy="chunk",
            cost=float(n_chunks * n_stmts),
            depth=n_chunks,
            width=float(chunk),
            chunk=chunk,
            carried_min=carried_min,
            max_width=chunk,
            reason=(
                f"{total} iterations in {n_chunks} sequential chunks of "
                f"{chunk} (min carried distance {carried_min}"
                + (
                    f", capped by chunk_limit={ctx.chunk_limit}"
                    if ctx.chunk_limit is not None and chunk != carried_min
                    else ""
                )
                + ")"
            ),
        )


class UnimodularSkew(SchedulingPolicy):
    """Diagonal-wavefront execution after a det-±1 change of basis."""

    name = "skew"

    def plan(self, ctx: SccContext) -> Optional[StrategyPlan]:
        if ctx.model != "doall":
            # per-processor free orders serialize each lane regardless of
            # basis — skewing buys nothing and the chains already pin the
            # depth, so don't offer a plan
            return None
        mat = find_unimodular_skew(
            [d.distance for d in ctx.internal_deps], len(ctx.bounds)
        )
        if mat is None:
            return None
        _, total = strides_of(ctx.bounds)
        depth, max_width = _scc_shape(ctx, lanes=False)
        n_stmts = len(ctx.statements)
        width = total / depth if depth else 0.0
        return StrategyPlan(
            strategy="skew",
            cost=float(depth * n_stmts),
            depth=depth,
            width=width,
            skew=mat,
            max_width=max_width,
            reason=(
                f"unimodular skew {mat} makes all internal distances "
                f"per-dim non-negative; transformed-space layering runs "
                f"{total} iterations in {depth} wavefronts "
                f"(mean width {width:.1f})"
            ),
        )


class PerSccModel(SchedulingPolicy):
    """Run the SCC dswp-style internally: one sequential lane per statement,
    pipelined across iterations, while the rest of the program stays doall.

    The depth estimate is analytic, not a graph pass: each lane serializes
    its statement's ``total`` instances (chain length ``total``), and the
    kept intra-iteration program order adds the pipeline fill, so depth ≈
    ``total + n_stmts - 1``.  That bound also proves the cost model can
    never prefer dswp over chunking (chunk depth = ``ceil(total/chunk)`` ≤
    ``total``), so this strategy is effectively *forced-only* — it exists
    to model per-statement-processor machines, not to win the cost race —
    and charging an exact O(instances·edges) layering just to lose the
    auction would be wasted planning work on every auto-planned SCC.
    """

    name = "dswp"

    def plan(self, ctx: SccContext) -> Optional[StrategyPlan]:
        if ctx.model != "doall":
            return None  # the global model already owns the lane structure
        _, total = strides_of(ctx.bounds)
        n_stmts = len(ctx.statements)
        depth = total + n_stmts - 1 if total else 0
        width = total / depth if depth else 0.0
        return StrategyPlan(
            strategy="dswp",
            cost=float(depth * n_stmts),
            depth=depth,
            width=width,
            max_width=1,  # each lane advances one instance per level
            reason=(
                f"per-SCC dswp: {n_stmts} statement lane(s) pipelined over "
                f"{total} iterations in ~{depth} levels (analytic lane-chain "
                "estimate)"
            ),
        )


# Per-batched-group dispatch weight of the interpreters' default
# depth × statement-groups cost model.  A uniform scale that never flips
# an auction on its own — it exists so a calibrated profile
# (repro.calibrate) can express interpreter costs on the same measured
# scale as the backend hooks; resolved late like every other cost unit.
DISPATCH_UNITS = 1.0


# chunk first: it is the tie-breaker (the historical behavior) and the
# universal fallback for forced strategies that turn out infeasible
DEFAULT_STRATEGIES: Tuple[SchedulingPolicy, ...] = (
    ChunkedDoacross(),
    UnimodularSkew(),
    PerSccModel(),
)

STRATEGY_NAMES: Tuple[str, ...] = tuple(s.name for s in DEFAULT_STRATEGIES)


class CostModelPolicy(SchedulingPolicy):
    """Score every feasible strategy, pick the cheapest (ties → first).

    ``level_cost`` is the backend's capability hook
    (:attr:`~repro.core.parallelizer.BackendSpec.level_cost`): when set,
    each offer is re-scored as what it would cost *on that machine* instead
    of the interpreters' depth × statement-groups model the offers are born
    with — which is how ``plan.compile("xla")`` can pick ``chunk`` for the
    same SCC where ``plan.compile("wavefront")`` picks ``skew``.
    """

    name = "auto"

    def __init__(
        self,
        candidates: Sequence[SchedulingPolicy] = DEFAULT_STRATEGIES,
        level_cost: Optional[LevelCostFn] = None,
    ) -> None:
        self.candidates = tuple(candidates)
        self.level_cost = level_cost

    def plan(self, ctx: SccContext) -> Optional[StrategyPlan]:
        offers = [
            p for c in self.candidates if (p := c.plan(ctx)) is not None
        ]
        if not offers:
            return None
        # late import: the auction is priced by whatever calibration state
        # is active *now* (a warmed per-host profile, or the hand-set
        # module constants) — never frozen at import time
        from repro.calibrate import dispatch_units, profile_generation

        if self.level_cost is not None:
            scored = [(float(self.level_cost(p, ctx)), p) for p in offers]
            tag = (
                "cost model "
                f"({getattr(self.level_cost, '__name__', 'level_cost')})"
            )
        else:
            # the interpreters' depth × groups model, weighted by the
            # calibrated per-dispatch cost.  The weight is uniform across
            # offers — it can never flip this auction — so the *recorded*
            # scoreboard keeps the model-space prices (reports stay
            # calibration-invariant; see tests/test_calibrate.py) while
            # the scoring pass reads the profile like every other consumer
            du = dispatch_units()
            scored = [(p.cost * du, p) for p in offers]
            tag = "cost model"
        best_cost, best = min(scored, key=lambda t: t[0])  # tie → first
        if self.level_cost is None:
            # record model-space, not the uniformly-scaled scores
            scored = [(p.cost, p) for p in offers]
            best_cost = best.cost
        scoreboard = ", ".join(f"{p.strategy}={c:.0f}" for c, p in scored)
        return dataclasses.replace(
            best,
            cost=best_cost,
            reason=f"{tag} picked {best.strategy} "
            f"({scoreboard}); {best.reason}",
            offers=tuple((p.strategy, c) for c, p in scored),
            profile_generation=profile_generation(),
        )


class _ForcedPolicy(SchedulingPolicy):
    """Force one strategy; fall back to chunk (and say so) when infeasible."""

    def __init__(self, inner: SchedulingPolicy) -> None:
        self.inner = inner
        self.name = inner.name

    def plan(self, ctx: SccContext) -> Optional[StrategyPlan]:
        offer = self.inner.plan(ctx)
        if offer is not None:
            return dataclasses.replace(
                offer, reason=f"forced scc_policy={self.name!r}; {offer.reason}"
            )
        if ctx.model != "doall":
            cause = (
                f"the {ctx.model!r} execution model already owns the lane "
                "structure (per-processor free orders serialize the SCC)"
            )
        elif self.name == "skew":
            cause = (
                "no unimodular matrix within the bounded search makes "
                "every internal retained distance per-dimension non-negative"
            )
        else:
            cause = "the strategy declined this SCC"
        fallback = ChunkedDoacross().plan(ctx)
        return dataclasses.replace(
            fallback,
            reason=(
                f"forced scc_policy={self.name!r} is infeasible for this "
                f"SCC ({cause}); fell back to chunk — {fallback.reason}"
            ),
        )


def resolve_policy(
    spec: SccPolicyLike, level_cost: Optional[LevelCostFn] = None
) -> SchedulingPolicy:
    """Normalize a user-facing ``scc_policy`` value to a policy object.

    ``None``/``"auto"`` → the cost model; a strategy name forces it (with
    chunk fallback when infeasible); a :class:`SchedulingPolicy` instance
    passes through.  Raises ``ValueError`` for anything else — this is the
    validation ``PlanOptions``/``parallelize()`` runs at entry.

    ``level_cost`` is the scheduling backend's cost hook: it is consulted
    only by the default cost model, so a forced strategy or an explicit
    policy instance is never silently re-scored.  It deliberately does NOT
    participate in the structural compile key (each backend resolves its own
    hook, so within one backend's cache "auto" is unambiguous).
    """

    if spec is None or spec == "auto":
        return CostModelPolicy(level_cost=level_cost)
    if isinstance(spec, SchedulingPolicy):
        return spec
    if isinstance(spec, str):
        for strategy in DEFAULT_STRATEGIES:
            if strategy.name == spec:
                return _ForcedPolicy(strategy)
        raise ValueError(
            f"unknown scc_policy {spec!r}; expected 'auto', one of "
            f"{STRATEGY_NAMES}, or a SchedulingPolicy instance"
        )
    raise ValueError(
        f"scc_policy must be None, 'auto', one of {STRATEGY_NAMES}, or a "
        f"SchedulingPolicy instance — got {type(spec).__name__}: {spec!r}"
    )


def policy_signature(spec: object) -> Tuple:
    """Bounds-free identity of the policy knob (a diagnostics/test helper).

    Class identity participates so a custom policy subclass can never alias
    a built-in of the same name, and instance state participates by
    ``repr`` so differently-configured instances of one class normally
    differ.  Nothing on the compile path calls this: repr is not injective
    (e.g. numpy truncates large arrays), so
    :func:`repro.compile.structure.structural_key` canonicalizes the
    resolved policy's full instance state itself with the same
    content-hashing fingerprint machinery the compute functions get, and
    reports identify the policy by its ``name``.
    """

    def _sig(p: SchedulingPolicy) -> Tuple:
        base: Tuple = (p.name, type(p).__module__, type(p).__qualname__)
        if isinstance(p, _ForcedPolicy):
            return base + (_sig(p.inner),)
        if isinstance(p, CostModelPolicy):
            hook = p.level_cost
            if hook is None:
                hook_id = None
            else:
                # behavioral identity, not qualname: two distinct lambdas
                # both print "<lambda>" — reuse the compute-fingerprint
                # machinery (lazy import: structure imports this module)
                from repro.compile.structure import compute_fingerprint

                hook_id = compute_fingerprint(hook)
            return base + (tuple(_sig(c) for c in p.candidates), hook_id)
        state = getattr(p, "__dict__", None) or {}
        return base + (
            tuple(sorted((k, repr(v)) for k, v in state.items())),
        )

    return ("scc-policy", _sig(resolve_policy(spec)))
