"""Multi-threaded shared-memory reference executor for SyncPrograms.

This is the paper's target machine in miniature: every loop iteration runs on
its own thread of a shared-memory multiprocessor (§2.2, Fig. 2), statements
within an iteration run in program order, and cross-iteration dependences are
enforced *only* by the send/wait instructions (§4.1) — exactly the guarantees
the ISD's edges model.  It serves three purposes:

  * **semantic validation** — results must equal :func:`repro.core.ir.run_sequential`
    for any correctly synchronized program (used by the hypothesis property
    tests over random loop programs);
  * **race demonstration** — with adversarial per-instance stalls, an
    under-synchronized program (e.g. the paper's own Alg. 5, which misses the
    S2 δf(b,Δ=1) S1 dependence) deterministically produces wrong values;
  * **sync accounting** — counts send/wait events executed and how many waits
    actually blocked, the paper's implied cost metric.

Registers implement the paper's semantics: ``send(reg, i)`` posts value ``i``;
``wait(reg, v)`` blocks until value ``v`` has been posted (a wait for an
iteration below the loop's lower bound is trivially satisfied, matching
"dusty deck" arrays initialized before the loop).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Mapping, Optional, Tuple

from repro.core.ir import LoopProgram, ref_cell, run_sequential
from repro.core.sync import SyncProgram


class SyncRegisterFile:
    """Monotone posted-value registers with condition-variable waits."""

    def __init__(self) -> None:
        self._posted: Dict[int, set] = {}
        self._cv = threading.Condition()
        self.sends = 0
        self.waits = 0
        self.blocked_waits = 0

    def send(self, reg: int, value: Tuple[int, ...]) -> None:
        with self._cv:
            self._posted.setdefault(reg, set()).add(value)
            self.sends += 1
            self._cv.notify_all()

    def wait(
        self,
        reg: int,
        value: Tuple[int, ...],
        trivially_satisfied: bool,
        timeout: float,
    ) -> None:
        with self._cv:
            self.waits += 1
            if trivially_satisfied:
                return
            if value not in self._posted.get(reg, ()):  # will block
                self.blocked_waits += 1
                deadline = time.monotonic() + timeout
                while value not in self._posted.get(reg, ()):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"wait(reg={reg}, {value}) timed out — "
                            f"program is under-synchronized or deadlocked"
                        )
                    self._cv.wait(remaining)


@dataclasses.dataclass
class ExecutionStats:
    sends: int
    waits: int
    blocked_waits: int
    threads: int


@dataclasses.dataclass
class ExecutionReport:
    store: dict
    stats: ExecutionStats
    matches_sequential: bool


def run_threaded(
    sync: SyncProgram,
    *,
    stalls: Optional[Mapping[Tuple[str, Tuple[int, ...]], float]] = None,
    timeout: float = 10.0,
    store: Optional[Mapping[str, dict]] = None,
    compare: bool = True,
    model: str = "doall",
) -> ExecutionReport:
    """Run ``sync.program`` on real threads under the chosen execution model.

    ``model="doall"``: one thread per *iteration* (paper §2.2, Fig. 2 — each
    thread executes a subset of the iteration space).  ``model="dswp"``: one
    thread per *statement* (paper §3.2, Fig. 4 — pipelined SCC execution);
    each statement-thread walks all iterations in order.

    ``stalls`` maps (statement name, iteration vector) → seconds of injected
    delay *before* that statement instance executes — the adversarial
    scheduler used to expose missing synchronization deterministically.
    """

    prog = sync.program
    init = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    mem = {a: dict(c) for a, c in init.items()}
    regs = SyncRegisterFile()
    stalls = dict(stalls or {})
    errors: list[BaseException] = []

    def in_space(it: Tuple[int, ...]) -> bool:
        return all(lo <= x < hi for x, (lo, hi) in zip(it, prog.bounds))

    def exec_instance(s, it: Tuple[int, ...]) -> None:
        if (s.name, it) in stalls:
            time.sleep(stalls[(s.name, it)])
        for w in sync.pre_waits.get(s.name, ()):
            target = tuple(x - d for x, d in zip(it, w.distance))
            regs.wait(
                w.reg,
                target,
                trivially_satisfied=not in_space(target),
                timeout=timeout,
            )
        if s.guard is not None:
            gidx = tuple(x + o for x, o in zip(it, s.guard.offset_tuple()))
            if not mem[s.guard.array][gidx] > 0:
                # a skipped instance must STILL post its sends — the paper's
                # send carries fence semantics, and consumers wait on the
                # iteration regardless of the branch outcome
                for snd in sync.post_sends.get(s.name, ()):
                    regs.send(snd.reg, it)
                return
        reads = [mem[r.array][ref_cell(r, it, mem)] for r in s.reads]
        mem[s.write.array][ref_cell(s.write, it, mem)] = s.compute(*reads)
        for snd in sync.post_sends.get(s.name, ()):
            regs.send(snd.reg, it)

    def iteration_body(it: Tuple[int, ...]) -> None:
        try:
            for s in prog.statements:
                exec_instance(s, it)
        except BaseException as e:  # noqa: BLE001 - surfaced to caller
            errors.append(e)

    def statement_body(s) -> None:
        try:
            for it in prog.iterations():
                exec_instance(s, it)
        except BaseException as e:  # noqa: BLE001 - surfaced to caller
            errors.append(e)

    if model == "doall":
        threads = [
            threading.Thread(target=iteration_body, args=(it,), daemon=True)
            for it in prog.iterations()
        ]
    elif model == "dswp":
        threads = [
            threading.Thread(target=statement_body, args=(s,), daemon=True)
            for s in prog.statements
        ]
    else:
        raise ValueError(f"unknown execution model {model!r}")
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout * 2
    for t in threads:
        t.join(max(0.0, deadline - time.monotonic()))
        if t.is_alive():
            errors.append(TimeoutError("iteration thread did not finish"))
    if errors:
        raise errors[0]

    matches = True
    if compare:
        expect = run_sequential(prog, init)
        matches = expect == mem

    return ExecutionReport(
        store=mem,
        stats=ExecutionStats(
            sends=regs.sends,
            waits=regs.waits,
            blocked_waits=regs.blocked_waits,
            threads=len(threads),
        ),
        matches_sequential=matches,
    )


def run_loops_sequence(
    loops, prog: LoopProgram, store: Optional[Mapping[str, dict]] = None
) -> dict:
    """Execute a fissioned loop sequence (each loop fully, in order), with
    each *parallel* loop's iterations run in an adversarial (reversed)
    order — legal iff the loop truly has no loop-carried dependence."""

    mem = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    for loop in loops:
        order = list(prog.iterations())
        if getattr(loop, "parallel", False):
            order = order[::-1]
        for it in order:
            for s in loop.statements:
                reads = [mem[r.array][ref_cell(r, it, mem)] for r in s.reads]
                mem[s.write.array][ref_cell(s.write, it, mem)] = s.compute(
                    *reads
                )
    return mem
