"""Inspector–executor runtime dependence analysis for non-affine loops.

The static analyzer can only bound an indirect access ``a[idx[i]]`` by the
conservative Δ=1 proxy chain (full serialization).  The *inspector* stage
(after the inspector–executor line of work — arXiv 1111.6756 §speculative
loop optimization, and the graph-based dependence identifier of
arXiv 2102.09317) evaluates every subscript against the actual index-array
contents at plan-per-bounds time and produces the **exact instance-level
dependence graph**: one edge per (earlier instance → later instance) pair
that truly touches the same cell.  That graph feeds the existing
longest-path layering (:func:`repro.core.wavefront.schedule_levels`
``instance_edges=``) — a new dependence *source*, not a new scheduler.

Soundness ladder (who decides what):

  * the sequential oracle decides *semantics* — every execution path must
    reproduce its store bit for bit;
  * the inspector graph decides *sufficiency* for the non-affine set — an
    order is safe iff it respects every inspector edge (affine dependences
    stay with the static retained set);
  * speculation (``deps="speculate"``) runs the doall-optimistic schedule
    first and uses :func:`speculation_violations` post-hoc; any violated
    edge triggers rollback to the conservative hybrid schedule.

Caching: instance graphs are bounds- *and* content-dependent by
construction, so results live in a bounded per-bounds memo keyed by
(program fingerprint, bounds, index-array content digest) — beside the
level-table cache, never inside the bounds-free structural key.

Guards are treated as always-executing during inspection (their outcome can
depend on loop-computed values): a superset of the real access set, hence a
superset of the real edges — over-serialization, never under.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.core.ir import (
    ArrayRef,
    IndirectRef,
    LoopProgram,
    Statement,
    is_indirect,
    ref_cell,
)

Instance = Tuple[str, Tuple[int, ...]]
InstanceEdge = Tuple[Instance, Instance]


@dataclasses.dataclass(frozen=True)
class InspectionResult:
    """The exact instance dependence graph over the non-affine array set."""

    program: LoopProgram
    # arrays accessed through at least one indirect subscript — the set the
    # inspector is authoritative for
    arrays: Tuple[str, ...]
    # (earlier instance, later instance) in sequential order; same-iteration
    # conflicts are omitted (intra-iteration program order enforces them)
    edges: Tuple[InstanceEdge, ...]

    @property
    def conflict_free(self) -> bool:
        return not self.edges

    def summary(self) -> Dict[str, object]:
        return {
            "arrays": list(self.arrays),
            "edges": len(self.edges),
            "conflict_free": self.conflict_free,
        }


def validate_inspectable(prog: LoopProgram) -> None:
    """Indirect programs must keep their index arrays loop-invariant.

    :class:`~repro.core.ir.LoopProgram` already rejects direct writes; this
    re-checks at inspection time so hand-built programs that bypassed
    construction (e.g. dataclasses.replace) fail loudly here too.
    """

    clobbered = set(prog.index_arrays()) & {
        s.write.array for s in prog.statements
    }
    if clobbered:
        raise ValueError(
            f"index array(s) {sorted(clobbered)} are written inside the loop"
            f" — the inspector cannot evaluate subscripts at loop entry"
        )


def _inspected_arrays(prog: LoopProgram) -> Tuple[str, ...]:
    seen: List[str] = []
    for s in prog.statements:
        for ref in (s.write, *s.reads):
            if is_indirect(ref) and ref.array not in seen:
                seen.append(ref.array)
    return tuple(seen)


def _compute_edges(
    prog: LoopProgram, store: Mapping[str, dict]
) -> Tuple[InstanceEdge, ...]:
    """One sequential sweep with per-cell last-writer/reader tracking.

    Near-linear in the access count: every read sits in at most one
    "readers since last write" list and is flushed by at most one later
    write, so |edges| = O(|accesses|) — the O(n²) pairwise comparison exists
    only as the test-side cross-check (tests/test_inspector.py).
    """

    targets = set(_inspected_arrays(prog))
    last_write: Dict[Tuple[str, Tuple[int, ...]], Instance] = {}
    readers: Dict[Tuple[str, Tuple[int, ...]], List[Instance]] = {}
    edges: List[InstanceEdge] = []
    seen: set = set()

    def emit(u: Instance, v: Instance) -> None:
        if u[1] == v[1]:
            return  # same iteration: intra-iteration program order covers it
        if (u, v) not in seen:
            seen.add((u, v))
            edges.append((u, v))

    for it in prog.iterations():
        for s in prog.statements:
            inst = (s.name, it)
            reads = list(s.reads)
            if s.guard is not None:
                reads.append(s.guard)  # conservatively always evaluated
            for r in reads:
                if r.array not in targets:
                    continue
                cell = (r.array, ref_cell(r, it, store))
                lw = last_write.get(cell)
                if lw is not None:
                    emit(lw, inst)  # flow
                readers.setdefault(cell, []).append(inst)
            w = s.write
            if w.array in targets:
                cell = (w.array, ref_cell(w, it, store))
                for rd in readers.pop(cell, ()):
                    emit(rd, inst)  # anti
                lw = last_write.get(cell)
                if lw is not None:
                    emit(lw, inst)  # output
                last_write[cell] = inst
    return tuple(edges)


# ---------------------------------------------------------------------- #
# Per-bounds inspector memo (beside the level-table cache — never in the
# bounds-free structural key).
# ---------------------------------------------------------------------- #

_INSPECTOR_MEMO: "collections.OrderedDict[tuple, InspectionResult]" = (
    collections.OrderedDict()
)
_INSPECTOR_MEMO_MAX = 64
_INSPECTOR_LOCK = threading.Lock()
# registry-backed counters (repro.obs.metrics); inspector_cache_stats()
# keeps the exact pre-registry return shape ("misses" doubles as the
# re-inspection count the serving summary reports)
_INSPECTOR_HITS = _metrics.counter("inspector_cache.hits")
_INSPECTOR_MISSES = _metrics.counter("inspector_cache.misses")


def index_content_digest(prog: LoopProgram, store: Mapping[str, dict]) -> str:
    """Content digest of the index arrays — the part of the store the
    instance graph actually depends on (subscripts are loop-invariant)."""

    h = hashlib.sha1()
    for arr in prog.index_arrays():
        h.update(arr.encode())
        for cell, val in sorted(store[arr].items()):
            # normalize the value type: a wave passing {"bin": [0, 1]} and
            # the lowering's float-normalized index view must digest
            # identically, or the same instance graph is re-inspected once
            # per representation (the subscript evaluator int()s the value
            # either way, so float() loses nothing the graph depends on)
            h.update(repr((tuple(cell), float(val))).encode())
    return h.hexdigest()


def inspector_cache_stats() -> Dict[str, int]:
    with _INSPECTOR_LOCK:
        size = len(_INSPECTOR_MEMO)
    return {
        "hits": _INSPECTOR_HITS.value,
        "misses": _INSPECTOR_MISSES.value,
        "size": size,
    }


def clear_inspector_cache() -> None:
    with _INSPECTOR_LOCK:
        _INSPECTOR_MEMO.clear()
    _INSPECTOR_HITS.reset()
    _INSPECTOR_MISSES.reset()


def inspect_dependences(
    prog: LoopProgram, store: Optional[Mapping[str, dict]] = None
) -> InspectionResult:
    """Evaluate all subscripts over ``store`` and build the exact
    instance-level dependence graph for the non-affine array set.

    Affine programs yield an empty graph (nothing to inspect).  Results are
    memoized per (program, bounds, index contents).
    """

    validate_inspectable(prog)
    arrays = _inspected_arrays(prog)
    if not arrays:
        return InspectionResult(program=prog, arrays=(), edges=())
    mem = store if store is not None else prog.initial_store()

    from repro.compile.structure import program_fingerprint

    key = (
        program_fingerprint(prog),
        prog.bounds,
        index_content_digest(prog, mem),
    )
    with _INSPECTOR_LOCK:
        cached = _INSPECTOR_MEMO.get(key)
        if cached is not None:
            _INSPECTOR_MEMO.move_to_end(key)
    if cached is not None:
        _INSPECTOR_HITS.inc()
        return cached
    _INSPECTOR_MISSES.inc()
    with _trace.span("inspect", statements=len(prog.statements)):
        result = InspectionResult(
            program=prog, arrays=arrays, edges=_compute_edges(prog, mem)
        )
    with _INSPECTOR_LOCK:
        _INSPECTOR_MEMO[key] = result
        while len(_INSPECTOR_MEMO) > _INSPECTOR_MEMO_MAX:
            _INSPECTOR_MEMO.popitem(last=False)
    return result


# ---------------------------------------------------------------------- #
# Speculation: run doall-optimistic, validate post-hoc, roll back.
# ---------------------------------------------------------------------- #

def affine_retained(deps: Sequence) -> Tuple:
    """The retained set with non-affine proxies dropped — what the exact
    instance edges replace under ``deps="inspect"``/``"speculate"``."""

    return tuple(d for d in deps if not getattr(d, "nonaffine", False))


def speculation_violations(
    prog: LoopProgram,
    edges: Sequence[InstanceEdge],
    level_of: Mapping[Instance, int],
) -> List[InstanceEdge]:
    """Inspector edges the speculative schedule failed to respect.

    An edge u→v is honored iff level(u) < level(v), or both share a level
    and u's statement is lexically earlier (groups inside a level execute in
    lexical order; lanes of one group are unordered, so a same-statement
    same-level conflict is always a violation).
    """

    lex = prog.lexical_index
    bad: List[InstanceEdge] = []
    for u, v in edges:
        lu, lv = level_of.get(u), level_of.get(v)
        if lu is None or lv is None:
            bad.append((u, v))  # unscheduled instance: cannot be validated
            continue
        if lu < lv:
            continue
        if lu == lv and u[0] != v[0] and lex(u[0]) < lex(v[0]):
            continue
        bad.append((u, v))
    return bad


# ---------------------------------------------------------------------- #
# The canonical non-affine example programs (gather/scatter, sparse
# matvec, histogram) — shared by tests/programs.py, benchmarks and the
# serving demo so every consumer exercises identical structures.
# ---------------------------------------------------------------------- #

def gather_scatter(n: int = 8) -> LoopProgram:
    """b[i] = f(a[idx[i]]); a[perm[i]] = f(b[i]) — gather then scatter."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("b", 0),
                (IndirectRef("a", ArrayRef("idx", 0)),),
            ),
            Statement(
                "S2",
                IndirectRef("a", ArrayRef("perm", 0)),
                (ArrayRef("b", 0),),
            ),
        ),
        bounds=((0, n),),
    )


def sparse_matvec(n: int = 8) -> LoopProgram:
    """COO-style y[row[k]] = f(y[row[k]], v[k], x[col[k]]).

    The accumulate-into-y self conflict serializes exactly the iterations
    sharing a row; distinct rows run doall under ``deps="inspect"``.
    """

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                IndirectRef("y", ArrayRef("row", 0)),
                (
                    IndirectRef("y", ArrayRef("row", 0)),
                    ArrayRef("v", 0),
                    IndirectRef("x", ArrayRef("col", 0)),
                ),
            ),
        ),
        bounds=((0, n),),
    )


def histogram(n: int = 8) -> LoopProgram:
    """h[bin[i]] = f(h[bin[i]], w[i]) — the classic indirect reduction."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                IndirectRef("h", ArrayRef("bin", 0)),
                (IndirectRef("h", ArrayRef("bin", 0)), ArrayRef("w", 0)),
            ),
        ),
        bounds=((0, n),),
    )


def indexed_store(
    prog: LoopProgram,
    indices: Mapping[str, Sequence[int]],
    pad: int = 8,
) -> dict:
    """An initial store whose index arrays hold the given subscript values.

    Convenience for tests and benchmarks that need controlled patterns
    (all-distinct → pure doall, all-same → full serialization,
    permutations).  Cells outside the provided values keep the default
    deterministic content.
    """

    store = prog.initial_store(pad=pad)
    (lo, _hi), = prog.bounds
    for arr, vals in indices.items():
        cells = store[arr]
        for k, v in enumerate(vals):
            cells[(lo + k,)] = float(v)
    return store
