"""SCC-condensed hybrid scheduling of cyclic retained-dependence sets.

The wavefront layering (:mod:`repro.core.wavefront`) is only defined when
every retained dependence distance is per-dimension non-negative — the ISD
precondition.  Real nests violate it routinely: a skewed stencil like
``a[i,j] = f(a[i-1,j+1])`` carries the lexicographically *positive* but
mixed-sign distance ``(1,-1)``, and until this module existed both fast
backends rejected the whole program with :class:`WavefrontError` while only
the O(iterations)-threads machine could run it.

This module implements the standard condensation recipe (DOACROSS/chunking
after Baghdadi et al., arXiv:1111.6756; cycle detection framing after Alluru
& Jeganathan, arXiv:2102.09317):

  1. condense the statement-level enforced-order graph (retained dependences
     plus the execution model's free orders) into strongly connected
     components with Tarjan's algorithm;
  2. classify each SCC — components whose internal dependences are all
     per-dimension non-negative keep the existing instance-level longest-path
     layering (strategy ``"layer"``); components carrying a mixed-sign
     internal dependence are **recurrence blocks** planned by the pluggable
     scheduling-policy engine (:mod:`repro.core.policy`): a chunked DOACROSS
     (``"chunk"`` — iterations in sequential order, chunks of the minimum
     carried linearized distance), a unimodular-skew diagonal wavefront
     (``"skew"`` — instance layering legalized by a det-±1 change of basis),
     or a per-SCC dswp pipeline (``"dswp"`` — one lane per statement).  A
     cost model picks per SCC by default; ``scc_policy`` forces one.  The
     chosen :class:`~repro.core.policy.StrategyPlan` is recorded on each
     :class:`SccInfo` (strategy, skew matrix, cost, and a human-readable
     ``reason``) and surfaces through every report summary;
  3. layer the mixed granularity — individual instances for layerable,
     skewed, and dswp-piped statements, chunk super-nodes for chunked
     recurrence statements — with one global longest-path pass, which yields
     cross-SCC *pipelining* for free: a downstream acyclic SCC's instances
     level right after the producer chunk (or skewed wavefront) they read,
     not after the whole recurrence finishes.

The result is expressed in the ordinary level/group vocabulary (one batched
evaluation per (statement, level), groups within a level executed in lexical
statement order — both executors already do exactly that), so the NumPy
interpreter and the XLA compile path consume hybrid schedules unchanged;
:mod:`repro.compile.lowering` additionally collapses recurrence bands into a
nested ``lax.fori_loop``.

Genuinely unschedulable sets still raise :class:`WavefrontError`, now with a
real diagnosis: a retained dependence whose distance is lexicographically
negative (or zero against lexical order) contradicts sequential execution
order — the paper's send/wait machine would deadlock on it — and the error
names the offending SCC's statements plus a witness cycle.  Validation runs
at ``parallelize()`` time, not mid-execution.

Import-light on purpose (no numpy, no jax): :mod:`repro.compile.structure`
folds :func:`scc_signature` into the structural cache key without paying any
heavy import.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.obs import metrics as _metrics
from repro.core.dependence import Dependence
from repro.core.ir import LoopProgram
from repro.core.policy import (
    LevelCostFn,
    Matrix,
    SccContext,
    SccPolicyLike,
    StrategyPlan,
    find_unimodular_skew,
    linearize as _linearized,
    resolve_policy,
    strides_of as _strides,
)

Instance = Tuple[str, Tuple[int, ...]]
# a scheduling unit: ("i", statement, iteration) for individually layered
# (incl. skewed / per-SCC-dswp) instances, ("c", scc id, chunk index) for
# chunked recurrence blocks
Unit = Tuple


class WavefrontError(ValueError):
    """The retained-dependence set admits no parallel schedule at all.

    Raised only for sets that contradict sequential execution order (the
    send/wait machine would deadlock on them); mixed-sign but
    lexicographically positive sets are *schedulable* via the SCC-condensed
    hybrid and no longer error.
    """


# ---------------------------------------------------------------------- #
# Small vector helpers
# ---------------------------------------------------------------------- #

def _lex_sign(vec: Sequence[int]) -> int:
    for v in vec:
        if v > 0:
            return 1
        if v < 0:
            return -1
    return 0


def _vacuous(distance: Sequence[int], bounds: Sequence[Tuple[int, int]]) -> bool:
    """True when no instance pair of this distance fits inside ``bounds``."""

    return any(abs(d) >= hi - lo for d, (lo, hi) in zip(distance, bounds))


# ---------------------------------------------------------------------- #
# Statement-level enforced-order graph
# ---------------------------------------------------------------------- #

def _free_statement_edges(
    prog: LoopProgram,
    model: str,
    processors: Optional[Dict[str, object]],
) -> List[Tuple[str, str, int]]:
    """The model's free orders, projected to statements.

    Returns ``(source, sink, carried)`` triples; ``carried`` is 0 for
    intra-iteration order and 1 for the lexicographic-successor order
    (per-statement for dswp, per-processor wraparound for procmap).  The
    carried edges are what force recurrence chunks down to size 1 under
    non-doall models: batching a chunk may not reorder anything a processor
    executes sequentially for free.
    """

    names = prog.names
    if model == "doall":
        return [(a, b, 0) for a, b in zip(names, names[1:])]
    if model == "dswp":
        return [(a, a, 1) for a in names]
    if model == "procmap":
        if not processors:
            raise ValueError("procmap model requires a processors mapping")
        edges: List[Tuple[str, str, int]] = []
        by_proc: Dict[object, List[str]] = {}
        for n in names:
            by_proc.setdefault(processors[n], []).append(n)
        for stmts in by_proc.values():
            for a, b in zip(stmts, stmts[1:]):
                edges.append((a, b, 0))
            edges.append((stmts[-1], stmts[0], 1))  # next-iteration wrap
        return edges
    raise ValueError(f"unknown execution model {model!r}")


def tarjan_sccs(
    nodes: Sequence[str], adj: Dict[str, Set[str]]
) -> List[List[str]]:
    """Iterative Tarjan; returns SCCs in topological (condensation) order."""

    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work: List[Tuple[str, int]] = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack.add(v)
            recursed = False
            succs = sorted(adj.get(v, ()))
            for i in range(pi, len(succs)):
                w = succs[i]
                if w not in index:
                    work[-1] = (v, i + 1)
                    work.append((w, 0))
                    recursed = True
                    break
                if w in on_stack:
                    low[v] = min(low[v], index[w])
            if recursed:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[v])
            if low[v] == index[v]:
                comp: List[str] = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                sccs.append(comp)
    sccs.reverse()  # Tarjan emits reverse-topological order
    return sccs


def _witness_cycle(
    dep: Dependence, deps: Sequence[Dependence]
) -> Tuple[Dependence, ...]:
    """A dependence cycle through ``dep``, if one exists (BFS sink→source)."""

    if dep.source == dep.sink:
        return (dep,)
    adj: Dict[str, List[Dependence]] = {}
    for d in deps:
        adj.setdefault(d.source, []).append(d)
    prev: Dict[str, Dependence] = {}
    frontier = [dep.sink]
    seen = {dep.sink}
    while frontier:
        nxt: List[str] = []
        for u in frontier:
            for d in adj.get(u, ()):
                if d.sink in seen:
                    continue
                prev[d.sink] = d
                if d.sink == dep.source:
                    path = [d]
                    while path[-1].source != dep.sink:
                        path.append(prev[path[-1].source])
                    return (dep,) + tuple(path[::-1])
                seen.add(d.sink)
                nxt.append(d.sink)
        frontier = nxt
    return ()


def validate_retained(
    prog: LoopProgram, retained: Sequence[Dependence]
) -> None:
    """Reject dependence sets that contradict sequential execution order.

    A retained dependence demands source(i) execute before sink(i + Δ); when
    ``Δ`` is lexicographically negative — or zero while the sink does not
    follow the source in program text — the sequential oracle itself runs
    the two instances in the opposite order, so *no* backend can both
    enforce the dependence and stay bit-equal to the oracle (the send/wait
    machine deadlocks or races on it).  The diagnostic names each offending
    dependence, its SCC's statements, and a witness cycle when the Δ-sign
    mix closes one.  Everything else — including per-dimension sign mixes
    with lexicographically positive distances — is schedulable by the
    SCC-condensed hybrid and passes.
    """

    problems: List[str] = []
    deps = list(retained)
    for d in deps:
        sign = _lex_sign(d.distance)
        why = None
        if sign < 0:
            why = "its distance is lexicographically negative"
        elif sign == 0 and d.source == d.sink:
            why = "a zero-distance self-dependence orders an instance before itself"
        elif sign == 0 and prog.lexical_index(d.sink) < prog.lexical_index(d.source):
            why = (
                "its distance is zero but the sink precedes the source in "
                "program text"
            )
        if why is None:
            continue
        msg = f"{d.pretty()} runs against sequential execution order ({why})"
        cycle = _witness_cycle(d, deps)
        if cycle:
            stmts = sorted(
                {x for c in cycle for x in (c.source, c.sink)},
                key=prog.lexical_index,
            )
            msg += (
                f"; its Δ-sign mix closes a cycle through SCC "
                f"{{{', '.join(stmts)}}} — witness cycle: "
                + "  ->  ".join(c.pretty() for c in cycle)
            )
        problems.append(msg)
    if problems:
        _metrics.counter("plan.wavefront_rejections").inc()
        raise WavefrontError(
            "no parallel schedule can enforce the retained synchronized "
            "dependences (the send/wait machine would deadlock on them): "
            + "; ".join(problems)
            + " — drop the dependence or reformulate the loop "
            "(reversal/skewing) so every retained distance is "
            "lexicographically non-negative"
        )


# ---------------------------------------------------------------------- #
# Partition
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SccInfo:
    """One strongly connected component of the enforced-order graph.

    For recurrence components, ``strategy``/``skew``/``cost``/``reason``
    record the :class:`~repro.core.policy.StrategyPlan` the policy engine
    chose; layerable components carry strategy ``"layer"``.
    """

    id: int
    statements: Tuple[str, ...]  # lexical order
    cyclic: bool                 # the component contains a dependence cycle
    recurrence: bool             # carries a mixed-sign internal dependence
    chunk: Optional[int] = None  # iterations per chunk (strategy "chunk")
    # min linearized carried distance inside the SCC (recurrence only) —
    # ``chunk`` equals it unless capped by the chunk_limit knob
    carried_min: Optional[int] = None
    strategy: str = "layer"      # "layer" | "chunk" | "skew" | "dswp"
    skew: Optional[Matrix] = None  # unimodular matrix (strategy "skew")
    cost: Optional[float] = None   # cost-model estimate for the choice
    reason: str = ""               # why this strategy won (human-readable)
    # the policy's full predicted scoreboard, (strategy, cost) per offer —
    # empty for forced strategies; feeds the predicted-vs-measured profiler
    offers: Tuple[Tuple[str, float], ...] = ()
    # generation of the calibration profile that priced the auction
    # (0 = hand-set defaults or forced strategy); provenance only, never
    # part of scc_signature
    profile_generation: int = 0


@dataclasses.dataclass(frozen=True)
class SccPartition:
    """Tarjan condensation of the statement graph, in topological order."""

    sccs: Tuple[SccInfo, ...]
    model: str
    policy: str = "auto"  # canonical name of the scheduling policy used

    def scc_of(self) -> Dict[str, int]:
        return {s: info.id for info in self.sccs for s in info.statements}

    @property
    def recurrences(self) -> Tuple[SccInfo, ...]:
        return tuple(s for s in self.sccs if s.recurrence)

    def summary(self) -> dict:
        return {
            "sccs": len(self.sccs),
            "cyclic": sum(1 for s in self.sccs if s.cyclic),
            "recurrences": [
                {
                    "statements": list(s.statements),
                    "strategy": s.strategy,
                    "chunk": s.chunk,
                    "carried_min": s.carried_min,
                    "skew": [list(r) for r in s.skew] if s.skew else None,
                    "cost": s.cost,
                    "reason": s.reason,
                    "offers": {name: cost for name, cost in s.offers},
                    "profile_generation": s.profile_generation,
                }
                for s in self.recurrences
            ],
            "model": self.model,
            "policy": self.policy,
        }


def analyze_sccs(
    prog: LoopProgram,
    retained: Sequence[Dependence],
    *,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: SccPolicyLike = None,
    level_cost: Optional[LevelCostFn] = None,
    instance_edges: Optional[Sequence[Tuple[Instance, Instance]]] = None,
) -> SccPartition:
    """Condense + classify; validates the retained set first (may raise).

    ``chunk_limit`` caps the DOACROSS chunk size (smaller chunks are always
    sound — they only serialize more); ``None`` uses the full minimum
    carried distance.  ``scc_policy`` selects the recurrence strategy per
    SCC: ``None``/``"auto"`` runs the cost model, a strategy name
    (``"chunk"``/``"skew"``/``"dswp"``) forces it, and any
    :class:`~repro.core.policy.SchedulingPolicy` instance plugs in directly.
    ``level_cost`` is the scheduling backend's per-SCC cost hook
    (:attr:`~repro.core.parallelizer.BackendSpec.level_cost`), consulted by
    the default cost model only — never by forced strategies or explicit
    policy instances.

    ``instance_edges`` (the inspector's exact runtime dependence graph) is
    projected onto statements before condensation: instance conflicts can
    run *both* directions between two statements, so leaving them out could
    place mutually dependent statements in separate SCCs and break the
    condensation's topological-order invariant downstream.
    """

    policy = resolve_policy(scc_policy, level_cost=level_cost)
    validate_retained(prog, retained)
    bounds = prog.bounds
    deps = [d for d in retained if not _vacuous(d.distance, bounds)]
    free = _free_statement_edges(prog, model, processors)

    adj: Dict[str, Set[str]] = {n: set() for n in prog.names}
    for d in deps:
        adj[d.source].add(d.sink)
    for a, b, _carried in free:
        adj[a].add(b)
    if instance_edges:
        for (su, _itu), (sv, _itv) in instance_edges:
            if su != sv:
                adj[su].add(sv)

    comps = tarjan_sccs(prog.names, adj)
    member_of: Dict[str, int] = {}
    for cid, comp in enumerate(comps):
        for n in comp:
            member_of[n] = cid

    lex = prog.lexical_index
    infos: List[SccInfo] = []
    for cid, comp in enumerate(comps):
        mset = set(comp)
        internal = [d for d in deps if d.source in mset and d.sink in mset]
        free_internal = [
            (a, b, c) for (a, b, c) in free if a in mset and b in mset
        ]
        cyclic = len(comp) > 1 or any(d.source == d.sink for d in internal)
        recurrence = any(
            any(x < 0 for x in d.distance) for d in internal
        )
        statements = tuple(sorted(comp, key=lex))
        if not recurrence:
            infos.append(
                SccInfo(
                    id=cid,
                    statements=statements,
                    cyclic=cyclic,
                    recurrence=False,
                )
            )
            continue
        ctx = SccContext(
            statements=statements,
            internal_deps=tuple(internal),
            bounds=bounds,
            model=model,
            chunk_limit=chunk_limit,
            carried_free=any(c == 1 for (_a, _b, c) in free_internal),
        )
        plan: Optional[StrategyPlan] = policy.plan(ctx)
        if plan is None:
            # a custom policy may decline an SCC; chunking is always sound
            from repro.core.policy import ChunkedDoacross

            plan = ChunkedDoacross().plan(ctx)
            plan = dataclasses.replace(
                plan,
                reason=f"policy {policy.name!r} declined this SCC; "
                f"fell back to chunk — {plan.reason}",
            )
        infos.append(
            SccInfo(
                id=cid,
                statements=statements,
                cyclic=cyclic,
                recurrence=True,
                chunk=plan.chunk,
                carried_min=plan.carried_min,
                strategy=plan.strategy,
                skew=plan.skew,
                cost=plan.cost,
                reason=plan.reason,
                offers=plan.offers,
                profile_generation=plan.profile_generation,
            )
        )
    return SccPartition(
        sccs=tuple(infos), model=model, policy=policy.name
    )


def scc_signature(
    prog: LoopProgram,
    retained: Sequence[Dependence],
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
) -> Tuple:
    """Bounds-free canonical form of the SCC partition (cache-key component).

    Membership, recurrence flags, and the bounds-free unimodular-skew
    candidate per recurrence SCC (the matrix search depends only on the
    internal distance vectors) — chunk sizes and the cost model's strategy
    choice are evaluated against concrete bounds and belong to the
    per-bounds table cache, not the structural key.
    """

    free = _free_statement_edges(prog, model, processors)
    adj: Dict[str, Set[str]] = {n: set() for n in prog.names}
    for d in retained:
        adj[d.source].add(d.sink)
    for a, b, _carried in free:
        adj[a].add(b)
    comps = tarjan_sccs(prog.names, adj)
    lex = prog.lexical_index
    out = []
    for comp in comps:
        mset = set(comp)
        internal_dists = tuple(
            d.distance
            for d in retained
            if d.source in mset and d.sink in mset
        )
        recurrence = any(any(x < 0 for x in d) for d in internal_dists)
        out.append(
            (
                tuple(sorted(comp, key=lex)),
                recurrence,
                find_unimodular_skew(internal_dists, prog.ndim)
                if recurrence
                else None,
            )
        )
    return tuple(out)


# ---------------------------------------------------------------------- #
# Hybrid layering
# ---------------------------------------------------------------------- #

def hybrid_levels(
    prog: LoopProgram,
    retained: Sequence[Dependence],
    *,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: SccPolicyLike = None,
    level_cost: Optional[LevelCostFn] = None,
    instance_edges: Optional[Sequence[Tuple[Instance, Instance]]] = None,
) -> Tuple[List[Dict[str, List[Tuple[int, ...]]]], SccPartition]:
    """Longest-path layering over mixed instance/chunk scheduling units.

    Returns ``(levels, partition)`` where ``levels[L]`` maps statement name
    to its (iteration-ordered) batch at level ``L``.  The partition's
    per-SCC strategy records decide the scheduling units: ``"chunk"`` SCCs
    become chunk super-nodes, ``"skew"`` and ``"dswp"`` SCCs stay
    instance-granular (``"dswp"`` adds per-statement lane chains).
    Correctness argument:

      * every enforced-order edge between *different* units strictly
        increases the level (Kahn longest path), exactly like the plain
        wavefront layering;
      * edges *inside* one chunk are only intra-iteration orders running
        lexically forward (program order, zero-distance dependences) — the
        executors evaluate a level's groups in lexical statement order, so
        those hold; carried edges can never stay inside a chunk because the
        chunk size is the minimum carried linearized distance;
      * skewed SCCs carry no intra-unit orders at all: every internal
        dependence is an ordinary unit edge, so the longest-path pass — the
        existing machinery, applied to what is isomorphically the
        T-transformed instance space (per-dimension non-negative distances,
        the ISD precondition) — strictly levels it; the levels carry
        original coordinates, i.e. the skew's index remap is already folded
        into the emitted batches;
      * per-SCC dswp adds per-statement lexicographic chains *on top of*
        intra-iteration program order (the elimination assumed program
        order; adding enforced edges is always sound), pipelining the lanes
        across iterations without reordering within one;
      * the unit graph is acyclic: every edge advances the sequential
        (iteration, lexical position) order, and chunks of one SCC are
        totally ordered by construction;
      * inspector ``instance_edges`` run strictly forward in sequential
        order and join the condensation at statement granularity (see
        :func:`analyze_sccs`), so both-direction instance conflicts merge
        into one SCC and cannot close a cross-unit cycle; an instance edge
        that would land *inside* one chunk span shrinks that SCC's chunk to
        1 (always sound — smaller chunks only serialize more).
    """

    part = analyze_sccs(
        prog,
        retained,
        model=model,
        processors=processors,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
        level_cost=level_cost,
        instance_edges=instance_edges,
    )
    bounds = prog.bounds
    deps = [d for d in retained if not _vacuous(d.distance, bounds)]
    strides, total = _strides(bounds)
    lows = [lo for lo, _hi in bounds]
    member_of = part.scc_of()
    chunk_info = {
        info.id: info
        for info in part.recurrences
        if info.strategy == "chunk"
    }
    lane_sccs = [
        info for info in part.recurrences if info.strategy == "dswp"
    ]
    names = prog.names
    pts = list(prog.iterations())

    def pos(it: Tuple[int, ...]) -> int:
        return sum((x - lo) * s for x, lo, s in zip(it, lows, strides))

    def unit(stmt: str, it: Tuple[int, ...]) -> Unit:
        info = chunk_info.get(member_of[stmt])
        if info is not None:
            return ("c", info.id, pos(it) // info.chunk)
        return ("i", stmt, it)

    if instance_edges and chunk_info:
        # an exact instance edge batched away inside one chunk span would be
        # violated — shrink those SCCs to chunk 1 (same-iteration edges are
        # never emitted by the inspector, so chunk 1 can hold no edge)
        shrink: Set[int] = set()
        for (su, itu), (sv, itv) in instance_edges:
            cu = member_of.get(su)
            if cu is None or cu != member_of.get(sv):
                continue
            info = chunk_info.get(cu)
            if info is not None and pos(itu) // info.chunk == pos(itv) // info.chunk:
                shrink.add(cu)
        for cid in shrink:
            chunk_info[cid] = dataclasses.replace(chunk_info[cid], chunk=1)

    in_space = set(pts)
    adj: Dict[Unit, Set[Unit]] = {}
    nodes: List[Unit] = []
    seen_nodes: Set[Unit] = set()
    for it in pts:
        for s in names:
            u = unit(s, it)
            if u not in seen_nodes:
                seen_nodes.add(u)
                nodes.append(u)

    def add(u: Unit, v: Unit) -> None:
        if u != v:
            adj.setdefault(u, set()).add(v)

    # free orders of the execution model, instance-enumerated
    if model == "doall":
        for it in pts:
            for a, b in zip(names, names[1:]):
                add(unit(a, it), unit(b, it))
    elif model == "dswp":
        from repro.core.isd import _next_point

        for it in pts:
            nxt = _next_point(it, bounds)
            if nxt is not None:
                for a in names:
                    add(unit(a, it), unit(a, nxt))
    else:  # procmap
        if not processors:
            raise ValueError("procmap model requires a processors mapping")
        by_proc: Dict[object, List[str]] = {}
        for n in names:
            by_proc.setdefault(processors[n], []).append(n)
        lex = {n: k for k, n in enumerate(names)}
        for stmts in by_proc.values():
            seq = sorted(
                ((it, lex[s], s) for it in pts for s in stmts),
                key=lambda t: (t[0], t[1]),
            )
            for (it_a, _la, sa), (it_b, _lb, sb) in zip(seq, seq[1:]):
                add(unit(sa, it_a), unit(sb, it_b))

    # retained dependence edges
    for d in deps:
        for it in pts:
            dst = tuple(x + dd for x, dd in zip(it, d.distance))
            if dst in in_space:
                add(unit(d.source, it), unit(d.sink, dst))

    # exact inspector instance edges (runtime non-affine dependences)
    if instance_edges:
        for (su, itu), (sv, itv) in instance_edges:
            if itu in in_space and itv in in_space:
                add(unit(su, itu), unit(sv, itv))

    # per-SCC dswp lanes: each statement of the SCC is one sequential
    # processor, so its lexicographic-successor order is enforced for free
    if lane_sccs:
        from repro.core.isd import _next_point

        nxt_of = {it: _next_point(it, bounds) for it in pts}
        for info in lane_sccs:
            for s in info.statements:
                for it in pts:
                    nxt = nxt_of[it]
                    if nxt is not None:
                        add(unit(s, it), unit(s, nxt))

    # chunk sequencing: a chunked recurrence block iterates its carry in order
    for info in chunk_info.values():
        n_chunks = -(-total // info.chunk)
        for t in range(n_chunks - 1):
            add(("c", info.id, t), ("c", info.id, t + 1))

    # Kahn longest-path layering over units
    indeg: Dict[Unit, int] = {u: 0 for u in nodes}
    for u, succs in adj.items():
        for v in succs:
            indeg[v] += 1
    level: Dict[Unit, int] = {}
    frontier = [u for u in nodes if indeg[u] == 0]
    for u in frontier:
        level[u] = 0
    done = 0
    while frontier:
        nxt: List[Unit] = []
        for u in frontier:
            done += 1
            for v in adj.get(u, ()):
                level[v] = max(level.get(v, 0), level[u] + 1)
                indeg[v] -= 1
                if indeg[v] == 0:
                    nxt.append(v)
        frontier = nxt
    if done != len(nodes):  # pragma: no cover - guarded by validate_retained
        stuck = [u for u in nodes if indeg[u] > 0][:4]
        raise WavefrontError(
            "internal error: hybrid unit graph is cyclic despite validation "
            f"(stuck units include {stuck})"
        )

    depth = max(level.values(), default=-1) + 1
    levels: List[Dict[str, List[Tuple[int, ...]]]] = [
        {} for _ in range(depth)
    ]
    # instance units, visited in iteration order so batches come out sorted
    for it in pts:
        for s in names:
            u = unit(s, it)
            if u[0] == "i":
                levels[level[u]].setdefault(s, []).append(it)
    # chunk units expand to one batch per member statement (lexical order)
    for info in chunk_info.values():
        n_chunks = -(-total // info.chunk)
        for t in range(n_chunks):
            lvl = level[("c", info.id, t)]
            span = pts[t * info.chunk : (t + 1) * info.chunk]
            for s in info.statements:
                levels[lvl][s] = list(span)
    return levels, part
