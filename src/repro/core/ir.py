"""Loop-program intermediate representation.

The paper (§2–§4) operates on single loops (and rectangular loop nests) whose
bodies are straight-line statements with affine array accesses ``a[i - d]``.
This module defines that IR:

  * :class:`ArrayRef`  — an access ``array[i + offset]`` (offset may be
    negative; ``a[i-1]`` is ``ArrayRef("a", -1)``).
  * :class:`IndirectRef` — a *non-affine* access ``array[idx[i + k] + offset]``
    through an index array (gather/scatter, sparse matvec, histogram).  The
    subscript is only known once the index array's contents are — the
    inspector (:mod:`repro.core.inspector`) evaluates it at plan-per-bounds
    time; static analysis treats it conservatively.
  * :class:`Statement` — one statement ``S_k``: a single write plus a list of
    reads and an opaque compute function used by the reference executors.
  * :class:`LoopProgram` — ``for i = lo; i < hi; i++ { S1; ...; Sk }``.

The IR is deliberately *executable*: both the sequential oracle and the
multi-threaded send/wait executor (:mod:`repro.core.executor`) interpret it
directly, so every transformation in :mod:`repro.core` can be checked for
semantic equivalence, exactly in the paper's shared-memory setting.

Multi-dimensional iteration spaces (used when the sync optimizer is lifted to
(stage × microbatch) pipeline schedules, :mod:`repro.core.schedule`) reuse the
same classes with tuple-valued offsets/distances.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional, Sequence, Tuple, Union

Offset = Union[int, Tuple[int, ...]]


def _as_tuple(off: Offset) -> Tuple[int, ...]:
    if isinstance(off, tuple):
        return off
    return (int(off),)


@dataclasses.dataclass(frozen=True)
class ArrayRef:
    """An affine access ``array[i + offset]`` (per-dimension for nests)."""

    array: str
    offset: Offset = 0

    def offset_tuple(self) -> Tuple[int, ...]:
        return _as_tuple(self.offset)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        offs = self.offset_tuple()
        idx = ",".join(
            f"i{k}{o:+d}" if o else f"i{k}" for k, o in enumerate(offs)
        )
        return f"{self.array}[{idx}]"


@dataclasses.dataclass(frozen=True)
class IndirectRef:
    """A non-affine access ``array[idx[i + index.offset] + offset]``.

    ``index`` is the (affine) access that fetches the subscript from the
    index array; its value is truncated toward zero (``int()``) and ``offset``
    added to form the target cell.  Restricted to 1-D loop nests — the
    paper's non-affine scenarios (gather/scatter, sparse matvec, histogram)
    are all 1-D.  The index array must not be written anywhere in the loop
    (the classic inspector–executor requirement: subscripts are computable
    at loop entry); :class:`LoopProgram` rejects programs that violate it.
    """

    array: str
    index: ArrayRef
    offset: int = 0

    def offset_tuple(self) -> Tuple[int, ...]:
        """Rank marker only — the *index access* offset, so rank validation
        and windowing treat the ref as rank-1.  Never use it to compute the
        target cell; that is :func:`ref_cell`'s job."""

        return self.index.offset_tuple()

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        o = self.index.offset_tuple()[0]
        inner = f"i{o:+d}" if o else "i"
        outer = f"{self.offset:+d}" if self.offset else ""
        return f"{self.array}[{self.index.array}[{inner}]{outer}]"


def is_indirect(ref) -> bool:
    return isinstance(ref, IndirectRef)


def ref_arrays(ref) -> Tuple[str, ...]:
    """Arrays an access touches: the target and, if indirect, the index."""

    if is_indirect(ref):
        return (ref.array, ref.index.array)
    return (ref.array,)


def ref_cell(ref, point: Tuple[int, ...], mem: Mapping[str, dict]) -> Tuple[int, ...]:
    """The store cell an access resolves to at iteration ``point``.

    Affine refs need no memory; indirect refs fetch the subscript from the
    index array (KeyError on uninitialized index cells, like any read).
    """

    if is_indirect(ref):
        iidx = tuple(p + o for p, o in zip(point, ref.index.offset_tuple()))
        return (int(mem[ref.index.array][iidx]) + ref.offset,)
    return tuple(p + o for p, o in zip(point, ref.offset_tuple()))


ComputeFn = Callable[..., float]


def _default_compute(*reads: float) -> float:
    """Deterministic, order-sensitive combiner used when no compute is given.

    It is intentionally non-commutative-ish (alternating add/sub with index
    weights) so that executing statements in a wrong order produces wrong
    values — silent reorder bugs cannot hide behind commutativity.
    """

    acc = 1.0
    for k, r in enumerate(reads):
        acc = acc + (r * (k + 1) if k % 2 == 0 else -r / (k + 2))
    return acc


@dataclasses.dataclass(frozen=True)
class Statement:
    """``write.array[i+write.offset] = f(reads...)``.

    ``name`` is the paper-style label (``"S1"``).  ``compute`` consumes the
    read values (in ``reads`` order) and returns the value to store.

    ``guard`` (optional) models the paper's control dependence δc (§2.1):
    the statement executes only if the guard access is positive at run time
    — e.g. ``guard=ArrayRef("p", -1)`` is ``if (p[i-1] > 0) S``.  The guard
    read participates in dependence analysis like any read, and the δc edge
    from the statement that *writes* the guard is emitted explicitly.
    """

    name: str
    write: ArrayRef
    reads: Tuple[ArrayRef, ...]
    compute: ComputeFn = _default_compute
    guard: Optional[ArrayRef] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "reads", tuple(self.reads))

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        rhs = " , ".join(str(r) for r in self.reads) or "..."
        return f"{self.name}: {self.write} <- f({rhs})"


@dataclasses.dataclass(frozen=True)
class LoopProgram:
    """``for i in [lo, hi) { statements }`` (rectangular nest when ndim>1).

    ``bounds`` is a sequence of (lo, hi) per loop dimension.  The paper's
    examples are 1-D (``for i=1; i<n; i++``); the pipeline-schedule lift uses
    2-D (stage, microbatch).
    """

    statements: Tuple[Statement, ...]
    bounds: Tuple[Tuple[int, int], ...] = ((1, 8),)

    def __post_init__(self) -> None:
        object.__setattr__(self, "statements", tuple(self.statements))
        object.__setattr__(
            self, "bounds", tuple((int(lo), int(hi)) for lo, hi in self.bounds)
        )
        ndim = len(self.bounds)
        index_arrays: set = set()
        written: set = set()
        for s in self.statements:
            if is_indirect(s.guard):
                raise ValueError(
                    f"{s.name}: guards must be affine accesses, got {s.guard}"
                )
            refs = (s.write, *s.reads) + ((s.guard,) if s.guard else ())
            for ref in refs:
                if is_indirect(ref):
                    if ndim != 1:
                        raise ValueError(
                            f"{s.name}: indirect access {ref} requires a 1-D "
                            f"loop nest, got rank {ndim}"
                        )
                    index_arrays.add(ref.index.array)
                if len(ref.offset_tuple()) != ndim:
                    raise ValueError(
                        f"{s.name}: access {ref} has rank "
                        f"{len(ref.offset_tuple())} but loop nest has rank {ndim}"
                    )
            written.add(s.write.array)
        clobbered = index_arrays & written
        if clobbered:
            raise ValueError(
                f"index array(s) {sorted(clobbered)} are written inside the "
                f"loop — indirect subscripts must be computable at loop entry "
                f"(inspector–executor requirement)"
            )

    # ------------------------------------------------------------------ #
    @property
    def ndim(self) -> int:
        return len(self.bounds)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.statements)

    def statement(self, name: str) -> Statement:
        for s in self.statements:
            if s.name == name:
                return s
        raise KeyError(name)

    def lexical_index(self, name: str) -> int:
        for k, s in enumerate(self.statements):
            if s.name == name:
                return k
        raise KeyError(name)

    def arrays(self) -> Tuple[str, ...]:
        seen = []
        for s in self.statements:
            refs = (s.write, *s.reads) + ((s.guard,) if s.guard else ())
            for ref in refs:
                for arr in ref_arrays(ref):
                    if arr not in seen:
                        seen.append(arr)
        return tuple(seen)

    def has_indirect(self) -> bool:
        """True iff any access goes through an index array."""

        return any(
            is_indirect(ref)
            for s in self.statements
            for ref in (s.write, *s.reads)
        )

    def index_arrays(self) -> Tuple[str, ...]:
        """The index arrays feeding indirect subscripts (loop-invariant by
        the __post_init__ contract)."""

        seen = []
        for s in self.statements:
            for ref in (s.write, *s.reads):
                if is_indirect(ref) and ref.index.array not in seen:
                    seen.append(ref.index.array)
        return tuple(seen)

    def iterations(self) -> Sequence[Tuple[int, ...]]:
        """All iteration points in lexicographic (sequential) order."""

        return iterations_of(self.bounds)

    # ------------------------------------------------------------------ #
    def initial_store(self, pad: int = 8) -> dict:
        """A deterministic initial memory image covering all accesses.

        Arrays are dense dicts ``{index_tuple: value}`` padded ``pad`` cells
        beyond the loop bounds on each side so that out-of-iteration reads
        (``b[i-2]`` at ``i=1``) hit initialized memory, as in Fortran dusty
        decks where arrays are pre-initialized.
        """

        store: dict = {}
        for arr in self.arrays():
            cells: dict = {}
            ranges = [range(lo - pad, hi + pad) for lo, hi in self.bounds]
            idxs: list[Tuple[int, ...]] = [()]
            for r in ranges:
                idxs = [p + (i,) for p in idxs for i in r]
            for idx in idxs:
                # deterministic pseudo-random-ish initial content
                h = hash((arr, idx)) % 1000003
                cells[idx] = (h % 97) / 7.0 - 5.0
            store[arr] = cells
        return store


def iterations_of(
    bounds: Sequence[Tuple[int, int]]
) -> list[Tuple[int, ...]]:
    """Iteration points of a rectangular nest in lexicographic order.

    The single definition of sequential iteration order —
    :meth:`LoopProgram.iterations` and the scheduling-policy cost model
    both delegate here, so the contract cannot silently diverge.
    """

    pts: list[Tuple[int, ...]] = [()]
    for lo, hi in bounds:
        pts = [p + (i,) for p in pts for i in range(lo, hi)]
    return pts


def run_sequential(prog: LoopProgram, store: Mapping[str, dict] | None = None) -> dict:
    """Execute ``prog`` exactly as written, sequentially.  The oracle."""

    mem = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    for point in prog.iterations():
        for s in prog.statements:
            if s.guard is not None:
                gidx = tuple(
                    p + o for p, o in zip(point, s.guard.offset_tuple())
                )
                if not mem[s.guard.array][gidx] > 0:
                    continue
            reads = [mem[r.array][ref_cell(r, point, mem)] for r in s.reads]
            widx = ref_cell(s.write, point, mem)
            mem[s.write.array][widx] = s.compute(*reads)
    return mem


# ---------------------------------------------------------------------- #
# The paper's didactic programs (Algorithms 1, 4 and 6).
# ---------------------------------------------------------------------- #

def paper_alg1(n: int = 8) -> LoopProgram:
    """Alg. 1: the acyclic-dependence example (Fig. 3a)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            Statement("S2", ArrayRef("b", 0), (ArrayRef("c", -1),)),
            Statement(
                "S3",
                ArrayRef("t", 0),
                (ArrayRef("a", -1), ArrayRef("b", 0), ArrayRef("d", -2)),
            ),
            Statement("S4", ArrayRef("d", 0), (ArrayRef("b", -2),)),
        ),
        bounds=((1, n),),
    )


def paper_alg4(n: int = 8) -> LoopProgram:
    """Alg. 4: the cross-iteration cyclic example (Fig. 5)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            Statement("S2", ArrayRef("b", 0), (ArrayRef("c", -1),)),
            Statement(
                "S3", ArrayRef("c", 0), (ArrayRef("b", -2), ArrayRef("a", -1))
            ),
        ),
        bounds=((1, n),),
    )


def paper_alg6(n: int = 8) -> LoopProgram:
    """Alg. 6: the synchronization-elimination example (Fig. 6)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), ()),
            Statement("S2", ArrayRef("b", 0), (ArrayRef("c", -1),)),
            Statement("S3", ArrayRef("c", 0), (ArrayRef("a", -2),)),
        ),
        bounds=((1, n),),
    )
