"""Loop-program intermediate representation.

The paper (§2–§4) operates on single loops (and rectangular loop nests) whose
bodies are straight-line statements with affine array accesses ``a[i - d]``.
This module defines that IR:

  * :class:`ArrayRef`  — an access ``array[i + offset]`` (offset may be
    negative; ``a[i-1]`` is ``ArrayRef("a", -1)``).
  * :class:`Statement` — one statement ``S_k``: a single write plus a list of
    reads and an opaque compute function used by the reference executors.
  * :class:`LoopProgram` — ``for i = lo; i < hi; i++ { S1; ...; Sk }``.

The IR is deliberately *executable*: both the sequential oracle and the
multi-threaded send/wait executor (:mod:`repro.core.executor`) interpret it
directly, so every transformation in :mod:`repro.core` can be checked for
semantic equivalence, exactly in the paper's shared-memory setting.

Multi-dimensional iteration spaces (used when the sync optimizer is lifted to
(stage × microbatch) pipeline schedules, :mod:`repro.core.schedule`) reuse the
same classes with tuple-valued offsets/distances.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional, Sequence, Tuple, Union

Offset = Union[int, Tuple[int, ...]]


def _as_tuple(off: Offset) -> Tuple[int, ...]:
    if isinstance(off, tuple):
        return off
    return (int(off),)


@dataclasses.dataclass(frozen=True)
class ArrayRef:
    """An affine access ``array[i + offset]`` (per-dimension for nests)."""

    array: str
    offset: Offset = 0

    def offset_tuple(self) -> Tuple[int, ...]:
        return _as_tuple(self.offset)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        offs = self.offset_tuple()
        idx = ",".join(
            f"i{k}{o:+d}" if o else f"i{k}" for k, o in enumerate(offs)
        )
        return f"{self.array}[{idx}]"


ComputeFn = Callable[..., float]


def _default_compute(*reads: float) -> float:
    """Deterministic, order-sensitive combiner used when no compute is given.

    It is intentionally non-commutative-ish (alternating add/sub with index
    weights) so that executing statements in a wrong order produces wrong
    values — silent reorder bugs cannot hide behind commutativity.
    """

    acc = 1.0
    for k, r in enumerate(reads):
        acc = acc + (r * (k + 1) if k % 2 == 0 else -r / (k + 2))
    return acc


@dataclasses.dataclass(frozen=True)
class Statement:
    """``write.array[i+write.offset] = f(reads...)``.

    ``name`` is the paper-style label (``"S1"``).  ``compute`` consumes the
    read values (in ``reads`` order) and returns the value to store.

    ``guard`` (optional) models the paper's control dependence δc (§2.1):
    the statement executes only if the guard access is positive at run time
    — e.g. ``guard=ArrayRef("p", -1)`` is ``if (p[i-1] > 0) S``.  The guard
    read participates in dependence analysis like any read, and the δc edge
    from the statement that *writes* the guard is emitted explicitly.
    """

    name: str
    write: ArrayRef
    reads: Tuple[ArrayRef, ...]
    compute: ComputeFn = _default_compute
    guard: Optional[ArrayRef] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "reads", tuple(self.reads))

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        rhs = " , ".join(str(r) for r in self.reads) or "..."
        return f"{self.name}: {self.write} <- f({rhs})"


@dataclasses.dataclass(frozen=True)
class LoopProgram:
    """``for i in [lo, hi) { statements }`` (rectangular nest when ndim>1).

    ``bounds`` is a sequence of (lo, hi) per loop dimension.  The paper's
    examples are 1-D (``for i=1; i<n; i++``); the pipeline-schedule lift uses
    2-D (stage, microbatch).
    """

    statements: Tuple[Statement, ...]
    bounds: Tuple[Tuple[int, int], ...] = ((1, 8),)

    def __post_init__(self) -> None:
        object.__setattr__(self, "statements", tuple(self.statements))
        object.__setattr__(
            self, "bounds", tuple((int(lo), int(hi)) for lo, hi in self.bounds)
        )
        ndim = len(self.bounds)
        for s in self.statements:
            refs = (s.write, *s.reads) + ((s.guard,) if s.guard else ())
            for ref in refs:
                if len(ref.offset_tuple()) != ndim:
                    raise ValueError(
                        f"{s.name}: access {ref} has rank "
                        f"{len(ref.offset_tuple())} but loop nest has rank {ndim}"
                    )

    # ------------------------------------------------------------------ #
    @property
    def ndim(self) -> int:
        return len(self.bounds)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.statements)

    def statement(self, name: str) -> Statement:
        for s in self.statements:
            if s.name == name:
                return s
        raise KeyError(name)

    def lexical_index(self, name: str) -> int:
        for k, s in enumerate(self.statements):
            if s.name == name:
                return k
        raise KeyError(name)

    def arrays(self) -> Tuple[str, ...]:
        seen = []
        for s in self.statements:
            refs = (s.write, *s.reads) + ((s.guard,) if s.guard else ())
            for ref in refs:
                if ref.array not in seen:
                    seen.append(ref.array)
        return tuple(seen)

    def iterations(self) -> Sequence[Tuple[int, ...]]:
        """All iteration points in lexicographic (sequential) order."""

        return iterations_of(self.bounds)

    # ------------------------------------------------------------------ #
    def initial_store(self, pad: int = 8) -> dict:
        """A deterministic initial memory image covering all accesses.

        Arrays are dense dicts ``{index_tuple: value}`` padded ``pad`` cells
        beyond the loop bounds on each side so that out-of-iteration reads
        (``b[i-2]`` at ``i=1``) hit initialized memory, as in Fortran dusty
        decks where arrays are pre-initialized.
        """

        store: dict = {}
        for arr in self.arrays():
            cells: dict = {}
            ranges = [range(lo - pad, hi + pad) for lo, hi in self.bounds]
            idxs: list[Tuple[int, ...]] = [()]
            for r in ranges:
                idxs = [p + (i,) for p in idxs for i in r]
            for idx in idxs:
                # deterministic pseudo-random-ish initial content
                h = hash((arr, idx)) % 1000003
                cells[idx] = (h % 97) / 7.0 - 5.0
            store[arr] = cells
        return store


def iterations_of(
    bounds: Sequence[Tuple[int, int]]
) -> list[Tuple[int, ...]]:
    """Iteration points of a rectangular nest in lexicographic order.

    The single definition of sequential iteration order —
    :meth:`LoopProgram.iterations` and the scheduling-policy cost model
    both delegate here, so the contract cannot silently diverge.
    """

    pts: list[Tuple[int, ...]] = [()]
    for lo, hi in bounds:
        pts = [p + (i,) for p in pts for i in range(lo, hi)]
    return pts


def run_sequential(prog: LoopProgram, store: Mapping[str, dict] | None = None) -> dict:
    """Execute ``prog`` exactly as written, sequentially.  The oracle."""

    mem = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    for point in prog.iterations():
        for s in prog.statements:
            if s.guard is not None:
                gidx = tuple(
                    p + o for p, o in zip(point, s.guard.offset_tuple())
                )
                if not mem[s.guard.array][gidx] > 0:
                    continue
            reads = [
                mem[r.array][tuple(p + o for p, o in zip(point, r.offset_tuple()))]
                for r in s.reads
            ]
            widx = tuple(p + o for p, o in zip(point, s.write.offset_tuple()))
            mem[s.write.array][widx] = s.compute(*reads)
    return mem


# ---------------------------------------------------------------------- #
# The paper's didactic programs (Algorithms 1, 4 and 6).
# ---------------------------------------------------------------------- #

def paper_alg1(n: int = 8) -> LoopProgram:
    """Alg. 1: the acyclic-dependence example (Fig. 3a)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            Statement("S2", ArrayRef("b", 0), (ArrayRef("c", -1),)),
            Statement(
                "S3",
                ArrayRef("t", 0),
                (ArrayRef("a", -1), ArrayRef("b", 0), ArrayRef("d", -2)),
            ),
            Statement("S4", ArrayRef("d", 0), (ArrayRef("b", -2),)),
        ),
        bounds=((1, n),),
    )


def paper_alg4(n: int = 8) -> LoopProgram:
    """Alg. 4: the cross-iteration cyclic example (Fig. 5)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            Statement("S2", ArrayRef("b", 0), (ArrayRef("c", -1),)),
            Statement(
                "S3", ArrayRef("c", 0), (ArrayRef("b", -2), ArrayRef("a", -1))
            ),
        ),
        bounds=((1, n),),
    )


def paper_alg6(n: int = 8) -> LoopProgram:
    """Alg. 6: the synchronization-elimination example (Fig. 6)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), ()),
            Statement("S2", ArrayRef("b", 0), (ArrayRef("c", -1),)),
            Statement("S3", ArrayRef("c", 0), (ArrayRef("a", -2),)),
        ),
        bounds=((1, n),),
    )
