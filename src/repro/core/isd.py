"""Iteration Space Diagram (ISD) construction and window sizing (paper §4.2).

The ISD is a graph over statement *instances* ``S_k(i)`` for iterations ``i``
in a bounded window.  Its edges are the orders that the parallel execution is
guaranteed to enforce:

  * **program order** within one iteration — code executes serially on the
    processor running that iteration (S_k(i) → S_{k+1}(i));
  * **synchronized dependences** — for each retained (synchronized) δ with
    distance Δ: source(δ)(i) → sink(δ)(i + Δ).

Window size (paper): "the number of iterations needed in the ISD for the loop
is equal to the least product of the unique prime factors of the dependence
distance, plus one."  For Alg. 6 (distances {2, 1}) that is 2 + 1 = 3 — the
dotted box of Fig. 6.  Because the enforced-order edges are shift-invariant,
covering every placement inside one window covers the whole iteration space.

All edges advance execution order monotonically (iteration vectors never
decrease, and lexical position strictly increases inside an iteration), so a
window of ``W + max|Δe|`` iterations suffices for the reachability queries.
"""

from __future__ import annotations

import dataclasses
from functools import reduce
from typing import Dict, Iterable, List, Sequence, Set, Tuple

from repro.core.dependence import Dependence
from repro.core.ir import LoopProgram

Instance = Tuple[str, Tuple[int, ...]]  # (statement name, iteration vector)


def prime_factors(n: int) -> Set[int]:
    n = abs(int(n))
    out: Set[int] = set()
    d = 2
    while d * d <= n:
        while n % d == 0:
            out.add(d)
            n //= d
        d += 1
    if n > 1:
        out.add(n)
    return out


def isd_window(distances: Iterable[int]) -> int:
    """Paper's window formula: product of unique prime factors across the
    dependence distances, plus one (distance 0/±1 contribute no primes)."""

    primes: Set[int] = set()
    max_d = 1
    for d in distances:
        primes |= prime_factors(d)
        max_d = max(max_d, abs(d))
    prod = reduce(lambda a, b: a * b, sorted(primes), 1)
    # never smaller than the longest distance + 1, so every dependence has at
    # least one full instance inside the window
    return max(prod + 1, max_d + 1)


@dataclasses.dataclass
class ISD:
    """Bounded-window instance graph with enforced-order edges."""

    program: LoopProgram
    window: Tuple[Tuple[int, int], ...]  # per-dim [lo, hi) of the window
    # adjacency: instance → list of (successor, tag); tag identifies which
    # enforced order produced the edge ("program-order" or the dependence)
    adj: Dict[Instance, List[Tuple[Instance, object]]]

    def successors(self, inst: Instance) -> List[Tuple[Instance, object]]:
        return self.adj.get(inst, [])

    def has_path(
        self, src: Instance, dst: Instance, *, forbidden_tag: object = None
    ) -> Tuple[bool, List[Instance]]:
        """BFS path search avoiding edges tagged ``forbidden_tag``.

        Returns (found, path) — the path is the witness recorded in
        benchmarks (e.g. the S1(2)→…→S3(4) chain of Fig. 6).
        """

        if src == dst:
            return True, [src]
        prev: Dict[Instance, Instance] = {}
        seen = {src}
        frontier = [src]
        while frontier:
            nxt: List[Instance] = []
            for u in frontier:
                for v, tag in self.successors(u):
                    if tag is forbidden_tag or v in seen:
                        continue
                    prev[v] = u
                    if v == dst:
                        path = [v]
                        while path[-1] != src:
                            path.append(prev[path[-1]])
                        return True, path[::-1]
                    seen.add(v)
                    nxt.append(v)
            frontier = nxt
        return False, []


def build_isd(
    prog: LoopProgram,
    enforced: Sequence[Dependence],
    window: Sequence[Tuple[int, int]],
    model: str = "doall",
    processors: Dict[str, object] | None = None,
) -> ISD:
    """Materialize the ISD over ``window`` with free-order + ``enforced``
    dependence edges.

    ``model`` selects which orders the machine enforces for free:

      * ``"doall"`` — each *iteration* runs on one processor (paper §2.2):
        program order within an iteration is free
        (S_k(i) → S_{k+1}(i));
      * ``"dswp"``  — each *statement* runs on one processor (decoupled
        software pipelining, paper §3.2 / Fig. 4): per-statement order across
        consecutive iterations is free (S_k(i) → S_k(i+1));
      * ``"procmap"`` — explicit statement→processor assignment via
        ``processors``: execution order on each processor (lexicographic
        (iteration, lexical position) over its statements) is free.  Used to
        model kernel pipelines where DMA issue shares the compute unit's
        instruction stream while the DMA engine is its own processor.

    Requires per-dimension non-negative distances (true for all 1-D paper
    programs after normalization and for pipeline schedules); raises
    otherwise so callers fall back to retaining the dep.
    """

    if model not in ("doall", "dswp", "procmap"):
        raise ValueError(f"unknown execution model {model!r}")
    if model == "procmap" and not processors:
        raise ValueError("procmap model requires a processors mapping")

    for d in enforced:
        if any(x < 0 for x in d.distance):
            raise ValueError(
                f"ISD transitive reduction requires per-dim non-negative "
                f"distances, got {d.pretty()}"
            )

    pts: List[Tuple[int, ...]] = [()]
    for lo, hi in window:
        pts = [p + (i,) for p in pts for i in range(lo, hi)]

    names = prog.names
    adj: Dict[Instance, List[Tuple[Instance, object]]] = {}

    def add(u: Instance, v: Instance, tag: object) -> None:
        adj.setdefault(u, []).append((v, tag))

    in_window = set(pts)
    for it in pts:
        if model == "doall":
            # program order within the iteration (one processor per iteration)
            for a, b in zip(names, names[1:]):
                add((a, it), (b, it), "program-order")
        elif model == "dswp":
            # per-statement processor order (one processor per statement);
            # successor iteration in lexicographic order within the window
            nxt = _next_point(it, window)
            if nxt is not None:
                for a in names:
                    add((a, it), (a, nxt), "processor-order")
        else:  # procmap
            pass  # handled below (needs per-processor global order)
        # enforced dependence edges
        for dep in enforced:
            dst_it = tuple(i + d for i, d in zip(it, dep.distance))
            if dst_it in in_window:
                add((dep.source, it), (dep.sink, dst_it), dep)

    if model == "procmap":
        by_proc: Dict[object, List[Instance]] = {}
        for name in names:
            by_proc.setdefault(processors[name], []).append(name)
        lex = {n: k for k, n in enumerate(names)}
        for proc, stmts in by_proc.items():
            seq = sorted(
                ((it, lex[s]) for it in pts for s in stmts),
                key=lambda t: (t[0], t[1]),
            )
            for (it_a, la), (it_b, lb) in zip(seq, seq[1:]):
                add((names[la], it_a), (names[lb], it_b), "processor-order")
    return ISD(program=prog, window=tuple(window), adj=adj)


def _next_point(
    it: Tuple[int, ...], window: Sequence[Tuple[int, int]]
) -> Tuple[int, ...] | None:
    """Lexicographic successor of ``it`` inside the rectangular window."""

    pt = list(it)
    for k in range(len(pt) - 1, -1, -1):
        lo, hi = window[k]
        if pt[k] + 1 < hi:
            pt[k] += 1
            return tuple(pt)
        pt[k] = lo
    return None
