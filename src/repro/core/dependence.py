"""Dependence analysis over :class:`repro.core.ir.LoopProgram`.

Implements the paper's §2.1/§3 definitions: for statement instances
``S_a^i`` and ``S_b^j``,

  * flow   (``S_a δf S_b``): S_a assigns a value that S_b may later read;
  * anti   (``S_a δa S_b``): S_a fetches a value that S_b may later write;
  * output (``S_a δo S_b``): S_a modifies a value that S_b may later modify.

With affine accesses ``x[i + o]`` and constant offsets, every conflicting
pair induces a *constant dependence distance* Δ = (iteration of sink) −
(iteration of source).  Sequential execution order is lexicographic over the
iteration vector, tie-broken by lexical statement order, so the dependence
runs from the instance that executes first to the one that executes later —
a negative raw distance between a write and a later-lexical read flips the
pair into an antidependence with positive distance, per the classical
definitions the paper cites ([7], [15], [16]).

Only dependences with Δ ≥ 0 exist after this normalization (Δ lexicographic-
nonnegative for nests); Δ = 0 dependences are loop-independent and enforced by
intra-iteration program order (the paper: "code executes serially on a given
processor, ... only dependence with a distance greater than zero need to be
synchronized explicitly").
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

from repro.core.ir import LoopProgram, Statement, is_indirect

FLOW = "flow"
ANTI = "anti"
OUTPUT = "output"
CONTROL = "control"


@dataclasses.dataclass(frozen=True)
class Dependence:
    """A statement-level dependence with constant distance vector.

    ``nonaffine=True`` marks a *conservative proxy* for a conflict through an
    indirect subscript (``a[idx[i]]``): the true runtime distance is unknown,
    so the analyzer emits Δ=1 proxies in both directions (plus the Δ=0
    program-order case), which transitively serialize every possible runtime
    distance.  Non-affine proxies are never fed to the elimination
    algorithms (their distance is not a real constant) and are the exact set
    the inspector (:mod:`repro.core.inspector`) replaces with instance-level
    edges under ``deps="inspect"``.
    """

    kind: str
    source: str
    sink: str
    array: str
    distance: Tuple[int, ...]
    nonaffine: bool = False

    # ------------------------------------------------------------------ #
    @property
    def delta(self) -> int:
        """Scalar distance for 1-D loops (the paper's Δ)."""

        if len(self.distance) != 1:
            raise ValueError("delta is only defined for 1-D loop programs")
        return self.distance[0]

    @property
    def loop_carried(self) -> bool:
        return any(d != 0 for d in self.distance)

    def lexically_backward(self, prog: LoopProgram) -> bool:
        """True iff the sink *precedes* the source in program text (§4.2 iii)."""

        return prog.lexical_index(self.sink) < prog.lexical_index(self.source)

    def pretty(self) -> str:
        d = self.distance[0] if len(self.distance) == 1 else self.distance
        sym = {FLOW: "δf", ANTI: "δa", OUTPUT: "δo", CONTROL: "δc"}[self.kind]
        if self.nonaffine:
            sym += "~"  # conservative non-affine proxy, Δ is an upper bound
        return f"{self.source} {sym}({self.array}, Δ={d}) {self.sink}"


def _lex_nonneg(vec: Tuple[int, ...]) -> bool:
    """Lexicographic ``vec >= 0``."""

    for v in vec:
        if v > 0:
            return True
        if v < 0:
            return False
    return True


def _neg(vec: Tuple[int, ...]) -> Tuple[int, ...]:
    return tuple(-v for v in vec)


def _oriented(
    prog: LoopProgram,
    first: Statement,
    second: Statement,
    raw: Tuple[int, ...],
    kind_fwd: str,
    kind_bwd: str,
    array: str,
) -> Dependence | None:
    """Orient a conflicting access pair into a dependence running forward in
    sequential execution order.

    ``raw`` is (iteration of ``second``) − (iteration of ``first``) for the
    conflicting instances.  ``kind_fwd`` is the dependence kind when ``first``
    executes before ``second``; ``kind_bwd`` when the order is reversed.
    """

    zero = all(v == 0 for v in raw)
    if zero:
        a, b = prog.lexical_index(first.name), prog.lexical_index(second.name)
        if a == b:
            return None  # same-instance conflict: intra-statement, no dep
        if a < b:
            return Dependence(kind_fwd, first.name, second.name, array, raw)
        return Dependence(kind_bwd, second.name, first.name, array, raw)
    if _lex_nonneg(raw):
        return Dependence(kind_fwd, first.name, second.name, array, raw)
    return Dependence(kind_bwd, second.name, first.name, array, _neg(raw))


def _nonaffine_proxies(
    prog: LoopProgram,
    sa: Statement,
    sb: Statement,
    kind_fwd: str,
    kind_bwd: str,
    array: str,
) -> List[Dependence]:
    """Conservative proxies for a conflict whose distance is not a constant.

    Δ=1 proxies in both directions chain transitively (with the free
    intra-iteration program order) into a cover of *every* runtime distance;
    the Δ=0 case between distinct statements follows lexical order so the
    dswp model (which synchronizes Δ=0 cross-statement deps too) stays sound.
    ``kind_fwd`` is the dependence kind when ``sa``'s access happens first.
    """

    out = [
        Dependence(kind_fwd, sa.name, sb.name, array, (1,), nonaffine=True),
        Dependence(kind_bwd, sb.name, sa.name, array, (1,), nonaffine=True),
    ]
    ia, ib = prog.lexical_index(sa.name), prog.lexical_index(sb.name)
    if ia < ib:
        out.append(
            Dependence(kind_fwd, sa.name, sb.name, array, (0,), nonaffine=True)
        )
    elif ib < ia:
        out.append(
            Dependence(kind_bwd, sb.name, sa.name, array, (0,), nonaffine=True)
        )
    return out


def analyze(prog: LoopProgram) -> List[Dependence]:
    """All flow/anti/output dependences of ``prog``.

    Affine conflicting pairs get constant distances; pairs involving an
    indirect access get non-affine Δ=1/Δ=0 proxies (see
    :func:`_nonaffine_proxies`).
    """

    deps: List[Dependence] = []
    for sa in prog.statements:
        wa = sa.write.offset_tuple()
        for sb in prog.statements:
            # write(sa) vs guard-read(sb): the paper's control dependence δc
            # (whether sb executes depends on sa's outcome) — same distance
            # arithmetic as a flow dep, but tagged CONTROL; the mirrored
            # guard-read-before-write case is an ordinary anti dependence.
            if sb.guard is not None and sb.guard.array == sa.write.array:
                if is_indirect(sa.write):
                    deps.extend(
                        _nonaffine_proxies(
                            prog, sa, sb, CONTROL, ANTI, sa.write.array
                        )
                    )
                else:
                    raw = tuple(
                        w - r for w, r in zip(wa, sb.guard.offset_tuple())
                    )
                    d = _oriented(
                        prog, sa, sb, raw, CONTROL, ANTI, sa.write.array
                    )
                    if d is not None:
                        deps.append(d)
            # write(sa) vs read(sb): flow if write first, anti if read first
            for ref in sb.reads:
                if ref.array != sa.write.array:
                    continue
                if is_indirect(sa.write) or is_indirect(ref):
                    deps.extend(
                        _nonaffine_proxies(prog, sa, sb, FLOW, ANTI, ref.array)
                    )
                    continue
                raw = tuple(w - r for w, r in zip(wa, ref.offset_tuple()))
                d = _oriented(prog, sa, sb, raw, FLOW, ANTI, ref.array)
                if d is not None:
                    deps.append(d)
            # write(sa) vs write(sb): output (count each unordered pair once)
            if sb.write.array == sa.write.array:
                ia, ib = prog.lexical_index(sa.name), prog.lexical_index(sb.name)
                either_indirect = is_indirect(sa.write) or is_indirect(sb.write)
                if ia < ib:
                    if either_indirect:
                        deps.extend(
                            _nonaffine_proxies(
                                prog, sa, sb, OUTPUT, OUTPUT, sa.write.array
                            )
                        )
                    else:
                        raw = tuple(
                            w - v for w, v in zip(wa, sb.write.offset_tuple())
                        )
                        d = _oriented(
                            prog, sa, sb, raw, OUTPUT, OUTPUT, sa.write.array
                        )
                        if d is not None:
                            deps.append(d)
                elif ia == ib:
                    # same statement: impossible with a single constant-offset
                    # write — but an indirect write may revisit a cell, so it
                    # carries a self output dependence of unknown distance
                    if is_indirect(sa.write):
                        deps.append(
                            Dependence(
                                OUTPUT,
                                sa.name,
                                sa.name,
                                sa.write.array,
                                (1,),
                                nonaffine=True,
                            )
                        )
    return _dedup(deps)


def _dedup(deps: Iterable[Dependence]) -> List[Dependence]:
    seen = set()
    out: List[Dependence] = []
    for d in deps:
        key = (d.kind, d.source, d.sink, d.array, d.distance, d.nonaffine)
        if key not in seen:
            seen.add(key)
            out.append(d)
    return out


def loop_carried(deps: Iterable[Dependence]) -> List[Dependence]:
    """Only the cross-iteration dependences (Δ ≠ 0) — the ones that need
    explicit synchronization (paper §3.1)."""

    return [d for d in deps if d.loop_carried]


def paper_alg4_dependences() -> List[Dependence]:
    """The dependence graph *as stated in the paper* for Alg. 4
    (δf Δa=1; δf Δb=2; δf Δc=1).

    Note: our analyzer additionally finds ``S2 δf(b, Δ=1) S1`` (S1 reads
    b[i-1] which S2 writes) — the paper's Fig. 5 / Alg. 5 overlook it, which
    leaves Alg. 5 under-synchronized (demonstrable race; see
    tests/test_executor.py::test_paper_alg5_misses_a_dependence).  We keep
    this helper so the faithful Alg. 5 reproduction can be generated from the
    paper's own graph.
    """

    return [
        Dependence(FLOW, "S1", "S3", "a", (1,)),
        Dependence(FLOW, "S2", "S3", "b", (2,)),
        Dependence(FLOW, "S3", "S2", "c", (1,)),
    ]
