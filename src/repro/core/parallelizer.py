"""End-to-end auto-parallelization pipeline (paper §5's four steps).

  1. build the dependence graph;
  2. pick a synchronization strategy (here: send/wait, per §4);
  3. insert synchronization for every loop-carried dependence;
  4. eliminate partial dependences and optimize the sync instructions.

:func:`parallelize` composes the whole flow and reports before/after sync
counts — the framework's public compiler entry point, also used by the
pipeline-schedule lift (:mod:`repro.core.schedule`) and the Pallas kernel
schedule generator.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.dependence import Dependence, analyze, loop_carried
from repro.core.elimination import (
    EliminationResult,
    eliminate_pattern,
    eliminate_transitive,
)
from repro.core.fission import FissionResult, fission
from repro.core.ir import LoopProgram
from repro.core.sync import SyncProgram, insert_synchronization, strip_dependences
from repro.core.wavefront import WavefrontSchedule, schedule_wavefronts

BACKENDS = ("threaded", "wavefront")


@dataclasses.dataclass(frozen=True)
class ParallelizationReport:
    program: LoopProgram
    dependences: Tuple[Dependence, ...]
    fission: FissionResult
    naive_sync: SyncProgram
    elimination: EliminationResult
    optimized_sync: SyncProgram
    backend: str = "threaded"
    # level schedule of the optimized sync program (backend="wavefront" only)
    wavefront: Optional[WavefrontSchedule] = None

    def summary(self) -> dict:
        naive = self.naive_sync.sync_instruction_count()
        opt = self.optimized_sync.sync_instruction_count()
        out = {
            "dependences": len(self.dependences),
            "loop_carried": len(loop_carried(self.dependences)),
            "eliminated": len(self.elimination.eliminated),
            "naive_sync_instructions": naive["total"],
            "optimized_sync_instructions": opt["total"],
            "naive_runtime_sync_ops": self.naive_sync.runtime_sync_ops(),
            "optimized_runtime_sync_ops": self.optimized_sync.runtime_sync_ops(),
            "method": self.elimination.method,
            "backend": self.backend,
        }
        if self.wavefront is not None:
            out["wavefront_depth"] = self.wavefront.depth
            out["wavefront_batched_ops"] = self.wavefront.batched_ops
        return out


def parallelize(
    prog: LoopProgram,
    *,
    method: str = "isd",
    deps: Optional[Sequence[Dependence]] = None,
    merge_sends: bool = False,
    backend: str = "threaded",
) -> ParallelizationReport:
    """Run the full §5 pipeline.

    ``method``: ``"isd"`` (transitive reduction), ``"pattern"`` (Li &
    Abu-Sufah matching), ``"both"`` (pattern first — cheap — then ISD on the
    survivors), or ``"none"`` (naive synchronization only).

    ``backend``: ``"threaded"`` targets the send/wait machine
    (:func:`repro.core.executor.run_threaded`); ``"wavefront"`` additionally
    compiles the optimized sync program to a dependence-level schedule for
    :func:`repro.core.wavefront.run_wavefront` — O(depth) vectorized steps
    instead of O(iterations) threads.
    """

    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}"
        )

    dep_list = list(deps) if deps is not None else analyze(prog)
    fiss = fission(prog, dep_list)
    naive = insert_synchronization(prog, dep_list, merge=False)

    if method == "none":
        elim = EliminationResult(
            retained=tuple(loop_carried(dep_list)),
            eliminated=(),
            witnesses={},
            method="none",
        )
    elif method == "isd":
        elim = eliminate_transitive(prog, dep_list)
    elif method == "pattern":
        elim = eliminate_pattern(prog, dep_list)
    elif method == "both":
        first = eliminate_pattern(prog, dep_list)
        second = eliminate_transitive(prog, list(first.retained))
        elim = EliminationResult(
            retained=second.retained,
            eliminated=first.eliminated + second.eliminated,
            witnesses=second.witnesses,
            method="pattern+isd",
        )
    else:
        raise ValueError(f"unknown elimination method: {method!r}")

    optimized = strip_dependences(naive, elim.eliminated)
    if merge_sends:
        optimized = insert_synchronization(
            prog, list(elim.retained), merge=True
        )
    wavefront = None
    if backend == "wavefront":
        wavefront = schedule_wavefronts(optimized, list(elim.retained))
    return ParallelizationReport(
        program=prog,
        dependences=tuple(dep_list),
        fission=fiss,
        naive_sync=naive,
        elimination=elim,
        optimized_sync=optimized,
        backend=backend,
        wavefront=wavefront,
    )
