"""End-to-end auto-parallelization pipeline (paper §5's four steps).

  1. build the dependence graph;
  2. pick a synchronization strategy (here: send/wait, per §4);
  3. insert synchronization for every loop-carried dependence;
  4. eliminate partial dependences and optimize the sync instructions.

:func:`parallelize` composes the whole flow and reports before/after sync
counts — the framework's public compiler entry point, also used by the
pipeline-schedule lift (:mod:`repro.core.schedule`) and the Pallas kernel
schedule generator.

Execution backends are a *registry* (:func:`register_backend`), not a fixed
tuple: each :class:`BackendSpec` knows how to prepare backend-specific report
artifacts at parallelize time and how to execute a SyncProgram for the
differential harness (``tests/oracle.py`` iterates every registered backend,
so a new backend is differentially tested with zero per-test changes).
Built-ins: ``threaded`` (the paper's send/wait machine), ``wavefront`` (the
NumPy level interpreter), and — loaded lazily from :mod:`repro.compile` —
``xla`` (the structurally cached jitted level loop).

Because steps 1–4 depend on the statement graph but not the loop bounds (the
elimination window is derived from dependence distances), the expensive
elimination result is memoized per (statement graph, lower bounds, method):
repeated requests with the same structure — the serving path re-planning its
decode loop each batch wave — skip re-analysis entirely.
"""

from __future__ import annotations

import collections
import copy
import dataclasses
import functools
import importlib
import threading
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.core.dependence import Dependence, analyze, loop_carried
from repro.core.elimination import (
    EliminationResult,
    eliminate_pattern,
    eliminate_transitive,
)
from repro.core.executor import run_threaded
from repro.core.fission import FissionResult, fission
from repro.core.ir import LoopProgram
from repro.core.policy import resolve_policy
from repro.core.scc import validate_retained
from repro.core.sync import SyncProgram, insert_synchronization, strip_dependences
from repro.core.wavefront import (
    WavefrontSchedule,
    run_wavefront,
    schedule_wavefronts,
)


# ---------------------------------------------------------------------- #
# Backend registry
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One execution backend.

    ``prepare(optimized_sync, retained, **options)`` runs at parallelize
    time and returns extra :class:`ParallelizationReport` fields (e.g. the
    wavefront schedule, the compiled artifact); ``options`` carries the
    scheduling knobs (``chunk_limit``, ``scc_policy``) the caller passed to
    :func:`parallelize`.  ``differential(sync, *, store, stalls=None)``
    executes a SyncProgram and returns its final store — the hook
    ``tests/oracle.py`` uses to bit-compare every backend against the
    sequential oracle.
    """

    name: str
    prepare: Optional[Callable[..., Dict[str, object]]] = None
    differential: Optional[Callable[..., Mapping[str, dict]]] = None
    description: str = ""


_REGISTRY: Dict[str, BackendSpec] = {}

# Backends that register themselves on first use (import side effect), so
# e.g. requesting "xla" does not cost a jax import until someone asks for it.
_LAZY_BACKENDS: Dict[str, str] = {"xla": "repro.compile"}


def register_backend(spec: BackendSpec) -> None:
    """Register (or replace) an execution backend under ``spec.name``."""

    _REGISTRY[spec.name] = spec


def registered_backends() -> Tuple[str, ...]:
    """All backend names, including lazy ones not yet imported."""

    return tuple(_REGISTRY) + tuple(
        n for n in _LAZY_BACKENDS if n not in _REGISTRY
    )


def get_backend(name: str) -> BackendSpec:
    """Resolve a backend spec, importing lazy providers on demand."""

    spec = _REGISTRY.get(name)
    if spec is None and name in _LAZY_BACKENDS:
        importlib.import_module(_LAZY_BACKENDS[name])
        spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {registered_backends()}"
        )
    return spec


def execution_backends() -> Dict[str, BackendSpec]:
    """Name → spec for every backend with a differential runner (resolves
    lazy providers) — the iteration surface of ``tests/oracle.py``."""

    for name in registered_backends():
        get_backend(name)
    return {
        name: spec
        for name, spec in _REGISTRY.items()
        if spec.differential is not None
    }


register_backend(
    BackendSpec(
        name="threaded",
        prepare=None,
        differential=lambda sync, *, store=None, stalls=None: run_threaded(
            sync, stalls=stalls, store=store, compare=False
        ).store,
        description="one thread per iteration, send/wait only (the paper's machine)",
    )
)

register_backend(
    BackendSpec(
        name="wavefront",
        prepare=lambda optimized, retained, **options: {
            "wavefront": schedule_wavefronts(
                optimized,
                list(retained),
                chunk_limit=options.get("chunk_limit"),
                scc_policy=options.get("scc_policy"),
            )
        },
        differential=lambda sync, *, store=None, stalls=None: run_wavefront(
            sync, store=store, compare=False
        ).store,
        description="NumPy dependence-level interpreter (O(depth) batched steps)",
    )
)


# ---------------------------------------------------------------------- #
# Bounds-free analysis memo
# ---------------------------------------------------------------------- #

# bounded like the compile caches: a long-running server with varying
# request structures must not accumulate elimination results forever (and
# locked like them — concurrent serving threads share this memo)
_ANALYSIS_MEMO: "collections.OrderedDict[Tuple, EliminationResult]" = (
    collections.OrderedDict()
)
_ANALYSIS_MEMO_MAX = 256
_ANALYSIS_STATS = {"hits": 0, "misses": 0}
_ANALYSIS_LOCK = threading.Lock()


def analysis_cache_stats() -> Dict[str, int]:
    with _ANALYSIS_LOCK:
        return dict(_ANALYSIS_STATS)


def clear_analysis_cache() -> None:
    with _ANALYSIS_LOCK:
        _ANALYSIS_MEMO.clear()
        _ANALYSIS_STATS.update(hits=0, misses=0)


def _eliminate(
    prog: LoopProgram, dep_list: Sequence[Dependence], method: str
) -> EliminationResult:
    if method == "none":
        return EliminationResult(
            retained=tuple(loop_carried(dep_list)),
            eliminated=(),
            witnesses={},
            method="none",
        )
    if method == "isd":
        return eliminate_transitive(prog, dep_list)
    if method == "pattern":
        return eliminate_pattern(prog, dep_list)
    if method == "both":
        first = eliminate_pattern(prog, dep_list)
        second = eliminate_transitive(prog, list(first.retained))
        return EliminationResult(
            retained=second.retained,
            eliminated=first.eliminated + second.eliminated,
            witnesses=second.witnesses,
            method="pattern+isd",
        )
    raise ValueError(f"unknown elimination method: {method!r}")


def _memoized_eliminate(
    prog: LoopProgram, dep_list: Sequence[Dependence], method: str
) -> EliminationResult:
    """Elimination keyed by (statement graph, lower bounds, deps, method).

    The ISD window is derived from dependence distances and anchored at the
    loop *lower* bounds, so the result — including witness paths — is
    invariant under any change of the upper bounds (iteration count).
    """

    from repro.compile.structure import program_fingerprint

    key = (
        program_fingerprint(prog),
        tuple(lo for lo, _hi in prog.bounds),
        method,
        tuple(dep_list),
    )
    with _ANALYSIS_LOCK:
        hit = _ANALYSIS_MEMO.get(key)
        if hit is not None:
            _ANALYSIS_MEMO.move_to_end(key)
            _ANALYSIS_STATS["hits"] += 1
            return hit
    elim = _eliminate(prog, dep_list, method)  # built outside the lock
    with _ANALYSIS_LOCK:
        _ANALYSIS_MEMO[key] = elim
        while len(_ANALYSIS_MEMO) > _ANALYSIS_MEMO_MAX:
            _ANALYSIS_MEMO.popitem(last=False)
        _ANALYSIS_STATS["misses"] += 1
    return elim


def _accepted_options(
    prepare: Callable[..., Dict[str, object]], options: Dict[str, object]
) -> Dict[str, object]:
    """The subset of scheduling-knob kwargs ``prepare`` can receive.

    Backends registered before the knobs existed declared
    ``prepare(optimized, retained)`` — the registry is public API, so a
    legacy registrant must keep working (it simply never sees the knobs)
    instead of dying on an unexpected keyword argument.  The signature
    reflection is memoized per callable: the serving loop re-plans through
    here twice per wave, and warm plans are sub-millisecond.
    """

    accepted = _accepted_option_names(prepare)
    if accepted is None:
        return options
    return {k: v for k, v in options.items() if k in accepted}


@functools.lru_cache(maxsize=64)
def _accepted_option_names(
    prepare: Callable[..., Dict[str, object]]
) -> Optional[frozenset]:
    """``None`` = pass everything (``**kwargs`` or un-inspectable)."""

    import inspect

    try:
        params = inspect.signature(prepare).parameters
    except (TypeError, ValueError):  # C callables etc.: assume modern
        return None
    if any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    ):
        return None
    return frozenset(params)


# ---------------------------------------------------------------------- #
# Report + entry point
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ParallelizationReport:
    program: LoopProgram
    dependences: Tuple[Dependence, ...]
    fission: FissionResult
    naive_sync: SyncProgram
    elimination: EliminationResult
    optimized_sync: SyncProgram
    backend: str = "threaded"
    # level schedule of the optimized sync program (backend="wavefront" only)
    wavefront: Optional[WavefrontSchedule] = None
    # structural-cache artifact (backend="xla" only): repro.compile handle
    compiled: Optional[object] = None
    # scheduling knobs this report was planned under (echoed into the
    # statement-level SCC summary for backends without a schedule)
    chunk_limit: Optional[int] = None
    scc_policy: object = None

    @functools.cached_property
    def _statement_scc_summary(self) -> dict:
        """SCC partition + strategy records for backends without a schedule.

        Cached on the report: the cost model's exact-depth estimates make a
        fresh ``analyze_sccs`` of a recurrence-bearing program an
        O(instances) pass, too heavy to redo on every ``summary()`` call
        (cached_property writes to ``__dict__``, which a frozen dataclass
        permits — same pattern as WavefrontSchedule's cached stats).
        """

        from repro.core.scc import analyze_sccs

        return analyze_sccs(
            self.program,
            self.elimination.retained,
            chunk_limit=self.chunk_limit,
            scc_policy=self.scc_policy,
        ).summary()

    def summary(self) -> dict:
        naive = self.naive_sync.sync_instruction_count()
        opt = self.optimized_sync.sync_instruction_count()
        out = {
            "dependences": len(self.dependences),
            "loop_carried": len(loop_carried(self.dependences)),
            "eliminated": len(self.elimination.eliminated),
            "naive_sync_instructions": naive["total"],
            "optimized_sync_instructions": opt["total"],
            "naive_runtime_sync_ops": self.naive_sync.runtime_sync_ops(),
            "optimized_runtime_sync_ops": self.optimized_sync.runtime_sync_ops(),
            "method": self.elimination.method,
            "backend": self.backend,
        }
        if self.wavefront is not None and self.wavefront.scc is not None:
            out["scc"] = self.wavefront.scc.summary()
        else:
            # deep copy: the cached dict must not be mutable through the
            # return value, or one caller's annotation would leak into
            # every later summary() of this report
            out["scc"] = copy.deepcopy(self._statement_scc_summary)
        if self.wavefront is not None:
            out["wavefront_depth"] = self.wavefront.depth
            out["wavefront_batched_ops"] = self.wavefront.batched_ops
        if self.compiled is not None:
            out["compile_key"] = self.compiled.key[:16]
            out["compile_cache"] = self.compiled.cache_stats()
        return out


def parallelize(
    prog: LoopProgram,
    *,
    method: str = "isd",
    deps: Optional[Sequence[Dependence]] = None,
    merge_sends: bool = False,
    backend: str = "threaded",
    chunk_limit: Optional[int] = None,
    scc_policy: object = None,
) -> ParallelizationReport:
    """Run the full §5 pipeline.

    ``method``: ``"isd"`` (transitive reduction), ``"pattern"`` (Li &
    Abu-Sufah matching), ``"both"`` (pattern first — cheap — then ISD on the
    survivors), or ``"none"`` (naive synchronization only).

    ``backend``: any registered backend name (:func:`registered_backends`).
    ``"threaded"`` targets the send/wait machine
    (:func:`repro.core.executor.run_threaded`); ``"wavefront"`` additionally
    compiles the optimized sync program to a dependence-level schedule for
    :func:`repro.core.wavefront.run_wavefront`; ``"xla"`` resolves the
    structural compile cache (:mod:`repro.compile`) and attaches the
    compiled artifact to the report — repeated structurally equal requests
    share the artifact and skip re-analysis (see the ``compile_cache``
    counters in :meth:`ParallelizationReport.summary`).

    ``chunk_limit`` caps the DOACROSS chunk of chunked recurrence SCCs;
    ``scc_policy`` selects the per-SCC recurrence strategy (``None``/
    ``"auto"`` = cost model, ``"chunk"``/``"skew"``/``"dswp"`` forces one, a
    :class:`~repro.core.policy.SchedulingPolicy` instance plugs in).  Both
    are validated here, at the pipeline entry, so a bad knob fails with a
    clear message instead of deep inside ``schedule_levels``.
    """

    spec = get_backend(backend)
    if chunk_limit is not None and (
        not isinstance(chunk_limit, int)
        or isinstance(chunk_limit, bool)
        or chunk_limit < 1
    ):
        raise ValueError(
            f"chunk_limit must be a positive integer or None, got "
            f"{chunk_limit!r} — a chunk of zero iterations cannot make "
            "progress (use chunk_limit=1 for fully sequential chunks)"
        )
    resolve_policy(scc_policy)  # raises ValueError with the allowed values

    dep_list = list(deps) if deps is not None else analyze(prog)
    fiss = fission(prog, dep_list)
    naive = insert_synchronization(prog, dep_list, merge=False)

    elim = _memoized_eliminate(prog, dep_list, method)

    # Genuinely unschedulable retained sets (lexicographically negative /
    # backward-zero distances — a cyclic Δ-sign mix no machine can honor)
    # fail HERE, at compile time, for every backend: the threaded machine
    # would deadlock mid-execution and the schedulers would reject later
    # with less context.  repro.core.scc raises with the offending SCC's
    # statements and a witness cycle.
    validate_retained(prog, elim.retained)

    optimized = strip_dependences(naive, elim.eliminated)
    if merge_sends:
        optimized = insert_synchronization(
            prog, list(elim.retained), merge=True
        )
    extra = {}
    if spec.prepare:
        options = {"chunk_limit": chunk_limit, "scc_policy": scc_policy}
        extra = spec.prepare(
            optimized, elim.retained, **_accepted_options(spec.prepare, options)
        )
    return ParallelizationReport(
        program=prog,
        dependences=tuple(dep_list),
        fission=fiss,
        naive_sync=naive,
        elimination=elim,
        optimized_sync=optimized,
        backend=backend,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
        **extra,
    )
