"""End-to-end auto-parallelization pipeline (paper §5's four steps).

  1. build the dependence graph;
  2. pick a synchronization strategy (here: send/wait, per §4);
  3. insert synchronization for every loop-carried dependence;
  4. eliminate partial dependences and optimize the sync instructions.

The public surface is a *staged* pipeline mirroring that structure:

  * :class:`PlanOptions` — a frozen, validated, hashable bundle of the
    analysis knobs (``method``/``deps``/``merge_sends``/``chunk_limit``/
    ``scc_policy``/``model``/``processors``);
  * :func:`plan` — runs steps 1–4 exactly once and returns a
    :class:`SyncPlan`, the backend-independent artifact (dependences,
    fission, naive and optimized sync, elimination with witnesses, retained
    validation);
  * :meth:`SyncPlan.compile` — targets one registered backend, checking the
    requested options against the backend's *capability contract*
    (:attr:`BackendSpec.accepts`; unknown options raise ``ValueError``
    instead of being silently dropped) and consulting its cost hook
    (:attr:`BackendSpec.level_cost`) so the same plan can schedule
    differently per machine;
  * :class:`Executable` — a uniform ``run(store=None, stalls=None)`` /
    ``report()`` contract across threaded / wavefront / xla.

:func:`parallelize` survives as a thin compatibility shim over
``plan(...).compile(...).report()`` — bit-identical reports, same structural
compile-cache keys — and emits a ``DeprecationWarning`` so in-repo call
sites stay on the staged API (the fast CI job escalates that warning to an
error).

Execution backends are a *registry* (:func:`register_backend`), not a fixed
tuple: each :class:`BackendSpec` knows how to prepare backend-specific
artifacts at compile time and how to execute a SyncProgram for the
differential harness (``tests/oracle.py`` iterates every registered backend,
so a new backend is differentially tested with zero per-test changes).
Built-ins: ``threaded`` (the paper's send/wait machine), ``wavefront`` (the
NumPy level interpreter), and — loaded lazily from :mod:`repro.compile` —
``xla`` (the structurally cached jitted level loop, whose
``level_cost`` hook models its near-flat narrow-band step cost).

Because steps 1–4 depend on the statement graph but not the loop bounds (the
elimination window is derived from dependence distances), the expensive
elimination result is memoized per (statement graph, lower bounds, deps,
method, execution model): repeated ``plan`` requests with the same structure
— the serving path re-planning its decode loop each batch wave — skip
re-analysis entirely.
"""

from __future__ import annotations

import collections
import copy
import dataclasses
import functools
import importlib
import threading
import warnings
from typing import (
    Callable,
    Dict,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.obs import metrics as _metrics
from repro.obs import obs_summary
from repro.obs import trace as _trace
from repro.core.dependence import Dependence, analyze, loop_carried
from repro.core.elimination import (
    EliminationResult,
    eliminate_pattern,
    eliminate_transitive,
)
from repro.core.executor import run_threaded
from repro.core.fission import FissionResult, fission
from repro.core.ir import LoopProgram
from repro.core.policy import LevelCostFn, SccPolicyLike, resolve_policy
from repro.core.scc import validate_retained
from repro.core.sync import SyncProgram, insert_synchronization, strip_dependences
from repro.core.wavefront import (
    WavefrontSchedule,
    run_wavefront,
    schedule_wavefronts,
)


# ---------------------------------------------------------------------- #
# Backend registry
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One execution backend and its capability contract.

    ``prepare(optimized_sync, retained, **options)`` runs at compile time
    and returns extra artifacts (e.g. the wavefront schedule, the compiled
    XLA handle); ``options`` carries the scheduling knobs the caller passed
    through :meth:`SyncPlan.compile`.

    ``accepts`` is the backend's *declared* capability contract: the option
    names ``compile``/``parallelize`` may forward to ``prepare``.  A
    requested option outside the contract raises ``ValueError`` naming the
    backend and its accepted options — never a silent drop.  ``None`` means
    "infer from the prepare signature" (the legacy-registrant default: a
    ``prepare(optimized, retained)`` from before the knobs existed simply
    accepts nothing, and passing it a knob is now an error rather than a
    no-op).

    ``level_cost(plan, ctx) -> float`` is the backend's per-SCC cost hook:
    the scheduling policy engine's default cost model scores each strategy
    offer through it, so the same :class:`SyncPlan` can pick ``chunk`` on a
    machine with width-proportional step cost (xla) where an interpreter
    with per-level dispatch cost (wavefront) picks ``skew``.

    ``differential(sync, *, store, stalls=None)`` executes an arbitrary
    SyncProgram and returns its final store — the hook ``tests/oracle.py``
    uses to bit-compare every backend against the sequential oracle.
    ``run(sync, artifacts, *, store, stalls=None)`` is the
    :class:`Executable` runner: like ``differential`` but handed the
    prepared artifacts so warm executions reuse the schedule / compiled
    handle instead of re-planning.
    """

    name: str
    prepare: Optional[Callable[..., Dict[str, object]]] = None
    differential: Optional[Callable[..., Mapping[str, dict]]] = None
    description: str = ""
    accepts: Optional[Tuple[str, ...]] = None
    level_cost: Optional[LevelCostFn] = None
    run: Optional[Callable[..., Mapping[str, dict]]] = None


_REGISTRY: Dict[str, BackendSpec] = {}

# Backends that register themselves on first use (import side effect), so
# e.g. requesting "xla" does not cost a jax import until someone asks for it.
_LAZY_BACKENDS: Dict[str, str] = {
    "xla": "repro.compile",
    "xla_spmd": "repro.compile.spmd",
}


def register_backend(spec: BackendSpec) -> None:
    """Register (or replace) an execution backend under ``spec.name``."""

    _REGISTRY[spec.name] = spec


def registered_backends() -> Tuple[str, ...]:
    """All backend names, including lazy ones not yet imported."""

    return tuple(_REGISTRY) + tuple(
        n for n in _LAZY_BACKENDS if n not in _REGISTRY
    )


def get_backend(name: str) -> BackendSpec:
    """Resolve a backend spec, importing lazy providers on demand."""

    spec = _REGISTRY.get(name)
    if spec is None and name in _LAZY_BACKENDS:
        importlib.import_module(_LAZY_BACKENDS[name])
        spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {registered_backends()}"
        )
    return spec


def execution_backends() -> Dict[str, BackendSpec]:
    """Name → spec for every backend with a differential runner (resolves
    lazy providers) — the iteration surface of ``tests/oracle.py``."""

    for name in registered_backends():
        get_backend(name)
    return {
        name: spec
        for name, spec in _REGISTRY.items()
        if spec.differential is not None
    }


def backend_accepted_options(spec: BackendSpec) -> Optional[Tuple[str, ...]]:
    """The backend's effective capability contract.

    The declared :attr:`BackendSpec.accepts` wins; specs without a
    declaration fall back to reflecting the ``prepare`` signature (a legacy
    registrant's ``prepare(optimized, retained)`` accepts nothing; a
    ``**kwargs`` prepare accepts everything, signalled as ``None``).
    """

    if spec.accepts is not None:
        return tuple(spec.accepts)
    if spec.prepare is None:
        return ()
    inferred = _accepted_option_names(spec.prepare)
    if inferred is None:
        return None  # **kwargs / un-inspectable: accepts everything
    return tuple(sorted(inferred))


@functools.lru_cache(maxsize=64)
def _accepted_option_names(
    prepare: Callable[..., Dict[str, object]]
) -> Optional[frozenset]:
    """Option names a ``prepare`` without a declared contract can receive.

    ``None`` = accepts everything (``**kwargs`` or un-inspectable).  The
    first two positional parameters are the pipeline artifacts (optimized
    sync, retained deps), not options, whatever the registrant named them.
    """

    import inspect

    try:
        params = list(inspect.signature(prepare).parameters.values())
    except (TypeError, ValueError):  # C callables etc.: assume modern
        return None
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return None
    names = []
    positional_seen = 0
    for p in params:
        if (
            p.kind
            in (
                inspect.Parameter.POSITIONAL_ONLY,
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
            )
            and positional_seen < 2
        ):
            positional_seen += 1
            continue
        if p.kind is inspect.Parameter.VAR_POSITIONAL:
            continue
        names.append(p.name)
    return frozenset(names)


def _check_backend_options(
    spec: BackendSpec, options: Mapping[str, object]
) -> None:
    """Enforce the capability contract: unknown options are an error.

    This replaces the old silent ``_accepted_options`` filter — e.g.
    ``chunk_limit`` on ``backend="threaded"`` used to do nothing without a
    word; now it raises naming the backend and its accepted options.
    """

    accepted = backend_accepted_options(spec)
    if accepted is None:
        return
    unknown = sorted(k for k in options if k not in accepted)
    if unknown:
        raise ValueError(
            f"backend {spec.name!r} does not accept option(s) "
            f"{', '.join(repr(k) for k in unknown)}; its capability "
            f"contract accepts {sorted(accepted) if accepted else 'no options'}"
            " — drop the option or compile for a backend that declares it"
        )


# ---------------------------------------------------------------------- #
# Option validation (shared by PlanOptions and SyncPlan.compile)
# ---------------------------------------------------------------------- #

ELIMINATION_METHODS = ("isd", "pattern", "both", "none")
EXECUTION_MODELS = ("doall", "dswp", "procmap")

# runtime dependence-resolution modes for non-affine (indirect) accesses:
# "inspect" schedules from the exact inspector instance graph; "speculate"
# runs doall-optimistic first and rolls back on a post-hoc validation failure
DEPS_MODES = ("inspect", "speculate")

# the scheduling knobs a PlanOptions forwards to ``prepare`` at compile time
SCHEDULING_OPTION_NAMES = (
    "chunk_limit",
    "scc_policy",
    "model",
    "processors",
    "deps",
)


def _validate_chunk_limit(chunk_limit: object) -> None:
    if chunk_limit is not None and (
        not isinstance(chunk_limit, int)
        or isinstance(chunk_limit, bool)
        or chunk_limit < 1
    ):
        raise ValueError(
            f"chunk_limit must be a positive integer or None, got "
            f"{chunk_limit!r} — a chunk of zero iterations cannot make "
            "progress (use chunk_limit=1 for fully sequential chunks)"
        )


def _validate_scheduling_options(options: Mapping[str, object]) -> None:
    """Value-validate the scheduling knobs (names are contract-checked
    separately, per backend)."""

    if "chunk_limit" in options:
        _validate_chunk_limit(options["chunk_limit"])
    if "scc_policy" in options:
        resolve_policy(options["scc_policy"])  # raises with allowed values
    if "model" in options and options["model"] not in EXECUTION_MODELS:
        raise ValueError(
            f"unknown execution model {options['model']!r}; expected one of "
            f"{EXECUTION_MODELS}"
        )
    if "deps" in options and options["deps"] not in DEPS_MODES:
        raise ValueError(
            f"unknown deps mode {options['deps']!r}; expected one of "
            f"{DEPS_MODES}"
        )


# ---------------------------------------------------------------------- #
# PlanOptions: the frozen, validated, hashable analysis configuration
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class PlanOptions:
    """Typed options of the analysis stage (:func:`plan`).

    Frozen and hashable so a plan request is a legitimate cache key;
    validated eagerly in ``__post_init__`` so a bad knob fails at
    construction with a clear message, not deep inside a scheduler.

    ``method``: ``"isd"`` (transitive reduction), ``"pattern"`` (Li &
    Abu-Sufah matching), ``"both"`` (pattern first — cheap — then ISD on the
    survivors), or ``"none"`` (naive synchronization only).
    ``deps``: explicit dependences; ``None`` runs the analyzer; the strings
    ``"inspect"``/``"speculate"`` also run the analyzer but additionally
    forward a runtime dependence-resolution mode for non-affine accesses to
    the backend — ``"inspect"`` schedules from the exact inspector instance
    graph (:mod:`repro.core.inspector`), ``"speculate"`` runs the
    doall-optimistic schedule and rolls back to the conservative one when
    post-hoc validation against the inspector graph fails.  On programs
    without indirect accesses both modes degrade to the conservative plan.
    ``merge_sends``: merge compatible sends during optimized insertion.
    ``chunk_limit``/``scc_policy``: recurrence-SCC scheduling knobs,
    forwarded at compile time to backends whose capability contract accepts
    them.  ``model``/``processors``: the execution model the elimination
    (and later scheduling) assumes — ``"procmap"`` is how the Pallas K-loop
    plan expresses its explicit two-processor pipeline.
    """

    method: str = "isd"
    deps: Union[None, str, Tuple[Dependence, ...]] = None
    merge_sends: bool = False
    chunk_limit: Optional[int] = None
    scc_policy: SccPolicyLike = None
    model: str = "doall"
    processors: Optional[Tuple[Tuple[str, object], ...]] = None

    def __post_init__(self) -> None:
        if isinstance(self.deps, str):
            if self.deps not in DEPS_MODES:
                raise ValueError(
                    f"unknown deps mode {self.deps!r}; expected one of "
                    f"{DEPS_MODES} (or an explicit dependence sequence)"
                )
        elif self.deps is not None:
            object.__setattr__(self, "deps", tuple(self.deps))
        if isinstance(self.processors, Mapping):
            object.__setattr__(
                self, "processors", tuple(sorted(self.processors.items()))
            )
        elif self.processors is not None:
            object.__setattr__(self, "processors", tuple(self.processors))
        if self.method not in ELIMINATION_METHODS:
            raise ValueError(
                f"unknown elimination method {self.method!r}; expected one "
                f"of {ELIMINATION_METHODS}"
            )
        _validate_chunk_limit(self.chunk_limit)
        resolve_policy(self.scc_policy)  # raises with the allowed values
        if self.model not in EXECUTION_MODELS:
            raise ValueError(
                f"unknown execution model {self.model!r}; expected one of "
                f"{EXECUTION_MODELS}"
            )
        if self.model == "procmap" and not self.processors:
            raise ValueError(
                "model='procmap' requires a processors mapping "
                "(statement name -> processor id)"
            )
        if self.model == "doall" and self.processors:
            raise ValueError(
                "processors only make sense under model='procmap'"
            )
        if self.model != "doall" and self.method in ("pattern", "both"):
            raise ValueError(
                f"method={self.method!r} implements the doall pattern "
                "matcher only; use method='isd' for non-doall models"
            )

    # ------------------------------------------------------------------ #
    @property
    def processor_map(self) -> Optional[Dict[str, object]]:
        return dict(self.processors) if self.processors else None

    def scheduling_options(self) -> Dict[str, object]:
        """The non-default scheduling knobs to forward at compile time."""

        out: Dict[str, object] = {}
        if self.chunk_limit is not None:
            out["chunk_limit"] = self.chunk_limit
        if self.scc_policy is not None:
            out["scc_policy"] = self.scc_policy
        if self.model != "doall":
            out["model"] = self.model
        if self.processors:
            out["processors"] = self.processor_map
        if isinstance(self.deps, str):
            out["deps"] = self.deps
        return out


# ---------------------------------------------------------------------- #
# Bounds-free analysis memo
# ---------------------------------------------------------------------- #

# bounded like the compile caches: a long-running server with varying
# request structures must not accumulate elimination results forever (and
# locked like them — concurrent serving threads share this memo)
_ANALYSIS_MEMO: "collections.OrderedDict[Tuple, EliminationResult]" = (
    collections.OrderedDict()
)
_ANALYSIS_MEMO_MAX = 256
_ANALYSIS_LOCK = threading.Lock()
# registry-backed (repro.obs.metrics): the unified registry owns the
# counters; this module keeps direct references for lock-free-looking
# increments and analysis_cache_stats() stays a thin view with the exact
# pre-registry return shape
_ANALYSIS_HITS = _metrics.counter("analysis_cache.hits")
_ANALYSIS_MISSES = _metrics.counter("analysis_cache.misses")


def analysis_cache_stats() -> Dict[str, int]:
    return {"hits": _ANALYSIS_HITS.value, "misses": _ANALYSIS_MISSES.value}


def clear_analysis_cache() -> None:
    with _ANALYSIS_LOCK:
        _ANALYSIS_MEMO.clear()
    _ANALYSIS_HITS.reset()
    _ANALYSIS_MISSES.reset()


def _eliminate(
    prog: LoopProgram,
    dep_list: Sequence[Dependence],
    method: str,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
) -> EliminationResult:
    # Non-affine proxies carry an unknown true distance: they can neither be
    # eliminated (a Δ=1 proxy does not prove the runtime distance is
    # covered) nor serve as covering edges for affine dependences (a
    # covering path through a proxy may not exist at runtime under
    # deps="inspect", where proxies are replaced by exact instance edges).
    # They bypass the algorithms and rejoin the retained set afterwards.
    nonaffine = tuple(d for d in dep_list if d.nonaffine)
    affine = [d for d in dep_list if not d.nonaffine]

    def _with_nonaffine(base: EliminationResult) -> EliminationResult:
        if not nonaffine:
            return base
        from repro.core.elimination import synchronized_set

        extra = tuple(synchronized_set(nonaffine, model, processors))
        return EliminationResult(
            retained=base.retained + extra,
            eliminated=base.eliminated,
            witnesses=base.witnesses,
            method=base.method,
        )

    if method == "none":
        return _with_nonaffine(
            EliminationResult(
                retained=tuple(loop_carried(affine)),
                eliminated=(),
                witnesses={},
                method="none",
            )
        )
    if method == "isd":
        return _with_nonaffine(
            eliminate_transitive(prog, affine, model=model, processors=processors)
        )
    if method == "pattern":
        return _with_nonaffine(eliminate_pattern(prog, affine))
    if method == "both":
        first = eliminate_pattern(prog, affine)
        second = eliminate_transitive(prog, list(first.retained))
        return _with_nonaffine(
            EliminationResult(
                retained=second.retained,
                eliminated=first.eliminated + second.eliminated,
                witnesses=second.witnesses,
                method="pattern+isd",
            )
        )
    raise ValueError(f"unknown elimination method: {method!r}")


def _memoized_eliminate(
    prog: LoopProgram,
    dep_list: Sequence[Dependence],
    method: str,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
) -> EliminationResult:
    """Elimination keyed by (statement graph, lower bounds, deps, method,
    execution model).

    The ISD window is derived from dependence distances and anchored at the
    loop *lower* bounds, so the result — including witness paths — is
    invariant under any change of the upper bounds (iteration count).
    """

    from repro.compile.structure import program_fingerprint

    key = (
        program_fingerprint(prog),
        tuple(lo for lo, _hi in prog.bounds),
        method,
        tuple(dep_list),
        model,
        tuple(sorted(processors.items())) if processors else None,
    )
    with _ANALYSIS_LOCK:
        hit = _ANALYSIS_MEMO.get(key)
        if hit is not None:
            _ANALYSIS_MEMO.move_to_end(key)
    if hit is not None:
        _ANALYSIS_HITS.inc()
        return hit
    elim = _eliminate(prog, dep_list, method, model, processors)
    with _ANALYSIS_LOCK:
        _ANALYSIS_MEMO[key] = elim
        while len(_ANALYSIS_MEMO) > _ANALYSIS_MEMO_MAX:
            _ANALYSIS_MEMO.popitem(last=False)
    _ANALYSIS_MISSES.inc()
    return elim


# ---------------------------------------------------------------------- #
# Report
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ParallelizationReport:
    program: LoopProgram
    dependences: Tuple[Dependence, ...]
    fission: FissionResult
    naive_sync: SyncProgram
    elimination: EliminationResult
    optimized_sync: SyncProgram
    backend: str = "threaded"
    # level schedule of the optimized sync program (backend="wavefront" only)
    wavefront: Optional[WavefrontSchedule] = None
    # structural-cache artifact (backend="xla" only): repro.compile handle
    compiled: Optional[object] = None
    # scheduling knobs this report was planned under (echoed into the
    # statement-level SCC summary for backends without a schedule)
    chunk_limit: Optional[int] = None
    scc_policy: SccPolicyLike = None
    # execution model the plan assumed (procmap nests carry the map too)
    model: str = "doall"
    processors: Optional[Dict[str, object]] = None

    @functools.cached_property
    def _statement_scc_summary(self) -> dict:
        """SCC partition + strategy records for backends without a schedule.

        Cached on the report: the cost model's exact-depth estimates make a
        fresh ``analyze_sccs`` of a recurrence-bearing program an
        O(instances) pass, too heavy to redo on every ``summary()`` call
        (cached_property writes to ``__dict__``, which a frozen dataclass
        permits — same pattern as WavefrontSchedule's cached stats).

        Strategy records reflect the report's *backend*: its ``level_cost``
        capability hook feeds the cost model, so an xla report shows the
        strategy the compiled artifact actually schedules.
        """

        from repro.core.scc import analyze_sccs

        try:
            hook = get_backend(self.backend).level_cost
        except ValueError:  # backend since unregistered: interpreter model
            hook = None
        return analyze_sccs(
            self.program,
            self.elimination.retained,
            model=self.model,
            processors=self.processors,
            chunk_limit=self.chunk_limit,
            scc_policy=self.scc_policy,
            level_cost=hook,
        ).summary()

    def summary(self) -> dict:
        naive = self.naive_sync.sync_instruction_count()
        opt = self.optimized_sync.sync_instruction_count()
        out = {
            "dependences": len(self.dependences),
            "loop_carried": len(loop_carried(self.dependences)),
            "eliminated": len(self.elimination.eliminated),
            "naive_sync_instructions": naive["total"],
            "optimized_sync_instructions": opt["total"],
            "naive_runtime_sync_ops": self.naive_sync.runtime_sync_ops(),
            "optimized_runtime_sync_ops": self.optimized_sync.runtime_sync_ops(),
            "method": self.elimination.method,
            "backend": self.backend,
        }
        if self.wavefront is not None and self.wavefront.scc is not None:
            out["scc"] = self.wavefront.scc.summary()
        else:
            # deep copy: the cached dict must not be mutable through the
            # return value, or one caller's annotation would leak into
            # every later summary() of this report
            out["scc"] = copy.deepcopy(self._statement_scc_summary)
        if self.wavefront is not None:
            out["wavefront_depth"] = self.wavefront.depth
            out["wavefront_batched_ops"] = self.wavefront.batched_ops
        if self.compiled is not None:
            out["compile_key"] = self.compiled.key[:16]
            out["compile_cache"] = self.compiled.cache_stats()
        # observability pointers (repro.obs): deliberately free of live
        # counter values so equal plans summarize identically regardless of
        # what else ran in between (shim/staged bit-identity)
        out["obs"] = obs_summary(self.backend)
        return out


# artifacts a backend's prepare() may contribute to the report; anything
# else it returns stays on Executable.artifacts (e.g. xla's compile_hit)
_REPORT_ARTIFACT_FIELDS = ("wavefront", "compiled")


# ---------------------------------------------------------------------- #
# The staged pipeline: plan -> SyncPlan -> compile -> Executable
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SyncPlan:
    """The backend-independent analysis artifact of :func:`plan`.

    Holds everything steps 1–4 produced — computed exactly once, however
    many backends the plan is later compiled for.  ``compile`` never re-runs
    dependence analysis or elimination; it only schedules/lowers.
    """

    program: LoopProgram
    options: PlanOptions
    dependences: Tuple[Dependence, ...]
    fission: FissionResult
    naive_sync: SyncProgram
    elimination: EliminationResult
    optimized_sync: SyncProgram

    @property
    def retained(self) -> Tuple[Dependence, ...]:
        """The synchronized dependences the optimized program enforces."""

        return tuple(self.elimination.retained)

    def compile(self, backend: str = "threaded", **backend_options) -> "Executable":
        """Target one registered backend; returns an :class:`Executable`.

        The effective options are the plan's scheduling knobs
        (:meth:`PlanOptions.scheduling_options`) overlaid with
        ``backend_options`` (an explicit ``None`` override removes a plan
        knob).  Every effective option must be in the backend's capability
        contract (:func:`backend_accepted_options`) — unknown options raise
        ``ValueError`` naming the backend and its accepted options.
        """

        spec = get_backend(backend)
        options = self.options.scheduling_options()
        options.update(backend_options)
        # contract-check the NAMES first, None-valued overrides included —
        # a misspelled knob must error even when its value is None; only
        # then does an explicit None override remove a plan-level knob
        _check_backend_options(spec, options)
        options = {k: v for k, v in options.items() if v is not None}
        _validate_scheduling_options(options)
        artifacts: Dict[str, object] = {}
        if spec.prepare:
            with _trace.span("compile", backend=backend):
                artifacts = dict(
                    spec.prepare(
                        self.optimized_sync, self.elimination.retained, **options
                    )
                )
        return Executable(
            plan=self,
            backend=backend,
            options=tuple(sorted(options.items(), key=lambda kv: kv[0])),
            artifacts=artifacts,
        )

    def summary(self) -> dict:
        """Backend-independent plan summary (sync counts, elimination)."""

        naive = self.naive_sync.sync_instruction_count()
        opt = self.optimized_sync.sync_instruction_count()
        return {
            "dependences": len(self.dependences),
            "loop_carried": len(loop_carried(self.dependences)),
            "eliminated": len(self.elimination.eliminated),
            "retained": len(self.elimination.retained),
            "naive_sync_instructions": naive["total"],
            "optimized_sync_instructions": opt["total"],
            "method": self.elimination.method,
        }


@dataclasses.dataclass(frozen=True)
class Executable:
    """One backend's compiled form of a :class:`SyncPlan`.

    ``run(store=None, stalls=None)`` executes the optimized program and
    returns its final store — the same contract on every backend (``stalls``
    inject adversarial delays on the threaded machine; the deterministic
    backends accept and ignore them, exactly like the differential hooks
    always have).  ``report()`` yields the familiar
    :class:`ParallelizationReport`.
    """

    plan: SyncPlan
    backend: str
    options: Tuple[Tuple[str, object], ...]
    artifacts: Mapping[str, object]

    def run(
        self,
        store: Optional[Mapping[str, dict]] = None,
        stalls: Optional[Mapping] = None,
    ) -> dict:
        spec = get_backend(self.backend)
        _metrics.counter(f"backend.runs.{self.backend}").inc()
        with _trace.span("run", backend=self.backend):
            if spec.run is not None:
                return spec.run(
                    self.plan.optimized_sync,
                    dict(self.artifacts),
                    store=store,
                    stalls=stalls,
                )
            if spec.differential is not None:
                return spec.differential(
                    self.plan.optimized_sync, store=store, stalls=stalls
                )
        raise ValueError(
            f"backend {self.backend!r} registers neither a run nor a "
            "differential hook — it cannot execute programs"
        )

    def trace_json(self, indent: Optional[int] = None) -> str:
        """The buffered span events as Chrome-trace JSON (see
        :mod:`repro.obs.trace`; empty unless tracing was enabled around the
        plan/compile/run calls)."""

        return _trace.trace_json(indent=indent)

    # convenience views over the prepared artifacts ---------------------- #
    @property
    def wavefront(self) -> Optional[WavefrontSchedule]:
        return self.artifacts.get("wavefront")

    @property
    def compiled(self) -> Optional[object]:
        return self.artifacts.get("compiled")

    @functools.cached_property
    def _report(self) -> ParallelizationReport:
        opts = dict(self.options)
        extra = {
            k: self.artifacts[k]
            for k in _REPORT_ARTIFACT_FIELDS
            if k in self.artifacts
        }
        return ParallelizationReport(
            program=self.plan.program,
            dependences=self.plan.dependences,
            fission=self.plan.fission,
            naive_sync=self.plan.naive_sync,
            elimination=self.plan.elimination,
            optimized_sync=self.plan.optimized_sync,
            backend=self.backend,
            chunk_limit=opts.get("chunk_limit"),
            scc_policy=opts.get("scc_policy"),
            model=opts.get("model", "doall"),
            processors=opts.get("processors"),
            **extra,
        )

    def report(self) -> ParallelizationReport:
        return self._report


def plan(
    prog: LoopProgram,
    options: Optional[PlanOptions] = None,
    **overrides,
) -> SyncPlan:
    """Run the backend-independent §5 analysis exactly once.

    ``options`` is a :class:`PlanOptions`; as a convenience, keyword
    arguments build one (``plan(prog, method="both")``) — but not both at
    once.  The pipeline: dependence analysis (or the caller's ``deps``),
    fission, naive synchronization insertion, memoized elimination,
    retained-set validation (unschedulable sets raise
    :class:`~repro.core.wavefront.WavefrontError` here, with the offending
    SCC and a witness cycle — before any backend is involved), and the
    optimized sync program.
    """

    if options is None:
        options = PlanOptions(**overrides)
    elif overrides:
        raise TypeError(
            "pass either a PlanOptions or keyword options, not both "
            f"(got options={options!r} plus {sorted(overrides)})"
        )

    with _trace.span("plan", method=options.method, statements=len(prog.statements)):
        with _trace.span("plan.deps"):
            dep_list = (
                list(options.deps)
                if options.deps is not None and not isinstance(options.deps, str)
                else analyze(prog)
            )
        with _trace.span("plan.fission"):
            fiss = fission(prog, dep_list)
        with _trace.span("plan.naive_sync"):
            naive = insert_synchronization(prog, dep_list, merge=False)

        with _trace.span("plan.elimination"):
            elim = _memoized_eliminate(
                prog,
                dep_list,
                options.method,
                options.model,
                options.processor_map,
            )

        # Genuinely unschedulable retained sets (lexicographically negative /
        # backward-zero distances — a cyclic Δ-sign mix no machine can honor)
        # fail HERE, at plan time, for every backend: the threaded machine
        # would deadlock mid-execution and the schedulers would reject later
        # with less context.  repro.core.scc raises with the offending SCC's
        # statements and a witness cycle (and bumps the
        # plan.wavefront_rejections counter).
        with _trace.span("plan.validate"):
            validate_retained(prog, elim.retained)

        with _trace.span("plan.optimize"):
            optimized = strip_dependences(naive, elim.eliminated)
            if options.merge_sends:
                optimized = insert_synchronization(
                    prog, list(elim.retained), merge=True
                )
    return SyncPlan(
        program=prog,
        options=options,
        dependences=tuple(dep_list),
        fission=fiss,
        naive_sync=naive,
        elimination=elim,
        optimized_sync=optimized,
    )


# ---------------------------------------------------------------------- #
# Built-in backends
# ---------------------------------------------------------------------- #

register_backend(
    BackendSpec(
        name="threaded",
        prepare=None,
        # the paper's machine takes no scheduling knobs; it accepts the
        # "deps" mode as a documented no-op — its conservative send/wait
        # execution enforces a superset of any inspector graph, so it is the
        # semantics every inspect/speculate schedule must reproduce
        accepts=("deps",),
        differential=lambda sync, *, store=None, stalls=None: run_threaded(
            sync, stalls=stalls, store=store, compare=False
        ).store,
        run=lambda sync, artifacts, *, store=None, stalls=None: run_threaded(
            sync, stalls=stalls, store=store, compare=False
        ).store,
        description="one thread per iteration, send/wait only (the paper's machine)",
    )
)


def _wavefront_prepare(
    optimized,
    retained,
    *,
    chunk_limit=None,
    scc_policy=None,
    model="doall",
    processors=None,
    deps=None,
):
    artifacts: Dict[str, object] = {
        "wavefront": schedule_wavefronts(
            optimized,
            list(retained),
            model=model,
            processors=processors,
            chunk_limit=chunk_limit,
            scc_policy=scc_policy,
        )
    }
    if deps is not None and optimized.program.has_indirect():
        from repro.core.inspector import affine_retained

        # the exact instance graph is store-dependent — run() builds the
        # final schedule; prepare records the mode, the knobs and (for
        # speculation) the store-independent optimistic schedule
        artifacts["deps_mode"] = deps
        artifacts["retained"] = tuple(retained)
        artifacts["sched_options"] = {
            "chunk_limit": chunk_limit,
            "scc_policy": scc_policy,
            "model": model,
            "processors": processors,
        }
        if deps == "speculate":
            artifacts["speculative"] = schedule_wavefronts(
                optimized,
                list(affine_retained(retained)),
                model=model,
                processors=processors,
                chunk_limit=chunk_limit,
                scc_policy=scc_policy,
            )
    return artifacts


def _wavefront_run(sync, artifacts, *, store=None, stalls=None):
    mode = artifacts.get("deps_mode")
    if mode is None:
        return run_wavefront(
            sync, schedule=artifacts.get("wavefront"), store=store, compare=False
        ).store

    from repro.core.inspector import (
        affine_retained,
        inspect_dependences,
        speculation_violations,
    )
    from repro.core.wavefront import schedule_levels

    prog = sync.program
    init = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    inspection = inspect_dependences(prog, init)
    opts = artifacts.get("sched_options") or {}
    if mode == "speculate":
        speculative = artifacts["speculative"]
        out = run_wavefront(
            sync, schedule=speculative, store=init, compare=False
        )
        _metrics.counter("speculation.validations").inc()
        with _trace.span("speculate.validate", backend="wavefront"):
            ok = not speculation_violations(
                prog, inspection.edges, speculative.level_of()
            )
        if ok:
            return out.store
        # rollback: the speculative result is discarded; re-execute the
        # conservative hybrid schedule from the untouched initial image
        _metrics.counter("speculation.rollbacks").inc()
        with _trace.span("speculate.rollback", backend="wavefront"):
            return run_wavefront(
                sync, schedule=artifacts["wavefront"], store=init, compare=False
            ).store
    # mode == "inspect": exact per-store schedule — conservative proxies
    # replaced by the inspector's instance edges
    exact = schedule_levels(
        prog,
        list(affine_retained(artifacts["retained"])),
        model=opts.get("model", "doall"),
        processors=opts.get("processors"),
        chunk_limit=opts.get("chunk_limit"),
        scc_policy=opts.get("scc_policy"),
        instance_edges=inspection.edges,
    )
    return run_wavefront(sync, schedule=exact, store=init, compare=False).store


register_backend(
    BackendSpec(
        name="wavefront",
        prepare=_wavefront_prepare,
        accepts=("chunk_limit", "scc_policy", "model", "processors", "deps"),
        differential=lambda sync, *, store=None, stalls=None: run_wavefront(
            sync, store=store, compare=False
        ).store,
        run=_wavefront_run,
        description="NumPy dependence-level interpreter (O(depth) batched steps)",
    )
)


# ---------------------------------------------------------------------- #
# Compatibility shim
# ---------------------------------------------------------------------- #

def parallelize(
    prog: LoopProgram,
    *,
    method: str = "isd",
    deps: Optional[Sequence[Dependence]] = None,
    merge_sends: bool = False,
    backend: str = "threaded",
    chunk_limit: Optional[int] = None,
    scc_policy: SccPolicyLike = None,
) -> ParallelizationReport:
    """One-shot shim over ``plan(...).compile(backend).report()``.

    Kept for source compatibility: reports are bit-identical to the staged
    pipeline's (it *is* the staged pipeline) and structural compile-cache
    keys are unchanged, so a warm artifact is shared across both entry
    points.  New code should stage explicitly — the plan is computed once
    and can be compiled for several backends::

        p = plan(prog, PlanOptions(method="isd"))
        schedule = p.compile("wavefront").report().wavefront
        store    = p.compile("xla").run()

    Note the capability contract applies here too: a scheduling knob the
    target backend does not declare (e.g. ``chunk_limit`` with
    ``backend="threaded"``) raises ``ValueError`` instead of being silently
    dropped.
    """

    warnings.warn(
        "parallelize() is deprecated in favor of the staged API: "
        "plan(prog, PlanOptions(...)).compile(backend).report() "
        "(one analysis, any number of backends)",
        DeprecationWarning,
        stacklevel=2,
    )
    options = PlanOptions(
        method=method,
        deps=tuple(deps) if deps is not None else None,
        merge_sends=merge_sends,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
    )
    return plan(prog, options).compile(backend).report()
