"""Wavefront (level-synchronous) execution backend for SyncPrograms.

The threaded executor (:mod:`repro.core.executor`) is the paper's machine in
miniature — one thread per iteration, cross-iteration order enforced only by
send/wait — which makes it a fine oracle and a hopeless fast path: a run of
``n`` iterations costs ``n`` OS threads plus a send/wait round-trip per
retained dependence instance.  This module replaces that with *static*
scheduling in the style of graph-based dependence layering (Alluru &
Jeganathan, arXiv:2102.09317; Baghdadi et al., arXiv:1111.6756):

  1. materialize the ISD over the loop's *actual* bounds — nodes are
     statement instances ``S_k(i)``, edges are exactly the orders the sync
     program's execution model enforces (free orders of the model + the
     retained synchronized dependences);
  2. compute each instance's *dependence level* by longest-path layering
     (level = length of the longest enforced-order chain reaching it);
  3. lower each level to one batched statement evaluation per (statement,
     level) group — a single vectorized NumPy gather/compute/scatter.

Soundness rides on the elimination invariant of §4.2: every true dependence
of the program is covered by a path of enforced-order edges, every enforced
edge strictly increases the level, hence any two instances sharing a level
are mutually independent and may execute in one batch, in any order.

The plain longest-path layering is only defined when retained distances are
per-dimension non-negative (the ISD precondition).  Retained sets with
mixed-sign distance components — skewed stencils, cross-iteration cycles
with a Δ-sign mix — route through the SCC-condensed hybrid scheduler
(:mod:`repro.core.scc`): Tarjan condensation of the statement graph, then a
per-SCC strategy from the scheduling-policy engine (:mod:`repro.core.policy`
— chunked DOACROSS, unimodular-skew diagonal wavefront, or per-SCC dswp
lanes; cost model by default, forced via ``scc_policy``) for recurrence
components, instance-level layering with cross-SCC pipelining for
everything else.  Only dependence sets that
contradict sequential execution order (lexicographically negative or
backward zero distances — the send/wait machine would deadlock) still raise
:class:`WavefrontError`, at schedule/parallelize time, naming the offending
SCC's statements and a witness cycle.

Four executors now coexist (see ROADMAP "Execution backends"):

  * :func:`repro.core.ir.run_sequential` — the semantic oracle;
  * :func:`repro.core.executor.run_threaded` — the paper's machine, used to
    demonstrate races and count send/wait traffic;
  * :func:`run_wavefront` (here) — the NumPy interpreter of the level
    schedule: O(depth) vectorized steps instead of O(iterations) threads;
  * :func:`repro.compile.run_xla` — the *compiled* form of the same
    schedule: :class:`WavefrontSchedule` is the hand-off IR that
    :mod:`repro.compile.lowering` packs into padded level buffers and jits
    as a single XLA level loop, cached structurally across bounds.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace as _trace
from repro.core.dependence import Dependence
from repro.core.ir import LoopProgram, is_indirect, run_sequential
from repro.core.isd import Instance, build_isd
from repro.core.policy import LevelCostFn, SccPolicyLike
from repro.core.scc import (
    SccPartition,
    WavefrontError,
    analyze_sccs,
    hybrid_levels,
    validate_retained,
)
from repro.core.sync import SyncProgram

__all__ = [
    "WavefrontError",  # re-exported; defined beside the SCC machinery
    "WavefrontGroup",
    "WavefrontSchedule",
    "WavefrontReport",
    "WavefrontStats",
    "run_wavefront",
    "schedule_levels",
    "schedule_wavefronts",
]


@dataclasses.dataclass(frozen=True)
class WavefrontGroup:
    """One batched evaluation: ``statement`` at every iteration in the group."""

    statement: str
    iterations: Tuple[Tuple[int, ...], ...]

    @property
    def width(self) -> int:
        return len(self.iterations)


@dataclasses.dataclass(frozen=True)
class WavefrontSchedule:
    """Dependence-level layering of a sync program's instance space."""

    program: LoopProgram
    levels: Tuple[Tuple[WavefrontGroup, ...], ...]
    model: str
    retained: Tuple[Dependence, ...]
    # statement → processor assignment (procmap model only) — carried so a
    # schedule is a complete lowering hand-off (repro.compile re-layers it
    # for other bounds under the same model)
    processors: Optional[Dict[str, object]] = None
    # Tarjan condensation of the statement graph (repro.core.scc); carries
    # the per-SCC strategy records (chunk sizes, skew matrices, cost-model
    # reasons) when the hybrid path was taken
    scc: Optional[SccPartition] = None
    # cap on DOACROSS chunk sizes this schedule was built with (the knob is
    # part of the lowering hand-off: re-layering for other bounds must chunk
    # under the same cap)
    chunk_limit: Optional[int] = None
    # the scc_policy spec this schedule was planned under (None/"auto",
    # a strategy name, or a SchedulingPolicy instance) — part of the
    # lowering hand-off for the same reason as chunk_limit
    scc_policy: SccPolicyLike = None

    # ------------------------------------------------------------------ #
    @property
    def depth(self) -> int:
        """Number of wavefronts — the O(depth) step count of the backend."""

        return len(self.levels)

    @functools.cached_property
    def batched_ops(self) -> int:
        """Total vectorized statement evaluations across all levels."""

        return sum(len(level) for level in self.levels)

    @functools.cached_property
    def instances(self) -> int:
        return sum(g.width for level in self.levels for g in level)

    @functools.cached_property
    def max_width(self) -> int:
        widths = [g.width for level in self.levels for g in level]
        return max(widths) if widths else 0

    def level_of(self) -> Dict[Instance, int]:
        """Instance → level index (inverse of ``levels``; test/debug aid)."""

        out: Dict[Instance, int] = {}
        for lvl, groups in enumerate(self.levels):
            for g in groups:
                for it in g.iterations:
                    out[(g.statement, it)] = lvl
        return out

    def summary(self) -> dict:
        out = {
            "depth": self.depth,
            "batched_ops": self.batched_ops,
            "instances": self.instances,
            "max_width": self.max_width,
            "model": self.model,
            "retained": [d.pretty() for d in self.retained],
        }
        if self.scc is not None:
            out["scc"] = self.scc.summary()
        return out


def _sync_dependences(sync: SyncProgram) -> List[Dependence]:
    """The dependences a SyncProgram actually synchronizes (its registers)."""

    out: List[Dependence] = []
    seen = set()
    for ds in sync.registers.values():
        for d in ds:
            key = (d.kind, d.source, d.sink, d.array, d.distance, d.nonaffine)
            if key not in seen:
                seen.add(key)
                out.append(d)
    return out


def schedule_wavefronts(
    sync: SyncProgram,
    retained: Optional[Sequence[Dependence]] = None,
    *,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: "SccPolicyLike" = None,
    level_cost: Optional["LevelCostFn"] = None,
) -> WavefrontSchedule:
    """Dependence-level layering of ``sync`` (hybrid when cycles demand it).

    ``retained`` defaults to the dependences ``sync`` synchronizes (its
    register table) — pass ``EliminationResult.retained`` explicitly when
    scheduling straight from a compiler report.  Raises
    :class:`WavefrontError` only for retained sets that contradict
    sequential execution order (see :func:`repro.core.scc.validate_retained`).
    """

    deps = list(retained) if retained is not None else _sync_dependences(sync)
    return schedule_levels(
        sync.program,
        deps,
        model=model,
        processors=processors,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
        level_cost=level_cost,
    )


def _levels_to_groups(
    prog: LoopProgram,
    raw: Sequence[Mapping[str, Sequence[Tuple[int, ...]]]],
) -> Tuple[Tuple[WavefrontGroup, ...], ...]:
    lex = {name: k for k, name in enumerate(prog.names)}
    return tuple(
        tuple(
            WavefrontGroup(statement=name, iterations=tuple(its))
            for name, its in sorted(groups.items(), key=lambda kv: lex[kv[0]])
        )
        for groups in raw
    )


def schedule_levels(
    prog: LoopProgram,
    retained: Sequence[Dependence],
    *,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: "SccPolicyLike" = None,
    level_cost: Optional["LevelCostFn"] = None,
    instance_edges: Optional[Sequence[Tuple[Instance, Instance]]] = None,
) -> WavefrontSchedule:
    """Layer a bare :class:`LoopProgram` given its retained dependences.

    The sync-program-independent core of :func:`schedule_wavefronts`; used
    directly by the Pallas K-loop plan, whose enforced orders come from an
    explicit processor map rather than a send/wait program.

    ``instance_edges`` injects *exact* instance-level orders — the
    inspector's runtime dependence graph for non-affine accesses
    (:func:`repro.core.inspector.inspect_dependences`) — on top of the
    statement-level retained set.  Pass the affine retained set alongside
    them: the inspector is authoritative only for the indirect array set.

    Per-dimension non-negative retained sets take the classic longest-path
    ISD layering below; sets with mixed-sign distance components route
    through the SCC-condensed hybrid (:func:`repro.core.scc.hybrid_levels`)
    — acyclic components stay instance-layered (pipelined), recurrence
    components execute under the strategy the scheduling-policy engine
    (:mod:`repro.core.policy`) picks per SCC: chunked DOACROSS blocks of at
    most ``chunk_limit`` iterations, a unimodular-skew diagonal wavefront,
    or a per-SCC dswp pipeline.  ``scc_policy`` forces one strategy
    (``"chunk"``/``"skew"``/``"dswp"``); the default runs the cost model,
    through the scheduling backend's ``level_cost`` hook when one is given
    (the compiled backend schedules with its own step-cost model — see
    ``repro.compile.xla_level_cost``).
    """

    deps = list(retained)
    validate_retained(prog, deps)  # WavefrontError before any execution

    extra: Dict[Instance, List[Instance]] = {}
    if instance_edges:
        for u, v in instance_edges:
            if u != v:
                extra.setdefault(u, []).append(v)

    if any(x < 0 for d in deps for x in d.distance):
        raw, part = hybrid_levels(
            prog,
            deps,
            model=model,
            processors=processors,
            chunk_limit=chunk_limit,
            scc_policy=scc_policy,
            level_cost=level_cost,
            instance_edges=instance_edges,
        )
        return WavefrontSchedule(
            program=prog,
            levels=_levels_to_groups(prog, raw),
            model=model,
            retained=tuple(deps),
            processors=dict(processors) if processors else None,
            scc=part,
            chunk_limit=chunk_limit,
            scc_policy=scc_policy,
        )

    try:
        isd = build_isd(prog, deps, prog.bounds, model=model, processors=processors)
    except ValueError as e:  # pragma: no cover - guarded above for deps
        raise WavefrontError(str(e)) from e

    # Kahn layering: level(v) = 1 + max(level(pred)); cycle check for free.
    nodes: List[Instance] = [
        (s.name, it) for it in prog.iterations() for s in prog.statements
    ]
    indeg: Dict[Instance, int] = {v: 0 for v in nodes}
    for u, succs in isd.adj.items():
        for v, _tag in succs:
            indeg[v] = indeg.get(v, 0) + 1
    for u, vs in extra.items():
        for v in vs:
            indeg[v] = indeg.get(v, 0) + 1

    level: Dict[Instance, int] = {}
    frontier = [v for v in nodes if indeg[v] == 0]
    for v in frontier:
        level[v] = 0
    done = 0
    while frontier:
        nxt: List[Instance] = []
        for u in frontier:
            done += 1
            for v, _tag in isd.successors(u):
                level[v] = max(level.get(v, 0), level[u] + 1)
                indeg[v] -= 1
                if indeg[v] == 0:
                    nxt.append(v)
            for v in extra.get(u, ()):
                level[v] = max(level.get(v, 0), level[u] + 1)
                indeg[v] -= 1
                if indeg[v] == 0:
                    nxt.append(v)
        frontier = nxt
    if done != len(nodes):
        stuck = [v for v in nodes if indeg[v] > 0][:4]
        raise WavefrontError(
            "enforced-order instance graph is cyclic — no wavefront "
            f"layering exists (unschedulable instances include {stuck}); "
            "check the retained dependences for a cyclic Δ-sign mix"
        )

    depth = max(level.values(), default=-1) + 1
    by_level: List[Dict[str, List[Tuple[int, ...]]]] = [
        {} for _ in range(depth)
    ]
    for it in prog.iterations():  # iteration order → sorted group members
        for s in prog.statements:
            by_level[level[(s.name, it)]].setdefault(s.name, []).append(it)
    return WavefrontSchedule(
        program=prog,
        levels=_levels_to_groups(prog, by_level),
        model=model,
        retained=tuple(deps),
        processors=dict(processors) if processors else None,
        scc=analyze_sccs(
            prog,
            deps,
            model=model,
            processors=processors,
            scc_policy=scc_policy,
            level_cost=level_cost,
        ),
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
    )


# ---------------------------------------------------------------------- #
# Vectorized execution
# ---------------------------------------------------------------------- #

@dataclasses.dataclass
class WavefrontStats:
    levels: int
    batched_ops: int
    instances: int
    max_width: int


@dataclasses.dataclass
class WavefrontReport:
    store: dict
    schedule: WavefrontSchedule
    stats: WavefrontStats
    matches_sequential: bool


class _DenseStore:
    """Dict-of-dicts memory image ⇄ dense float64 arrays with an origin.

    A sparse input store (cells missing inside its bounding box) gets a
    per-array coverage mask so that reading an absent cell raises KeyError —
    matching what the sequential/threaded executors do on the same store —
    instead of consuming uninitialized memory.  ``initial_store()`` produces
    full rectangles, so the common path carries no mask and no overhead.
    """

    def __init__(self, store: Mapping[str, dict]) -> None:
        self.origin: Dict[str, Tuple[int, ...]] = {}
        self.data: Dict[str, np.ndarray] = {}
        self.mask: Dict[str, np.ndarray] = {}  # only sparse arrays
        for arr, cells in store.items():
            if not cells:
                raise KeyError(
                    f"array {arr!r} in the provided store has no initialized "
                    "cells — the dense backends need the accessed cells "
                    "up front (sequential execution would fail on its first "
                    "access too)"
                )
            keys = np.asarray(list(cells.keys()), dtype=np.int64)
            lo_v = keys.min(axis=0)
            shape = tuple((keys.max(axis=0) - lo_v + 1).tolist())
            idx = tuple((keys - lo_v).T)
            dense = np.zeros(shape, dtype=np.float64)
            dense[idx] = np.fromiter(
                cells.values(), dtype=np.float64, count=len(cells)
            )
            self.origin[arr] = tuple(lo_v.tolist())
            self.data[arr] = dense
            if len(cells) != dense.size:
                covered = np.zeros(shape, dtype=bool)
                covered[idx] = True
                self.mask[arr] = covered

    def _index(self, arr: str, pts: np.ndarray) -> Tuple[np.ndarray, ...]:
        lo = self.origin[arr]
        idx = tuple(pts[:, d] - lo[d] for d in range(pts.shape[1]))
        shape = self.data[arr].shape
        for d, comp in enumerate(idx):
            if comp.size and (comp.min() < 0 or comp.max() >= shape[d]):
                raise KeyError(
                    f"access to {arr!r} outside the initialized store "
                    f"(dim {d}) — widen the pad of initial_store()"
                )
        return idx

    def gather(self, arr: str, pts: np.ndarray) -> np.ndarray:
        idx = self._index(arr, pts)
        covered = self.mask.get(arr)
        if covered is not None and not covered[idx].all():
            raise KeyError(
                f"read of uninitialized {arr!r} cell — the provided store "
                "does not cover this access"
            )
        return self.data[arr][idx]

    def scatter(self, arr: str, pts: np.ndarray, vals: np.ndarray) -> None:
        idx = self._index(arr, pts)
        self.data[arr][idx] = vals
        covered = self.mask.get(arr)
        if covered is not None:
            covered[idx] = True

    def to_dicts(self) -> dict:
        out: dict = {}
        for arr, dense in self.data.items():
            lo = self.origin[arr]
            covered = self.mask.get(arr)
            if covered is None:
                idx = np.indices(dense.shape).reshape(dense.ndim, -1).T
                vals = dense.ravel()
            else:
                idx = np.argwhere(covered)
                vals = dense[tuple(idx.T)]
            idx = idx + np.asarray(lo, dtype=np.int64)
            out[arr] = dict(
                zip(map(tuple, idx.tolist()), vals.tolist())
            )
        return out


def _batched_compute(stmt, reads: List[np.ndarray], width: int) -> np.ndarray:
    """Evaluate ``stmt.compute`` over whole read vectors at once, falling
    back to an elementwise loop for compute functions that don't broadcast."""

    try:
        vals = np.asarray(stmt.compute(*reads), dtype=np.float64)
        if vals.shape == (width,):
            return vals
        if vals.ndim == 0:  # zero-read statements produce one scalar
            return np.full(width, float(vals), dtype=np.float64)
    except Exception:
        pass
    return np.array(
        [
            float(stmt.compute(*(r[j] for r in reads)))
            for j in range(width)
        ],
        dtype=np.float64,
    )


def run_wavefront(
    sync: SyncProgram,
    *,
    schedule: Optional[WavefrontSchedule] = None,
    store: Optional[Mapping[str, dict]] = None,
    compare: bool = True,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: SccPolicyLike = None,
) -> WavefrontReport:
    """Execute ``sync`` level by level, one vectorized op per group.

    Mirrors :func:`repro.core.executor.run_threaded`: same store format,
    same ``matches_sequential`` contract (bit-equal against the sequential
    oracle).  An under-synchronized program mis-executes *deterministically*
    here — the layering simply places a racing read before its producer —
    which the differential tests exploit.
    """

    sched = schedule or schedule_wavefronts(
        sync,
        model=model,
        processors=processors,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
    )
    prog = sync.program
    init = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    mem = _DenseStore(init)
    data, origin = mem.data, mem.origin

    # Per-statement lowering, hoisted out of the level loop, for both paths:
    # store-relative scalar offsets (narrow groups) and absolute offset
    # arrays (wide groups), so the hot loop is pure index arithmetic.
    # Indirect accesses carry the index array's lowering instead — their
    # target cell is resolved per instance from the store's index contents.
    def _rel(ref):
        return tuple(
            o - l for o, l in zip(ref.offset_tuple(), origin[ref.array])
        )

    def _lower_ref(ref):
        if is_indirect(ref):
            idx = ref.index
            return (
                "ind",
                ref.array,
                idx.array,
                _rel(idx),
                np.asarray(idx.offset_tuple(), np.int64),
                ref.offset,
            )
        return (
            "aff",
            ref.array,
            _rel(ref),
            np.asarray(ref.offset_tuple(), np.int64),
        )

    lowered = {}
    for s in prog.statements:
        lowered[s.name] = (
            s,
            _lower_ref(s.write),
            tuple(_lower_ref(r) for r in s.reads),
            _lower_ref(s.guard) if s.guard is not None else None,
        )

    masks = mem.mask

    def scalar_cell(arr: str, it, off) -> np.float64:
        idx = tuple(x + o for x, o in zip(it, off))
        shape = data[arr].shape
        for d, x in enumerate(idx):
            if x < 0 or x >= shape[d]:
                raise KeyError(
                    f"access to {arr!r} outside the initialized store "
                    f"(dim {d}) — widen the pad of initial_store()"
                )
        covered = masks.get(arr)
        if covered is not None and not covered[idx]:
            raise KeyError(
                f"read of uninitialized {arr!r} cell — the provided store "
                "does not cover this access"
            )
        return data[arr][idx]

    def scalar_cell_of(acc, it) -> tuple:
        """Dense (store-relative) cell of one access at iteration ``it``."""

        if acc[0] == "aff":
            return tuple(x + o for x, o in zip(it, acc[2]))
        _tag, arr, iarr, irel, _ioff, const = acc
        # int() truncates toward zero — astype(int64) on the wide path agrees
        j = int(scalar_cell(iarr, it, irel)) + const
        return (j - origin[arr][0],)

    def wide_pts(acc, pts: np.ndarray) -> np.ndarray:
        """Absolute coordinates of one access for every point in ``pts``."""

        if acc[0] == "aff":
            return pts + acc[3]
        _tag, _arr, iarr, _irel, ioff, const = acc
        ivals = mem.gather(iarr, pts + ioff)
        return (ivals.astype(np.int64) + const)[:, None]

    # per-level span timing: the enabled check is hoisted so the disabled
    # path pays ONE branch per level (this loop is the interpreter's hot
    # path and the <5% disabled-overhead budget of the bench gate)
    _tracing = _trace.tracing_enabled()
    _t_level = 0
    for _level, groups in enumerate(sched.levels):
        if _tracing:
            _t_level = time.perf_counter_ns()
        for g in groups:
            stmt, w_l, reads_l, guard_l = lowered[g.statement]
            warr = w_l[1]
            width = len(g.iterations)
            if width <= 4:
                # narrow wavefront: scalar evaluation beats gather overhead
                for it in g.iterations:
                    if guard_l is not None and not (
                        scalar_cell(guard_l[1], it, guard_l[2]) > 0
                    ):
                        continue
                    vals = stmt.compute(
                        *(
                            scalar_cell(acc[1], it, acc[2])
                            if acc[0] == "aff"
                            else scalar_cell(
                                acc[1], scalar_cell_of(acc, it), (0,)
                            )
                            for acc in reads_l
                        )
                    )
                    widx = scalar_cell_of(w_l, it)
                    wshape = data[warr].shape
                    if any(
                        x < 0 or x >= n for x, n in zip(widx, wshape)
                    ):
                        raise KeyError(
                            f"write to {warr!r} outside the initialized "
                            "store — widen the pad of initial_store()"
                        )
                    data[warr][widx] = vals
                    covered = masks.get(warr)
                    if covered is not None:
                        covered[widx] = True
                continue
            pts = np.asarray(g.iterations, dtype=np.int64)
            if guard_l is not None:
                mask = mem.gather(guard_l[1], pts + guard_l[3]) > 0
                pts = pts[mask]
                if pts.shape[0] == 0:
                    continue
            reads = [mem.gather(acc[1], wide_pts(acc, pts)) for acc in reads_l]
            vals = _batched_compute(stmt, reads, pts.shape[0])
            mem.scatter(warr, wide_pts(w_l, pts), vals)
        if _tracing:
            _trace.emit(
                "wavefront.level",
                _t_level,
                level=_level,
                groups=len(groups),
                instances=sum(len(g.iterations) for g in groups),
            )

    result = mem.to_dicts()
    matches = True
    if compare:
        matches = run_sequential(prog, init) == result
    return WavefrontReport(
        store=result,
        schedule=sched,
        stats=WavefrontStats(
            levels=sched.depth,
            batched_ops=sched.batched_ops,
            instances=sched.instances,
            max_width=sched.max_width,
        ),
        matches_sequential=matches,
    )
