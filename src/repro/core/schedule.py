"""Lifting the synchronization optimizer to pipeline-parallel schedules.

A pipeline-parallel run over ``S`` stages × ``M`` microbatches is the
paper's §3.2 setting verbatim: each stage is a processor executing one
"statement" for every iteration (= microbatch) of a loop, and cross-stage
data flow is a set of dependences that must be enforced with
producer/consumer synchronization.  On a TPU pod the send/wait pair is a
``jax.lax.ppermute`` hand-off (plus the implicit fence of the collective).

This module builds the loop program for a stage graph, analyzes its
dependences with the *same* analyzer used for the paper's didactic loops,
and runs the ISD transitive reduction under the ``dswp`` execution model.
What gets eliminated in practice:

  * **skip/fan-out dependences** — e.g. an encoder output consumed by every
    decoder stage (whisper-style cross-attention), or cross-stage residuals:
    the stage-chain hand-offs transitively cover them, so the data can
    piggyback on the chain instead of one collective per consumer stage;
  * **gradient-accumulation dependences** — the optimizer update waits on
    the *last* microbatch's backward only; the per-stage processor order
    covers the other M−1 waits (the paper's "a single send/wait pair can
    synchronize more than one dependence", lifted to DP/PP);
  * **barrier-style over-synchronization** — a naive GPipe flush orders all
    stage pairs; only the data-dependence chain survives reduction.

The retained dependences are emitted as :class:`CommEvent`s consumed by
:mod:`repro.runtime.pipeline`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.dependence import Dependence, analyze
from repro.core.elimination import (
    EliminationResult,
    eliminate_transitive,
    synchronized_set,
)
from repro.core.ir import ArrayRef, LoopProgram, Statement
from repro.core.sync import SyncProgram, insert_synchronization, strip_dependences


@dataclasses.dataclass(frozen=True)
class StageGraph:
    """A pipeline stage graph: ``num_stages`` chained stages plus extra
    (producer_stage → consumer_stage) skip edges (cross-attention,
    residuals crossing stage boundaries, multi-tower fusions...)."""

    num_stages: int
    num_microbatches: int
    skips: Tuple[Tuple[int, int], ...] = ()
    with_backward: bool = False
    grad_accumulation: bool = True

    def forward_name(self, s: int) -> str:
        return f"F{s}"

    def backward_name(self, s: int) -> str:
        return f"B{s}"


def build_pipeline_program(graph: StageGraph) -> LoopProgram:
    """Statements = stage computations; 1-D loop over microbatches.

    ``F_s`` writes ``act_s[m]`` and reads ``act_{s-1}[m]`` (+ skip inputs).
    With backward: ``B_s`` writes ``grad_s[m]`` and the per-stage accumulator
    ``gacc_s[m]`` chain (reads ``gacc_s[m-1]``: a self-dependence, free on the
    stage's own processor), reading ``grad_{s+1}[m]`` and ``act_s[m]``.
    """

    S, M = graph.num_stages, graph.num_microbatches
    stmts: List[Statement] = []
    for s in range(S):
        reads = []
        if s > 0:
            reads.append(ArrayRef(f"act{s-1}", 0))
        for src, dst in graph.skips:
            if dst == s:
                reads.append(ArrayRef(f"act{src}", 0))
        stmts.append(Statement(graph.forward_name(s), ArrayRef(f"act{s}", 0), tuple(reads)))
    if graph.with_backward:
        for s in range(S - 1, -1, -1):
            reads = [ArrayRef(f"act{s}", 0)]
            if s < S - 1:
                reads.append(ArrayRef(f"grad{s+1}", 0))
            if graph.grad_accumulation:
                reads.append(ArrayRef(f"gacc{s}", -1))

            # B_s writes both grad_s[m] and gacc_s[m]; our IR has one write
            # per statement, so split into Bs (grad) and As (accumulate).
            stmts.append(
                Statement(graph.backward_name(s), ArrayRef(f"grad{s}", 0), tuple(reads))
            )
            if graph.grad_accumulation:
                stmts.append(
                    Statement(
                        f"A{s}",
                        ArrayRef(f"gacc{s}", 0),
                        (ArrayRef(f"grad{s}", 0), ArrayRef(f"gacc{s}", -1)),
                    )
                )
    return LoopProgram(statements=tuple(stmts), bounds=((0, M),))


@dataclasses.dataclass(frozen=True)
class CommEvent:
    """One retained synchronization event: a stage-to-stage hand-off for a
    given microbatch distance.  ``src_stmt``/``dst_stmt`` name the pipeline
    statements; in the runtime this lowers to one ppermute step."""

    src_stmt: str
    dst_stmt: str
    array: str
    distance: int


@dataclasses.dataclass(frozen=True)
class PipelineSyncPlan:
    graph: StageGraph
    program: LoopProgram
    dependences: Tuple[Dependence, ...]
    naive_sync: SyncProgram
    optimized_sync: SyncProgram
    elimination: EliminationResult
    events: Tuple[CommEvent, ...]

    def summary(self) -> dict:
        S, M = self.graph.num_stages, self.graph.num_microbatches
        naive = self.naive_sync.sync_instruction_count()
        opt = self.optimized_sync.sync_instruction_count()
        return {
            "stages": S,
            "microbatches": M,
            "synchronized_deps_naive": len(
                synchronized_set(list(self.dependences), "dswp")
            ),
            "synchronized_deps_optimized": len(self.elimination.retained),
            "eliminated": len(self.elimination.eliminated),
            "naive_sync_instructions": naive["total"],
            "optimized_sync_instructions": opt["total"],
            "naive_comm_events_per_step": naive["sends"] * M,
            "optimized_comm_events_per_step": opt["sends"] * M,
        }


def plan_pipeline_sync(graph: StageGraph) -> PipelineSyncPlan:
    """Analyze + synchronize + transitively reduce a pipeline stage graph."""

    prog = build_pipeline_program(graph)
    deps = analyze(prog)
    naive = insert_synchronization(prog, deps, model="dswp")
    elim = eliminate_transitive(prog, deps, model="dswp")
    optimized = strip_dependences(naive, elim.eliminated)
    events = tuple(
        CommEvent(
            src_stmt=d.source,
            dst_stmt=d.sink,
            array=d.array,
            distance=d.distance[0],
        )
        for d in elim.retained
    )
    return PipelineSyncPlan(
        graph=graph,
        program=prog,
        dependences=tuple(deps),
        naive_sync=naive,
        optimized_sync=optimized,
        elimination=elim,
        events=events,
    )


def stage_of(stmt: str) -> int:
    """Map a pipeline statement name (F3/B2/A1) to its stage index."""

    return int(stmt[1:])


def events_by_kind(plan: PipelineSyncPlan) -> Dict[str, List[CommEvent]]:
    """Split retained events into on-chip (same stage) and cross-stage —
    cross-stage events are the ones that cost ICI hops."""

    out: Dict[str, List[CommEvent]] = {"cross_stage": [], "local": []}
    for e in plan.events:
        if stage_of(e.src_stmt) == stage_of(e.dst_stmt):
            out["local"].append(e)
        else:
            out["cross_stage"].append(e)
    return out
