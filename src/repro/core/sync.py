"""send/wait producer–consumer synchronization (paper §4, Alg. 4 → Alg. 5).

``send(reg, i, vars)`` writes value ``i`` to synchronization register ``reg``;
``wait(reg, i - d, vars)`` blocks until iteration ``i - d``'s send on ``reg``
has been posted.  Both carry fence semantics (all memory effects before the
send are visible to anything ordered after the matching wait).

Insertion rule (paper §4.1):
  * after the *source* statement of dependence δ:  ``send(reg_δ, i, vars)``
  * before the *sink*  statement of dependence δ:  ``wait(reg_δ, i − d_δ, vars)``

Only loop-carried dependences (Δ ≠ 0) are synchronized; Δ = 0 dependences are
enforced by intra-iteration program order.

Send-merging (paper §4.2, "allowing a single send/wait pair to synchronize
more than one dependence"): dependences sharing a source statement can share
one register and therefore one ``send`` — the waits remain per-dependence with
their own distances.  :func:`merge_sends` implements this.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.dependence import Dependence, analyze, loop_carried
from repro.core.ir import LoopProgram


@dataclasses.dataclass(frozen=True)
class Send:
    reg: int
    # iteration value posted is the current loop index vector (offset 0)
    vars: Tuple[str, ...]

    def pretty(self, ivar: str = "i") -> str:
        return f"send({self.reg}, {ivar}, {','.join(self.vars)})"


@dataclasses.dataclass(frozen=True)
class Wait:
    reg: int
    distance: Tuple[int, ...]  # wait for iteration (i - distance)
    vars: Tuple[str, ...]

    def pretty(self, ivar: str = "i") -> str:
        if len(self.distance) == 1:
            d = self.distance[0]
            expr = f"{ivar}-{d}" if d else ivar
        else:
            expr = "(" + ",".join(
                f"{ivar}{k}-{d}" if d else f"{ivar}{k}"
                for k, d in enumerate(self.distance)
            ) + ")"
        return f"wait({self.reg}, {expr}, {','.join(self.vars)})"


@dataclasses.dataclass(frozen=True)
class SyncProgram:
    """A loop program with per-statement pre-waits and post-sends."""

    program: LoopProgram
    pre_waits: Dict[str, Tuple[Wait, ...]]
    post_sends: Dict[str, Tuple[Send, ...]]
    # register → the dependences it synchronizes (for reporting/elimination)
    registers: Dict[int, Tuple[Dependence, ...]]

    # ------------------------------------------------------------------ #
    def sync_instruction_count(self) -> Dict[str, int]:
        sends = sum(len(v) for v in self.post_sends.values())
        waits = sum(len(v) for v in self.pre_waits.values())
        return {"sends": sends, "waits": waits, "total": sends + waits}

    def runtime_sync_ops(self) -> int:
        """Static count × iterations: sync operations executed per full run."""

        iters = 1
        for lo, hi in self.program.bounds:
            iters *= max(0, hi - lo)
        return self.sync_instruction_count()["total"] * iters

    def pretty(self) -> str:
        lines = ["for parallel i = ...:"]
        for s in self.program.statements:
            for w in self.pre_waits.get(s.name, ()):
                lines.append(f"  {w.pretty()}")
            lines.append(f"  {s}")
            for snd in self.post_sends.get(s.name, ()):
                lines.append(f"  {snd.pretty()}")
        return "\n".join(lines)


def _register_order(prog: LoopProgram, deps: Sequence[Dependence]) -> List[Dependence]:
    """Register numbering that reproduces Alg. 5: by source statement lexical
    position, then sink lexical position, then distance."""

    return sorted(
        deps,
        key=lambda d: (
            prog.lexical_index(d.source),
            prog.lexical_index(d.sink),
            d.distance,
        ),
    )


def insert_synchronization(
    prog: LoopProgram,
    deps: Sequence[Dependence] | None = None,
    merge: bool = False,
    model: str = "doall",
) -> SyncProgram:
    """Insert send/wait pairs for every dependence that the execution model
    does not enforce for free (Alg. 5).

    ``model="doall"`` (paper §4.1): loop-carried deps only.  ``model="dswp"``
    (§3.2 pipelining): all cross-statement deps, including Δ=0.  With
    ``merge=True``, dependences with the same source statement share a
    register/send (paper §4.2 first optimization).
    """

    from repro.core.elimination import synchronized_set

    if deps is None:
        deps = analyze(prog)
    carried = _register_order(prog, synchronized_set(deps, model))

    reg_of: Dict[int, int] = {}  # index into `carried` → register
    registers: Dict[int, Tuple[Dependence, ...]] = {}
    if merge:
        by_source: Dict[str, int] = {}
        for k, d in enumerate(carried):
            if d.source not in by_source:
                by_source[d.source] = len(by_source)
            reg_of[k] = by_source[d.source]
    else:
        for k in range(len(carried)):
            reg_of[k] = k
    for k, d in enumerate(carried):
        r = reg_of[k]
        registers[r] = registers.get(r, ()) + (d,)

    pre: Dict[str, List[Wait]] = {s: [] for s in prog.names}
    post: Dict[str, List[Send]] = {s: [] for s in prog.names}

    emitted_send: set[int] = set()
    for k, d in enumerate(carried):
        r = reg_of[k]
        if r not in emitted_send:
            emitted_send.add(r)
            vars_ = tuple(sorted({x.array for x in registers.get(r, (d,))})) or (
                d.array,
            )
            post[d.source].append(Send(reg=r, vars=(d.array,) if not merge else vars_))
        pre[d.sink].append(Wait(reg=r, distance=d.distance, vars=(d.array,)))

    # order waits to match the sink statement's read order (Alg. 5 shows
    # wait(1, i-2, b) before wait(0, i-1, a) for S3: b[i-2] + a[i-1])
    for name in prog.names:
        stmt = prog.statement(name)
        read_pos = {r.array: p for p, r in reversed(list(enumerate(stmt.reads)))}
        pre[name].sort(key=lambda w: read_pos.get(w.vars[0], len(stmt.reads)))

    return SyncProgram(
        program=prog,
        pre_waits={k: tuple(v) for k, v in pre.items()},
        post_sends={k: tuple(v) for k, v in post.items()},
        registers=registers,
    )


def strip_dependences(
    sync: SyncProgram, eliminated: Sequence[Dependence]
) -> SyncProgram:
    """Remove the send/wait pairs of eliminated dependences.

    A register's send survives while it still synchronizes at least one
    retained dependence; waits are removed per (register, distance, array).
    """

    gone = {
        (d.source, d.sink, d.array, d.distance, d.kind, d.nonaffine)
        for d in eliminated
    }

    def keep(d: Dependence) -> bool:
        return (
            d.source,
            d.sink,
            d.array,
            d.distance,
            d.kind,
            d.nonaffine,
        ) not in gone

    registers = {
        r: tuple(d for d in ds if keep(d)) for r, ds in sync.registers.items()
    }
    live_regs = {r for r, ds in registers.items() if ds}

    pre = {
        name: tuple(
            w
            for w in ws
            if w.reg in live_regs
            and any(
                d.sink == name and d.distance == w.distance and d.array in w.vars
                for d in registers[w.reg]
            )
        )
        for name, ws in sync.pre_waits.items()
    }
    post = {
        name: tuple(s for s in ss if s.reg in live_regs)
        for name, ss in sync.post_sends.items()
    }
    return SyncProgram(
        program=sync.program,
        pre_waits=pre,
        post_sends=post,
        registers={r: ds for r, ds in registers.items() if ds},
    )
