"""Paper-faithful core: dependence analysis, loop parallelization, and
producer/consumer synchronization optimization (Liao et al., 2012)."""

from repro.core.dependence import ANTI, CONTROL, FLOW, OUTPUT, Dependence, analyze, loop_carried
from repro.core.elimination import (
    EliminationResult,
    eliminate_pattern,
    eliminate_transitive,
    synchronized_set,
)
from repro.core.executor import run_threaded
from repro.core.fission import FissionResult, fission
from repro.core.ir import (
    ArrayRef,
    LoopProgram,
    Statement,
    paper_alg1,
    paper_alg4,
    paper_alg6,
    run_sequential,
)
from repro.core.isd import build_isd, isd_window, prime_factors
from repro.core.parallelizer import (
    BackendSpec,
    ParallelizationReport,
    analysis_cache_stats,
    clear_analysis_cache,
    execution_backends,
    get_backend,
    parallelize,
    register_backend,
    registered_backends,
)
from repro.core.schedule import (
    CommEvent,
    PipelineSyncPlan,
    StageGraph,
    plan_pipeline_sync,
)
from repro.core.scc import (
    SccInfo,
    SccPartition,
    analyze_sccs,
    hybrid_levels,
    scc_signature,
    tarjan_sccs,
    validate_retained,
)
from repro.core.sync import (
    Send,
    SyncProgram,
    Wait,
    insert_synchronization,
    strip_dependences,
)
from repro.core.wavefront import (
    WavefrontError,
    WavefrontSchedule,
    run_wavefront,
    schedule_wavefronts,
)

__all__ = [
    "ANTI",
    "BackendSpec",
    "CONTROL",
    "FLOW",
    "OUTPUT",
    "ArrayRef",
    "CommEvent",
    "Dependence",
    "EliminationResult",
    "FissionResult",
    "LoopProgram",
    "ParallelizationReport",
    "PipelineSyncPlan",
    "SccInfo",
    "SccPartition",
    "Send",
    "StageGraph",
    "Statement",
    "SyncProgram",
    "Wait",
    "WavefrontError",
    "WavefrontSchedule",
    "analysis_cache_stats",
    "analyze",
    "analyze_sccs",
    "build_isd",
    "clear_analysis_cache",
    "execution_backends",
    "get_backend",
    "eliminate_pattern",
    "eliminate_transitive",
    "fission",
    "hybrid_levels",
    "insert_synchronization",
    "isd_window",
    "loop_carried",
    "paper_alg1",
    "paper_alg4",
    "paper_alg6",
    "parallelize",
    "plan_pipeline_sync",
    "prime_factors",
    "register_backend",
    "registered_backends",
    "run_sequential",
    "run_threaded",
    "run_wavefront",
    "scc_signature",
    "schedule_wavefronts",
    "strip_dependences",
    "synchronized_set",
    "tarjan_sccs",
    "validate_retained",
]
