"""Dependence-graph algorithms: Tarjan SCC, contraction, topological sort,
and the pipeline partitioning of §3.2 (decoupled software pipelining).

The paper's §3 recipe (after Midkiff [17]):
  1. build the dependence graph for the loop nest;
  2. find strongly connected components, contract each SCC into one node;
  3. mark single-statement nodes as parallel;
  4. topologically sort so all inter-node dependences are lexically forward;
  5. group independent, unordered nodes reading the same data (locality);
  6. loop fission: one loop per node (see :mod:`repro.core.fission`);
  7. mark loops from parallel nodes as parallel.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, List, Sequence, Tuple

from repro.core.dependence import Dependence
from repro.core.ir import LoopProgram


@dataclasses.dataclass(frozen=True)
class DepGraph:
    """Statement-level dependence graph."""

    nodes: Tuple[str, ...]
    edges: Tuple[Dependence, ...]

    @staticmethod
    def build(prog: LoopProgram, deps: Sequence[Dependence]) -> "DepGraph":
        return DepGraph(nodes=prog.names, edges=tuple(deps))

    def successors(self, node: str) -> List[Tuple[str, Dependence]]:
        return [(e.sink, e) for e in self.edges if e.source == node]

    def adjacency(self) -> Dict[str, List[str]]:
        adj: Dict[str, List[str]] = {n: [] for n in self.nodes}
        for e in self.edges:
            if e.sink not in adj[e.source]:
                adj[e.source].append(e.sink)
        return adj


def tarjan_scc(nodes: Sequence[str], adj: Dict[str, List[str]]) -> List[FrozenSet[str]]:
    """Tarjan's algorithm, iterative (no recursion-limit surprises).

    Returns SCCs in *reverse topological order* of the condensation (Tarjan's
    natural emission order).
    """

    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Dict[str, bool] = {}
    stack: List[str] = []
    sccs: List[FrozenSet[str]] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work: List[Tuple[str, int]] = [(root, 0)]
        while work:
            node, ei = work.pop()
            if ei == 0:
                index[node] = low[node] = counter[0]
                counter[0] += 1
                stack.append(node)
                on_stack[node] = True
            recurse = False
            succs = adj.get(node, [])
            for k in range(ei, len(succs)):
                nxt = succs[k]
                if nxt not in index:
                    work.append((node, k + 1))
                    work.append((nxt, 0))
                    recurse = True
                    break
                if on_stack.get(nxt, False):
                    low[node] = min(low[node], index[nxt])
            if recurse:
                continue
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    comp.append(w)
                    if w == node:
                        break
                sccs.append(frozenset(comp))
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
    return sccs


@dataclasses.dataclass(frozen=True)
class CondensedNode:
    """A node of the SCC-contracted graph (paper step 2)."""

    statements: FrozenSet[str]

    @property
    def is_parallel(self) -> bool:
        """Paper step 3: single-statement nodes are parallel ... unless the
        statement carries a self-dependence (a genuine 1-cycle)."""

        return len(self.statements) == 1 and not self._self_cycle

    _self_cycle: bool = False

    def label(self) -> str:
        return "+".join(sorted(self.statements))


@dataclasses.dataclass(frozen=True)
class CondensedGraph:
    nodes: Tuple[CondensedNode, ...]
    # edges between condensed nodes, carrying the original dependences
    edges: Tuple[Tuple[int, int, Dependence], ...]

    def node_of(self, stmt: str) -> int:
        for k, n in enumerate(self.nodes):
            if stmt in n.statements:
                return k
        raise KeyError(stmt)


def condense(graph: DepGraph) -> CondensedGraph:
    """Contract SCCs into single nodes (paper steps 2–3)."""

    sccs = tarjan_scc(list(graph.nodes), graph.adjacency())
    self_cycles = {e.source for e in graph.edges if e.source == e.sink}
    nodes = tuple(
        CondensedNode(
            statements=s,
            _self_cycle=(len(s) == 1 and next(iter(s)) in self_cycles),
        )
        for s in sccs
    )
    where: Dict[str, int] = {}
    for k, n in enumerate(nodes):
        for stmt in n.statements:
            where[stmt] = k
    edges = tuple(
        (where[e.source], where[e.sink], e)
        for e in graph.edges
        if where[e.source] != where[e.sink]
    )
    return CondensedGraph(nodes=nodes, edges=edges)


def topological_order(graph: CondensedGraph, prog: LoopProgram) -> List[int]:
    """Kahn topological sort of the condensation.

    Ties are broken by the *lexical* position of the earliest statement in
    the node, which reproduces the paper's Alg. 2 ordering (S2, S1, S4, S3)
    deterministically.
    """

    n = len(graph.nodes)
    indeg = [0] * n
    adj: Dict[int, List[int]] = {k: [] for k in range(n)}
    seen = set()
    for a, b, _ in graph.edges:
        if (a, b) in seen:
            continue
        seen.add((a, b))
        adj[a].append(b)
        indeg[b] += 1

    def lex_key(k: int) -> int:
        return min(prog.lexical_index(s) for s in graph.nodes[k].statements)

    ready = sorted([k for k in range(n) if indeg[k] == 0], key=lex_key)
    order: List[int] = []
    while ready:
        k = ready.pop(0)
        order.append(k)
        for nxt in adj[k]:
            indeg[nxt] -= 1
            if indeg[nxt] == 0:
                ready.append(nxt)
        ready.sort(key=lex_key)
    if len(order) != n:
        raise RuntimeError("condensed dependence graph is not acyclic")
    return order


def pipeline_stages(
    graph: CondensedGraph, prog: LoopProgram, num_threads: int
) -> List[List[int]]:
    """Decoupled-software-pipelining stage assignment (paper §3.2, Fig. 4).

    Contracted nodes, in topological order, are assigned to ``num_threads``
    pipeline stages balancing statement count — SCCs execute sequentially
    within a stage while different iterations overlap across stages.
    """

    order = topological_order(graph, prog)
    total = sum(len(graph.nodes[k].statements) for k in order)
    per = max(1, -(-total // num_threads))  # ceil
    stages: List[List[int]] = [[]]
    count = 0
    for k in order:
        w = len(graph.nodes[k].statements)
        if count + w > per and stages[-1] and len(stages) < num_threads:
            stages.append([])
            count = 0
        stages[-1].append(k)
        count += w
    return stages
