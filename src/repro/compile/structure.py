"""Canonical structural hashing of loop programs for the compile cache.

The compile cache (:mod:`repro.compile.cache`) must reuse one compiled
artifact across every request with the *same dependence structure* — the
serving path re-plans the identical decode loop once per batch wave, and the
Pallas K-loop plan re-lowers the identical ISSUE/LOAD/COMPUTE loop for every
``steps`` value.  The key therefore covers exactly what the lowering
specializes on and nothing else:

  * the statement graph — statement names in lexical order, their write /
    read / guard accesses (array name + constant offset vector), and a
    *behavioral* fingerprint of each compute function;
  * the retained (synchronized) dependences, as an order-insensitive set;
  * the execution model (``doall`` / ``dswp`` / ``procmap`` + processor map);
  * the SCC partition of the statement graph (:func:`repro.core.scc_signature`
    — membership + recurrence flags + the bounds-free unimodular-skew
    candidate per recurrence SCC), the DOACROSS ``chunk_limit`` knob, and
    the ``scc_policy`` knob — the *resolved policy object* canonicalized by
    :func:`_const_fp` with its full instance state (nested policies,
    ndarray-valued knobs by content hash), so two artifacts that condense,
    chunk, skew, or strategize the same graph differently can never alias.
    Chunk *sizes* and the cost model's per-bounds strategy choice are
    linearized against concrete bounds and live in the per-bounds table
    cache below.

Deliberately **excluded**: the loop bounds.  Two requests that differ only in
iteration count share a key (the per-bounds level tables are a second-level
cache inside :class:`repro.compile.lowering.CompiledProgram`), which is what
makes the cache useful for serving traffic whose batch sizes vary.

Compute functions are fingerprinted by *code*, not identity: re-creating a
behaviorally identical lambda (same bytecode, consts, closure values) in a
new request maps to the same key, so a cache keyed this way survives the
common pattern of rebuilding the program object per request.  Changing the
bytecode, a captured constant, or a default argument changes the key.

This module is import-light on purpose (no jax, no numpy): the parallelizer
consults :func:`program_fingerprint` for its analysis memo without paying the
jax import.
"""

from __future__ import annotations

import functools
import hashlib
import itertools
import re
import types
from typing import Dict, Optional, Sequence, Tuple

from repro.core.dependence import Dependence
from repro.core.ir import ArrayRef, LoopProgram, is_indirect
from repro.core.policy import SccPolicyLike

_PRIMITIVES = (int, float, bool, str, bytes, type(None))


def _const_fp(value: object, _seen: frozenset = frozenset()) -> object:
    """Canonicalize one captured value (nested code objects recurse — their
    ``repr`` embeds a memory address, which would break identity
    invariance; buffer-backed arrays hash their full contents — ``repr``
    truncates large arrays, which would collide distinct lookup tables).
    Cyclic containers/objects are cut with the visited set."""

    if isinstance(value, _PRIMITIVES):
        return value
    if id(value) in _seen:
        return ("cycle",)
    _seen = _seen | {id(value)}
    if isinstance(value, types.ModuleType):
        return ("module", value.__name__)
    if isinstance(value, type):
        return ("class", value.__module__, value.__qualname__)
    if isinstance(value, types.CodeType):
        return _code_fp(value, _seen)
    if isinstance(value, tuple):
        return tuple(_const_fp(v, _seen) for v in value)
    if isinstance(value, (list, set, frozenset)):
        kind = type(value).__name__
        items = [_const_fp(v, _seen) for v in value]
        if isinstance(value, (set, frozenset)):
            # sort the *canonical forms* — raw reprs would bypass the
            # address-guard/state introspection and collide distinct objects
            items = sorted(items, key=repr)
        return (kind, tuple(items))
    if isinstance(value, dict):
        return (
            "dict",
            tuple(
                sorted(
                    ((_const_fp(k, _seen), _const_fp(v, _seen))
                     for k, v in value.items()),
                    key=repr,
                )
            ),
        )
    tobytes = getattr(value, "tobytes", None)
    if callable(tobytes) and hasattr(value, "dtype"):  # ndarray-likes
        return (
            "ndarray",
            str(value.dtype),
            tuple(getattr(value, "shape", ())),
            hashlib.sha256(tobytes()).hexdigest(),
        )
    if callable(value):
        # callables captured as instance state (e.g. a policy's level_cost
        # hook) key by behavior: two distinct lambdas share the qualname
        # "<lambda>", which _object_fp would collide
        return compute_fingerprint(value, _seen=_seen)
    return _object_fp(value, _seen)


# default object reprs embed a memory address that the allocator can *reuse*
# after a free — two different objects fingerprinting equal would be a false
# cache hit, the one failure mode this module must never have
_ADDR_REPR = re.compile(r" at 0x[0-9a-fA-F]+>")
_MISS_TOKEN = itertools.count()


def _object_fp(value: object, _seen: frozenset = frozenset()) -> object:
    state = getattr(value, "__dict__", None)
    if state is None and hasattr(type(value), "__slots__"):
        state = {
            s: getattr(value, s)
            for s in type(value).__slots__
            if hasattr(value, s)
        }
    if state is not None:
        return (
            "object",
            type(value).__module__,
            type(value).__qualname__,
            tuple(
                sorted((k, _const_fp(v, _seen)) for k, v in state.items())
            ),
        )
    r = repr(value)
    if _ADDR_REPR.search(r):
        # address-bearing repr with no introspectable state: unknowable
        # behavior — force a cache miss rather than risk a false hit
        return ("opaque-unhashable", next(_MISS_TOKEN))
    return r


def _code_fp(code: types.CodeType, _seen: frozenset = frozenset()) -> Tuple:
    return (
        "code",
        code.co_code.hex(),
        tuple(_const_fp(c, _seen) for c in code.co_consts),
        code.co_names,
        code.co_varnames[: code.co_argcount + code.co_kwonlyargcount],
    )


def _all_names(code: types.CodeType) -> Tuple[str, ...]:
    """``co_names`` of ``code`` and every nested code object (lambdas in
    lambdas share the enclosing function's globals)."""

    names = list(code.co_names)
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            names.extend(_all_names(c))
    return tuple(dict.fromkeys(names))


def _value_fp(v: object, seen: frozenset) -> object:
    if callable(v):
        return compute_fingerprint(v, _seen=seen)
    return _const_fp(v, seen)


def compute_fingerprint(fn: object, *, _seen: frozenset = frozenset()) -> Tuple:
    """Behavioral fingerprint of a compute callable.

    Identity-insensitive: two functions compiled from the same source (same
    bytecode, consts, names, closure values, referenced globals, defaults)
    fingerprint equal.  Closure cells, ``functools.partial`` bindings and
    the *values of referenced globals* participate by value — a function
    whose bytecode reads ``SCALE`` from its module keys differently for
    ``SCALE=2`` and ``SCALE=3``, so the compile cache cannot silently reuse
    the wrong artifact.  Recursion through self-referencing globals/closures
    is cut with a visited set.
    """

    if id(fn) in _seen:
        return ("cycle",)
    _seen = _seen | {id(fn)}
    if isinstance(fn, type):
        # classes referenced as values key by qualified name (stable)
        return ("class", fn.__module__, fn.__qualname__)
    if isinstance(fn, types.MethodType):
        # bound methods proxy their function's __code__, but behave per
        # their receiver's state: Scaler(2).scale ≠ Scaler(3).scale
        return (
            "bound-method",
            compute_fingerprint(fn.__func__, _seen=_seen),
            _const_fp(fn.__self__, _seen),
        )
    if isinstance(fn, functools.partial):
        return (
            "partial",
            compute_fingerprint(fn.func, _seen=_seen),
            tuple(_value_fp(a, _seen) for a in fn.args),
            tuple(
                sorted(
                    (k, _value_fp(v, _seen))
                    for k, v in fn.keywords.items()
                )
            ),
        )
    code = getattr(fn, "__code__", None)
    if code is None:
        call = getattr(type(fn), "__call__", None)
        if getattr(call, "__code__", None) is not None:
            # stateful callable object: the behavior is (__call__ code ×
            # instance state) — fingerprint both, so Scaler(2) ≠ Scaler(3)
            return (
                "callable-object",
                compute_fingerprint(call, _seen=_seen),
                _object_fp(fn, _seen),
            )
        if isinstance(fn, types.BuiltinFunctionType):
            return ("builtin", fn.__module__, fn.__qualname__)
        # C-extension callables (e.g. numpy ufuncs): key on type + state /
        # stable repr — _object_fp itself forces a miss only when the repr
        # carries a reusable memory address
        return ("c-callable", _object_fp(fn, _seen))
    cells: Tuple = ()
    closure = getattr(fn, "__closure__", None)
    if closure:
        vals = []
        for cell in closure:
            try:
                v = cell.cell_contents
            except ValueError:  # pragma: no cover - empty cell
                vals.append("<empty-cell>")
                continue
            vals.append(_value_fp(v, _seen))
        cells = tuple(vals)
    defaults = getattr(fn, "__defaults__", None) or ()
    kwdefaults = getattr(fn, "__kwdefaults__", None) or {}
    fn_globals = getattr(fn, "__globals__", None) or {}
    names = _all_names(code)
    global_fp = []
    for name in names:
        if name not in fn_globals:
            continue
        v = fn_globals[name]
        if isinstance(v, types.ModuleType):
            # ``config.SCALE`` reads one attribute hop into a module: hash
            # the values of every co_name that resolves on it, so mutating
            # the module constant changes the key.  (Dynamic state further
            # away — config.get()... — is out of fingerprint scope; callers
            # with such computes should clear_compile_cache() on change.)
            global_fp.append(
                (
                    name,
                    "module",
                    v.__name__,
                    tuple(
                        (attr, _value_fp(getattr(v, attr), _seen))
                        for attr in names
                        if attr != name and hasattr(v, attr)
                    ),
                )
            )
        else:
            global_fp.append((name, _value_fp(v, _seen)))
    global_fp = tuple(global_fp)
    return (
        "fn",
        _code_fp(code, _seen),
        cells,
        tuple(_const_fp(d, _seen) for d in defaults),
        tuple(
            sorted((k, _const_fp(v, _seen)) for k, v in kwdefaults.items())
        ),
        global_fp,
    )


def _ref_sig(ref: Optional[ArrayRef]) -> Optional[Tuple]:
    if ref is None:
        return None
    if is_indirect(ref):
        # a[idx[i+o]] + c keys by (target, index array, index offset, +c) —
        # never by index *contents*: those are store data, and anything
        # store-dependent (the inspector's instance graph) lives with the
        # per-bounds tables, not the structural key
        return (
            "indirect",
            ref.array,
            ref.index.array,
            ref.index.offset_tuple(),
            ref.offset,
        )
    return (ref.array, ref.offset_tuple())


def program_signature(prog: LoopProgram) -> Tuple:
    """Bounds-free canonical form of the statement graph."""

    return (
        "loop-program",
        prog.ndim,
        tuple(
            (
                s.name,
                _ref_sig(s.write),
                tuple(_ref_sig(r) for r in s.reads),
                _ref_sig(s.guard),
                compute_fingerprint(s.compute),
            )
            for s in prog.statements
        ),
    )


def dependence_signature(deps: Sequence[Dependence]) -> Tuple:
    """Order-insensitive canonical form of a dependence set."""

    return tuple(
        sorted(
            (d.kind, d.source, d.sink, d.array, d.distance, d.nonaffine)
            for d in deps
        )
    )


def _digest(payload: Tuple) -> str:
    return hashlib.sha256(repr(payload).encode()).hexdigest()


def program_fingerprint(prog: LoopProgram) -> str:
    """Hash of the statement graph alone (no dependences, no bounds) — the
    parallelizer's analysis-memo key component."""

    return _digest(program_signature(prog))


def structural_key(
    prog: LoopProgram,
    retained: Sequence[Dependence],
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: SccPolicyLike = None,
    deps: Optional[str] = None,
) -> str:
    """The compile-cache key: hash of (statement graph, retained dependence
    set, execution model, SCC partition incl. bounds-free skew candidates,
    chunk knob, scheduling-policy knob, non-affine ``deps`` mode).  Loop
    bounds do not participate — under ``scc_policy="auto"`` the cost model
    may pick different strategies for different bounds of one structure,
    which is exactly why the chosen strategy lives with the per-bounds level
    tables inside the artifact while the *policy* (and the bounds-free skew
    matrix each SCC would use) lives here.  ``deps`` is the
    ``"inspect"``/``"speculate"`` *knob* only — it is structural like
    ``chunk_limit``; the inspector's store-dependent instance graph never
    reaches this key (it lives with the per-bounds tables)."""

    from repro.core.policy import resolve_policy
    from repro.core.scc import scc_signature

    procs = (
        tuple(sorted((k, repr(v)) for k, v in processors.items()))
        if processors
        else None
    )
    # The policy participates by full canonicalized instance state, not by
    # name or repr: _const_fp recurses into __dict__ (nested policy objects,
    # ndarray-valued knobs by content hash, address-bearing reprs forced to
    # miss), so two differently-configured custom policies can never alias
    # one artifact — the same no-false-hits bar the compute fingerprints
    # are held to.
    policy_fp = ("scc-policy", _const_fp(resolve_policy(scc_policy)))
    return _digest(
        (
            program_signature(prog),
            dependence_signature(retained),
            model,
            procs,
            scc_signature(prog, retained, model, processors),
            chunk_limit,
            policy_fp,
            deps,
        )
    )
