"""JAX/XLA lowering of a wavefront schedule to one jitted executable.

The NumPy wavefront backend (:mod:`repro.core.wavefront`) interprets the
level schedule: a Python loop over ~2·N levels, each doing a small gather /
compute / scatter.  For the deep, narrow schedules the paper's loops produce
(Alg. 6 at 1024 iterations has 2047 levels of width ≤ 2 after the batched
level 0) the Python-level dispatch dominates.  This module compiles the whole
level loop into a single XLA computation instead:

  * every array of the memory image becomes one flat ``float64`` buffer with
    a *trash cell* appended past the live data — masked-out lanes scatter
    there, so padding never corrupts the store;
  * each statement's wavefront groups are packed level-sorted into padded
    index tables of shape ``(G, W)`` (``G`` groups padded with a sentinel
    row, ``W`` lanes padded with redirected indices) — per-statement widths,
    so a 1024-wide DOALL statement does not inflate a width-1 chain;
  * the executable is a ``lax.fori_loop`` over levels whose body keeps one
    cursor per statement: when the cursor's next group belongs to the
    current level, a ``lax.cond`` runs that group's vectorized
    gather/compute/scatter and advances the cursor.  Per level, only the
    statements that actually have work pay for it.

Because the tables are *data*, the group/lane axes are padded to
power-of-two buckets, and every per-bounds scalar (level count, segment
extents, cursor bases, chunk counts) is a *traced argument*, one traced
artifact serves any iteration count whose bucketed shapes coincide.  That is
the third level of the cache hierarchy — structure → **bucket** → trace →
per-bounds tables: the structural cache (:mod:`repro.compile.cache`) maps a
dependence structure to one :class:`CompiledProgram`; inside it, jax's jit
cache keys each trace on the bounds-free statics plus bucketed shapes (the
"bucket", mirrored host-side in ``PreparedCase.bucket`` and counted through
the ``xla.traces`` / ``xla.bucket_*`` metrics); under each trace, the
per-(bounds, layout, content) table LRU supplies the values.  A serving loop
over a fixed structure-and-bucket mix therefore re-traces exactly zero times
at steady state, which ``benchmarks/run.py``'s ``serve_sustained_traffic``
row gates on.

Hybrid (SCC-condensed) schedules add one more structure: a cyclic SCC's
chunked DOACROSS block appears as a *recurrence band* — a run of consecutive
levels whose active groups are the same statements at consecutive table rows.
Those bands lower to a nested ``lax.fori_loop`` over chunks with the store
(the recurrence carry) in the loop state: no per-level ``lax.cond`` dispatch,
no cursor bookkeeping, only the band's statements in the loop body.  The
band detector is strategy-agnostic: a unimodular-*skew* SCC's diagonal
wavefronts and a per-SCC-*dswp* pipeline's lane progressions also advance
one table row per level in lockstep, so they collapse into the same nested
loop — the skew's index remap back to original coordinates is already folded
into the level tables (the schedule emits original iteration points), and
each dswp lane is simply its statement's own (group × lane) table.  Levels
outside any band keep the generic cursor machinery, so pipelined schedules
that interleave a recurrence with downstream acyclic levels still compile.
Only the segment *skeleton* (kinds + band statement sets) is static; segment
extents, cursor bases and chunk counts travel in per-segment ``int32``
vectors (``PreparedCase.seg_dyn``), so hybrid artifacts bucket-share traces
exactly like acyclic ones.  Schedules without recurrence SCCs take a single
level loop over a traced level count.

Everything runs in ``float64`` (via :func:`jax.experimental.enable_x64`), so
stores are bit-equal to :func:`repro.core.ir.run_sequential` — the same
contract the other executors are held to by ``tests/oracle.py``.

Error parity with the NumPy backend: an access outside the initialized store
raises ``KeyError("… outside the initialized store …")`` (statically for
unguarded statements, via an in-loop flag for guard-dependent ones), and a
read of an uninitialized cell of a sparse store raises
``KeyError("… uninitialized …")`` (tracked at run time with per-array
coverage buffers, since an earlier level may legitimately initialize a cell a
later level reads).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.core.dependence import Dependence
from repro.core.ir import LoopProgram, is_indirect
from repro.core.policy import SccPolicyLike
from repro.core.wavefront import (
    WavefrontSchedule,
    WavefrontStats,
    _DenseStore,
    schedule_levels,
)


class XlaLoweringError(ValueError):
    """The program cannot be lowered to XLA (e.g. untraceable compute fn)."""


# one rounding convention for table padding AND the cost model's padded-lane
# estimate (repro.compile.xla_level_cost) — they must never drift apart
from repro.compile import _next_pow2  # noqa: E402


# Width ladder for recurrence bands (ROADMAP 3b).  A band's ramp-up and
# ramp-down levels run at sliced lane widths — halvings of the padded band
# width, at most WIDTH_LADDER_RUNGS of them, never narrower than
# WIDTH_LADDER_MIN lanes (below that the per-step dispatch cost dwarfs any
# lane saving).  Read late (module attribute lookup, not captured values)
# so benchmarks can pin ``lowering.WIDTH_LADDER_RUNGS = 0`` for an unsplit
# control build.
WIDTH_LADDER_RUNGS = 3
WIDTH_LADDER_MIN = 8


# ---------------------------------------------------------------------- #
# Strict lane arithmetic.  XLA's CPU emitter compiles the whole computation
# into one LLVM function with aggressive FP op fusion, so a multiply feeding
# an add is contracted into an FMA — a 1-ulp divergence from the scalar
# interpreters that appears and disappears with fusion context, and that
# neither ``lax.optimization_barrier`` nor the documented fast-math flags
# suppress (the contraction happens below HLO, in instruction selection).
#
# The compute functions are therefore evaluated on proxies that *launder*
# every arithmetic result through an integer ``xor`` with a runtime-opaque
# zero (a scalar argument of the jitted executable, so neither XLA's
# algebraic simplifier nor LLVM's InstCombine can fold it away).  The
# laundering is bit-exact — including -0.0 and NaN — and severs every
# producer→consumer float pattern, forcing each IEEE op to round
# individually exactly like the sequential oracle.  Cost: two bitcasts and
# an integer xor per op per lane, on expressions a handful of ops long.
# ---------------------------------------------------------------------- #

class _StrictLane:
    """Operator-intercepting wrapper around a lane vector.

    ``z`` is the runtime-opaque int64 zero used to launder results.
    """

    __slots__ = ("x", "z")

    def __init__(self, x, z) -> None:
        self.x = x
        self.z = z

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_StrictLane({self.x!r})"

    def __bool__(self) -> bool:
        # `if lane:` would silently take one branch for every lane; raising
        # routes value-branching computes into the vmap fallback, where jax
        # gives the same treatment (trace error → XlaLoweringError)
        raise TypeError(
            "compute fn branches on a lane vector's truth value; "
            "per-lane branching is not vectorizable — use arithmetic "
            "selects or run backend='wavefront'"
        )


def _unwrap(v):
    return v.x if isinstance(v, _StrictLane) else v


def _protect(x, z):
    import jax.numpy as jnp
    from jax import lax

    x = jnp.asarray(x)
    if x.dtype != jnp.float64:  # int/bool intermediates are already exact
        return x
    bits = lax.bitcast_convert_type(x, jnp.int64)
    return lax.bitcast_convert_type(jnp.bitwise_xor(bits, z), jnp.float64)


def _launder_operand(v, z):
    """Make an operand runtime-opaque (python scalars become laundered f64
    constants).  Used for division-family ops: XLA rewrites division by a
    *compile-time* constant into a reciprocal multiply, which is not
    correctly rounded (e.g. ``x / 3`` differs from IEEE by 1 ulp for some
    x); a laundered divisor forces a true hardware ``fdiv``."""

    import jax.numpy as jnp

    if isinstance(v, (int, float)):
        v = jnp.asarray(float(v), jnp.float64)
    return _protect(v, z)


def _strict_binop(op, swap: bool, launder_operands: bool = False):
    def method(self, other):
        a, b = _unwrap(self), _unwrap(other)
        if launder_operands:
            a, b = _launder_operand(a, self.z), _launder_operand(b, self.z)
        if swap:
            a, b = b, a
        return _StrictLane(_protect(op(a, b), self.z), self.z)

    return method


def _strict_unop(op):
    def method(self):
        return _StrictLane(_protect(op(self.x), self.z), self.z)

    return method


def _install_strict_ops() -> None:
    import operator

    for name, op, launder in [
        ("add", operator.add, False),
        ("sub", operator.sub, False),
        ("mul", operator.mul, False),
        ("truediv", operator.truediv, True),
        ("floordiv", operator.floordiv, True),
        ("mod", operator.mod, True),
        ("pow", operator.pow, True),
    ]:
        setattr(
            _StrictLane, f"__{name}__", _strict_binop(op, False, launder)
        )
        setattr(
            _StrictLane, f"__r{name}__", _strict_binop(op, True, launder)
        )
    for name, op in [
        ("neg", operator.neg),
        ("pos", operator.pos),
        ("abs", operator.abs),
    ]:
        setattr(_StrictLane, f"__{name}__", _strict_unop(op))
    for name, op in [
        ("lt", operator.lt),
        ("le", operator.le),
        ("gt", operator.gt),
        ("ge", operator.ge),
        ("eq", operator.eq),  # value comparison, NOT python identity —
        ("ne", operator.ne),  # default object.__eq__ would be silently wrong
    ]:
        # comparisons exit the strict domain (no rounding to protect)
        setattr(
            _StrictLane,
            f"__{name}__",
            lambda self, other, op=op: op(_unwrap(self), _unwrap(other)),
        )


_STRICT_READY = False


def _ensure_strict_ops() -> None:
    global _STRICT_READY
    if not _STRICT_READY:
        _install_strict_ops()
        _STRICT_READY = True


# ---------------------------------------------------------------------- #
# Trace-shaping statics: everything (beyond argument shapes) that changes
# the structure of the traced computation.  Hashable by value, so prepared
# cases with identical statics and bucketed shapes share one jit trace.
# ---------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class _StmtStatic:
    name: str
    write: str
    reads: Tuple[str, ...]
    guard: Optional[str]
    has_oob: bool                  # tables carry an "oob" lane mask to flag
    cov_reads: Tuple[bool, ...]    # per read: consult the coverage buffer
    cov_guard: bool
    cov_write: bool                # scatter updates the coverage buffer
    # narrow statements run every level with the active bit folded into the
    # lane mask (a handful of trash-redirected lanes) — cheaper than a
    # lax.cond, whose pass-through copies the write array at every level;
    # wide statements keep the cond so inactive levels don't pay their lanes
    use_cond: bool = True


@dataclasses.dataclass(frozen=True)
class _CaseStatic:
    stmts: Tuple[_StmtStatic, ...]
    # segmented level loop (hybrid schedules with recurrence SCCs only) as a
    # bounds-free *skeleton*:
    #   ("wave",)            — generic dispatcher segment
    #   ("rec", (k1, ...))   — nested fori_loop band running statements k1…
    # Every per-bounds scalar (segment extents, cursor bases, chunk counts,
    # band row bases) rides in ``PreparedCase.seg_dyn`` as a *traced* jit
    # argument instead, so two bounds whose skeleton and bucketed shapes
    # coincide share one trace — the "bucket" level of the cache hierarchy
    # (structure → bucket → trace → per-bounds tables).
    # None → the single traced-bound level loop (likewise shared across
    # bounds with equal bucketed shapes)
    segments: Optional[Tuple[Tuple, ...]] = None


@dataclasses.dataclass
class PreparedCase:
    """Per-(bounds, store layout) lowering artifacts: level tables + layout."""

    static: _CaseStatic
    n_levels: int
    tables: Tuple[Dict[str, np.ndarray], ...]   # per statement
    arrays: Tuple[str, ...]
    origin: Dict[str, Tuple[int, ...]]
    shapes: Dict[str, Tuple[int, ...]]
    flat_sizes: Dict[str, int]                  # live cells per array
    padded_sizes: Dict[str, int]                # flat buffer length (≥ live+1)
    sparse: Tuple[str, ...]                     # arrays carrying coverage
    schedule: WavefrontSchedule
    # per-segment dynamic scalars (see _CaseStatic.segments):
    #   wave → [lo, hi, cursors0…] ; rec → [n_chunks, row0…]
    seg_dyn: Tuple[np.ndarray, ...] = ()
    bucket: Tuple = ()                          # trace-identity key (host view)
    _device_tables: Optional[Tuple] = None      # jnp copies, converted once
    _device_segdyn: Optional[Tuple] = None


_OOB_MSG = (
    "access outside the initialized store — widen the pad of initial_store()"
)
_HOLE_MSG = (
    "read of an uninitialized cell — the provided store does not cover "
    "this access"
)


class CompiledProgram:
    """One structural cache entry: a lowering plan plus its jit executable.

    Built once per (statement graph, retained dependences, execution model);
    per-bounds level tables and per-shape XLA specializations are nested
    caches inside.  ``parallelize(..., backend="xla")`` attaches the handle
    to the :class:`~repro.core.parallelizer.ParallelizationReport`.
    """

    # prepared-case LRU bound: a long-running server whose bounds vary per
    # request must not accumulate level tables without limit
    MAX_CASES = 32

    def __init__(
        self,
        key: str,
        program: LoopProgram,
        retained: Sequence[Dependence],
        model: str = "doall",
        processors: Optional[Dict[str, object]] = None,
        chunk_limit: Optional[int] = None,
        scc_policy: SccPolicyLike = None,
        deps: Optional[str] = None,
    ) -> None:
        import collections
        import threading

        import jax

        self.key = key
        self.program = program
        self.retained = tuple(retained)
        self.model = model
        self.processors = dict(processors) if processors else None
        self.chunk_limit = chunk_limit
        self.scc_policy = scc_policy
        # non-affine dependence mode: None (conservative proxies),
        # "inspect" (exact per-bounds instance graph), or "speculate"
        # (optimistic schedule; validation + rollback live in the run
        # wrapper — repro.compile.executor.execute_compiled)
        self.deps_mode = deps
        self.cache = None  # back-reference set by the owning CompileCache
        self._cases: "collections.OrderedDict[Tuple, PreparedCase]" = (
            collections.OrderedDict()
        )
        self._lock = threading.Lock()
        self._batched = [
            self._make_batched(s) for s in program.statements
        ]
        # trace accounting (the "bucket" cache level): _buckets collects the
        # distinct trace identities served so far; _trace_count is bumped by
        # the Python body of _exec, which jax runs exactly once per trace —
        # at steady state the two agree, and the service/bench judge
        # re-trace rate on the registry counter behind them
        self._buckets: set = set()
        self._trace_count = 0
        self._jit = jax.jit(self._exec, static_argnums=(0,))

    # ------------------------------------------------------------------ #
    @property
    def prepared_cases(self) -> int:
        return len(self._cases)

    @property
    def trace_count(self) -> int:
        """Times jax traced the executable (Python body executions)."""

        return self._trace_count

    @property
    def bucket_count(self) -> int:
        """Distinct (skeleton, bucketed shapes) trace identities served."""

        with self._lock:
            return len(self._buckets)

    def cache_stats(self) -> Dict[str, int]:
        if self.cache is None:  # pragma: no cover - standalone use
            return {}
        return self.cache.stats.as_dict()

    # ------------------------------------------------------------------ #
    # Backend-specialization hooks.  The sharded artifact
    # (repro.compile.spmd.SpmdCompiledProgram) overrides these; the base
    # definitions pin the single-device behavior exactly as before.
    # ------------------------------------------------------------------ #

    def _level_cost_hook(self):
        """Per-level step-cost model handed to the scheduling policy."""

        from repro.compile import xla_level_cost

        return xla_level_cost

    def _pad_lanes(self, wp: int) -> int:
        """Final lane padding (``wp`` is already a power of two)."""

        return wp

    def _use_cond(self, wp: int) -> bool:
        """Whether a statement of padded width ``wp`` gets a lax.cond (wide)
        or runs condless with the active bit folded into the lane mask."""

        return wp > 32

    def _make_static(self, stmts, segments) -> _CaseStatic:
        """Build the trace-shaping static for a prepared case."""

        return _CaseStatic(stmts=stmts, segments=segments)

    def _case_key_extra(self) -> Tuple:
        """Extra components appended to the per-bounds case key (the sharded
        artifact adds the shard count so re-meshing rebuilds tables without
        touching the structural level)."""

        return ()

    def _lane_values(self, k, ss, store, ridx, width, opaque_zero):
        """Gather + vectorized compute of one table row's lanes (the part of
        a group step the sharded artifact splits across devices)."""

        reads = [store[a][ix] for a, ix in zip(ss.reads, ridx)]
        return self._batched[k](reads, width, opaque_zero)

    # ------------------------------------------------------------------ #
    @staticmethod
    def _make_batched(stmt):
        """Vectorized compute over whole lane vectors.

        Reads are wrapped in :class:`_StrictLane` so every arithmetic op
        rounds individually (bit-identical to the scalar interpreters);
        compute functions that don't speak the proxy protocol (e.g. calling
        ``jnp.*`` directly) fall back to a plain ``jax.vmap`` — traceable
        but subject to XLA's usual elementwise codegen."""

        import jax
        import jax.numpy as jnp

        _ensure_strict_ops()
        n_reads = len(stmt.reads)

        def batched(reads: List, width: int, opaque_zero):
            if n_reads == 0:
                return jnp.broadcast_to(
                    jnp.asarray(stmt.compute(), jnp.float64), (width,)
                )
            try:
                out = jnp.asarray(
                    _unwrap(
                        stmt.compute(
                            *(_StrictLane(r, opaque_zero) for r in reads)
                        )
                    ),
                    jnp.float64,
                )
                if out.shape == (width,):
                    return out
                if out.ndim == 0:
                    return jnp.broadcast_to(out, (width,))
            except Exception:
                pass
            try:
                return jnp.asarray(jax.vmap(stmt.compute)(*reads), jnp.float64)
            except Exception as e:
                raise XlaLoweringError(
                    f"compute function of {stmt.name!r} is not traceable by "
                    f"jax ({e!r}); run this program with backend='wavefront' "
                    "or make the compute fn jnp-compatible"
                ) from e

        return batched

    # ------------------------------------------------------------------ #
    # Table construction (host side, NumPy)
    # ------------------------------------------------------------------ #

    @staticmethod
    def _layout_key(dense: _DenseStore) -> Tuple:
        return tuple(
            sorted(
                (a, dense.origin[a], dense.data[a].shape, a in dense.mask)
                for a in dense.data
            )
        )

    @staticmethod
    def _content_key(program: LoopProgram, dense: _DenseStore) -> Optional[str]:
        """Index-array content digest for indirect programs.

        The level tables of an indirect access are computed from the index
        array's *values* (and, under ``deps="inspect"``, so is the schedule
        itself), so the per-bounds case key must cover them — this is where
        store-dependent state lives, never in the bounds-free structural key.
        Affine programs return None and pay nothing.
        """

        if not program.has_indirect():
            return None
        h = hashlib.sha1()
        for arr in sorted(program.index_arrays()):
            h.update(arr.encode())
            h.update(repr(dense.origin[arr]).encode())
            h.update(dense.data[arr].tobytes())
            covered = dense.mask.get(arr)
            if covered is not None:
                h.update(covered.tobytes())
        return h.hexdigest()

    @staticmethod
    def _index_store(program: LoopProgram, dense: _DenseStore) -> dict:
        """Dict-form view of just the index arrays (inspector input)."""

        out: dict = {}
        for arr in program.index_arrays():
            d = dense.data[arr]
            lo = dense.origin[arr]
            covered = dense.mask.get(arr)
            cells = {}
            for idx in np.ndindex(d.shape):
                if covered is not None and not covered[idx]:
                    continue
                cells[tuple(int(x + l) for x, l in zip(idx, lo))] = float(
                    d[idx]
                )
            out[arr] = cells
        return out

    def prepare(
        self, program: LoopProgram, dense: _DenseStore
    ) -> Tuple[PreparedCase, bool]:
        """Level tables for these bounds + this store layout (memoized in a
        bounded LRU; thread-safe for concurrent serving)."""

        key = (
            program.bounds,
            self._layout_key(dense),
            self._content_key(program, dense),
            *self._case_key_extra(),
        )
        with self._lock:
            case = self._cases.get(key)
            if case is not None:
                self._cases.move_to_end(key)
                return case, True
        with _trace.span("compile.tables", bounds=str(program.bounds)):
            built = self._build_case(program, dense)
        with self._lock:
            case = self._cases.get(key)  # lost a build race: reuse theirs
            if case is None:
                self._cases[key] = case = built
                while len(self._cases) > self.MAX_CASES:
                    self._cases.popitem(last=False)
        return case, False

    def _build_case(
        self, program: LoopProgram, dense: _DenseStore
    ) -> PreparedCase:
        missing = [a for a in program.arrays() if a not in dense.data]
        if missing:
            raise KeyError(
                f"store is missing arrays {missing} referenced by the program"
            )
        # schedule under the compiled backend's own step-cost model: the
        # default scheduling policy scores strategies through the artifact's
        # level-cost hook (xla_level_cost here, the collective-aware
        # spmd_level_cost in the sharded subclass), so the same "auto" knob
        # can resolve to chunk here while the NumPy interpreter resolves it
        # to skew (forced strategies and explicit policy instances are
        # untouched by the hook)
        level_cost = self._level_cost_hook()

        retained = list(self.retained)
        instance_edges = None
        if self.deps_mode is not None and program.has_indirect():
            from repro.core.inspector import (
                affine_retained,
                inspect_dependences,
            )

            # drop the conservative non-affine proxies; under "inspect" the
            # exact per-bounds instance graph replaces them, under
            # "speculate" nothing does (optimistic doall — the run wrapper
            # validates post-hoc and rolls back to the deps=None artifact)
            retained = list(affine_retained(retained))
            if self.deps_mode == "inspect":
                instance_edges = inspect_dependences(
                    program, self._index_store(program, dense)
                ).edges
        sched = schedule_levels(
            program,
            retained,
            model=self.model,
            processors=self.processors,
            chunk_limit=self.chunk_limit,
            scc_policy=self.scc_policy,
            level_cost=level_cost,
            instance_edges=instance_edges,
        )
        n_levels = sched.depth
        arrays = tuple(sorted(dense.data))
        origin = {a: dense.origin[a] for a in arrays}
        shapes = {a: dense.data[a].shape for a in arrays}
        flat_sizes = {a: int(np.prod(shapes[a])) for a in arrays}
        padded_sizes = {a: _next_pow2(flat_sizes[a] + 1) for a in arrays}
        sparse = tuple(a for a in arrays if a in dense.mask)

        per_stmt: Dict[str, List[Tuple[int, np.ndarray]]] = {}
        for lvl, groups in enumerate(sched.levels):
            for g in groups:
                per_stmt.setdefault(g.statement, []).append(
                    (lvl, np.asarray(g.iterations, dtype=np.int64))
                )

        stmt_statics: List[_StmtStatic] = []
        tables: List[Dict[str, np.ndarray]] = []
        # Actual (unpadded) lane count of every table row, in row order, and
        # each statement's padded width — the width ladder's raw material.
        row_widths: List[List[int]] = []
        wps: List[int] = []
        for s in program.statements:
            entries = per_stmt.get(s.name, [])
            G = len(entries)
            W = max((pts.shape[0] for _, pts in entries), default=1)
            Gp, Wp = _next_pow2(G + 1), self._pad_lanes(_next_pow2(W))
            row_widths.append([int(pts.shape[0]) for _, pts in entries])
            wps.append(Wp)

            glevel = np.full(Gp, n_levels, dtype=np.int32)  # sentinel rows
            lanemask = np.zeros((Gp, Wp), dtype=bool)
            accesses = (
                [("write", s.write)]
                + [(f"read{j}", r) for j, r in enumerate(s.reads)]
                + ([("guard", s.guard)] if s.guard is not None else [])
            )
            idx = {
                role: np.zeros((Gp, Wp), dtype=np.int32)
                for role, _ in accesses
            }
            oob = np.zeros((Gp, Wp), dtype=bool)
            guard_oob = np.zeros((Gp, Wp), dtype=bool)

            for gi, (lvl, pts) in enumerate(entries):
                glevel[gi] = lvl
                w = pts.shape[0]
                lanemask[gi, :w] = True
                if Wp > w:  # pad lanes repeat the first point (masked out)
                    pts = np.concatenate(
                        [pts, np.repeat(pts[:1], Wp - w, axis=0)]
                    )
                for role, ref in accesses:
                    a = ref.array
                    idx_inb = None
                    if is_indirect(ref):
                        # resolve the subscript against the index array's
                        # *contents* — the reason this table cache is keyed
                        # by _content_key on top of (bounds, layout)
                        iarr = ref.index.array
                        icoords = (
                            pts
                            + np.asarray(ref.index.offset_tuple(), np.int64)
                            - np.asarray(origin[iarr], np.int64)
                        )
                        ishp = np.asarray(shapes[iarr], np.int64)
                        idx_inb = np.all(
                            (icoords >= 0) & (icoords < ishp), axis=1
                        )
                        iflat = np.ravel_multi_index(
                            tuple(
                                np.clip(icoords[:, d], 0, shapes[iarr][d] - 1)
                                for d in range(icoords.shape[1])
                            ),
                            shapes[iarr],
                        )
                        ivals = dense.data[iarr].ravel()[iflat]
                        icov = dense.mask.get(iarr)
                        if icov is not None:
                            idx_inb &= icov.ravel()[iflat]
                        # astype truncates toward zero like the scalar
                        # executors' int()
                        coords = (ivals.astype(np.int64) + ref.offset)[
                            :, None
                        ] - np.asarray(origin[a], np.int64)
                    else:
                        coords = (
                            pts
                            + np.asarray(ref.offset_tuple(), np.int64)
                            - np.asarray(origin[a], np.int64)
                        )
                    shp = np.asarray(shapes[a], np.int64)
                    inb = np.all((coords >= 0) & (coords < shp), axis=1)
                    if idx_inb is not None:
                        inb &= idx_inb
                    flat = np.ravel_multi_index(
                        tuple(
                            np.clip(coords[:, d], 0, shapes[a][d] - 1)
                            for d in range(coords.shape[1])
                        ),
                        shapes[a],
                    )
                    # out-of-box lanes are redirected to the trash cell
                    flat = np.where(inb, flat, padded_sizes[a] - 1)
                    idx[role][gi] = flat.astype(np.int32)
                    bad = ~inb[:w]
                    if role == "guard":
                        guard_oob[gi, :w] |= bad
                    else:
                        oob[gi, :w] |= bad

            oob &= lanemask
            guard_oob &= lanemask
            # The guard access itself is evaluated unconditionally by the
            # sequential oracle, so a guard read outside the store is a
            # static error even for guarded statements.
            if guard_oob.any():
                raise KeyError(f"{s.name}: guard {_OOB_MSG}")
            if s.guard is None and oob.any():
                raise KeyError(f"{s.name}: {_OOB_MSG}")
            has_oob = bool(s.guard is not None and oob.any())

            cov_reads = tuple(r.array in dense.mask for r in s.reads)
            cov_guard = bool(
                s.guard is not None and s.guard.array in dense.mask
            )
            cov_write = s.write.array in dense.mask

            stmt_statics.append(
                _StmtStatic(
                    name=s.name,
                    write=s.write.array,
                    reads=tuple(r.array for r in s.reads),
                    guard=s.guard.array if s.guard is not None else None,
                    has_oob=has_oob,
                    cov_reads=cov_reads,
                    cov_guard=cov_guard,
                    cov_write=cov_write,
                    use_cond=self._use_cond(Wp),
                )
            )
            table = {
                "glevel": glevel,
                "lanemask": lanemask,
                "widx": idx["write"],
            }
            table["ridx"] = tuple(
                idx[f"read{j}"] for j in range(len(s.reads))
            )
            if s.guard is not None:
                table["gidx"] = idx["guard"]
            if has_oob:
                table["oob"] = oob
            tables.append(table)

        # Segment hybrid schedules AND inspect schedules: the band detector
        # only looks at per-level (statement, row) lockstep runs, which is
        # strategy-agnostic — an inspector-scheduled serialized chain lowers
        # to the same nested-fori recurrence band a chunked DOACROSS does,
        # instead of paying the generic per-level cursor dispatcher.
        segments, seg_dyn = None, ()
        if (
            sched.scc is not None and sched.scc.recurrences
        ) or instance_edges is not None:
            segments, seg_dyn = self._segment_levels(
                program, sched, n_levels, len(program.statements)
            )
            seg_dyn = self._split_band_widths(
                segments, seg_dyn, row_widths, wps
            )

        static = self._make_static(tuple(stmt_statics), segments)
        # The trace identity, computed host-side: everything jax's jit cache
        # keys a trace on — the statics plus the bucketed argument shapes
        # (level tables, padded store/coverage buffers, segment scalars).
        # Per-bounds *values* (n_levels, table contents, seg_dyn contents)
        # are traced arguments and deliberately absent.
        bucket = (
            static,
            tuple(
                tuple(
                    sorted(
                        (
                            role,
                            tuple(a.shape for a in arr)
                            if isinstance(arr, tuple)
                            else arr.shape,
                        )
                        for role, arr in t.items()
                    )
                )
                for t in tables
            ),
            tuple(sorted(padded_sizes.items())),
            sparse,
            tuple(d.shape for d in seg_dyn),
        )

        return PreparedCase(
            static=static,
            n_levels=n_levels,
            tables=tuple(tables),
            arrays=arrays,
            origin=origin,
            shapes=shapes,
            flat_sizes=flat_sizes,
            padded_sizes=padded_sizes,
            sparse=sparse,
            schedule=sched,
            seg_dyn=seg_dyn,
            bucket=bucket,
        )

    # Minimum run of uniform levels worth collapsing into a nested loop —
    # below this the generic dispatcher's per-level cost doesn't matter.
    REC_BAND_MIN = 4

    def _band_rungs(self, wpb: int) -> int:
        """Width-ladder depth for a recurrence band of padded width
        ``wpb``: the number of halvings (≤ ``WIDTH_LADDER_RUNGS``) whose
        narrowest rung still holds ``WIDTH_LADDER_MIN`` lanes.  The sharded
        artifact overrides this to 0 (its per-shard lane slicing needs the
        full padded width).  Reads the module knobs late so a bench can
        pin the ladder off for an unsplit control build."""

        rungs = 0
        while (
            rungs < WIDTH_LADDER_RUNGS
            and (wpb >> (rungs + 1)) >= WIDTH_LADDER_MIN
        ):
            rungs += 1
        return rungs

    def _split_band_widths(
        self,
        segments: Tuple[Tuple, ...],
        seg_dyn: Tuple[np.ndarray, ...],
        row_widths: List[List[int]],
        wps: List[int],
    ) -> Tuple[np.ndarray, ...]:
        """Append width-ladder cut points to each recurrence band's dynamic
        vector (ROADMAP 3b).

        A skewed diamond's band ramps up to its widest diagonal and back
        down, but every level pays for the *widest* statement row because
        the whole band shares one padded lane count.  For a ladder of
        ascending rung widths ``w_1 < … < w_L < wpb`` this computes, per
        rung, the maximal prefix ``P_i`` (and suffix start ``Q_i``) of band
        rows whose actual lane counts all fit ``w_i`` — monotone cuts
        ``0 ≤ P_1 ≤ … ≤ P_L ≤ Q_L ≤ … ≤ Q_1 ≤ n`` appended as ``[P_1…P_L,
        Q_L…Q_1]`` — so the executor can run the ramps at sliced lane
        widths and only the plateau at full width.  Lanes sliced away are
        pure padding (mask-false, repeat-first-point, trash-scattered), so
        bit-equality is structural, not numerical luck.

        The cut *values* ride in the traced ``seg_dyn`` vector; only the
        ladder depth L changes the vector's shape, and L is a function of
        the padded band width — already a bucket component — so the
        four-level cache and the zero-re-trace property are preserved.
        Uniform bands (every row as wide as the plateau) append nothing
        and keep today's trace byte-for-byte.
        """

        out = []
        for seg, dyn in zip(segments, seg_dyn):
            if seg[0] != "rec":
                out.append(dyn)
                continue
            stmt_ks = seg[1]
            n = int(dyn[0])
            row0 = [int(r) for r in dyn[1:]]
            wpb = max(wps[k] for k in stmt_ks)
            rungs = self._band_rungs(wpb)

            def fits(t: int, w: int) -> bool:
                return all(
                    row_widths[k][row0[j] + t] <= min(w, wps[k])
                    for j, k in enumerate(stmt_ks)
                )

            ws = [wpb >> (rungs - i) for i in range(rungs)]
            cuts_p = []
            for w in ws:
                p = cuts_p[-1] if cuts_p else 0  # prefixes are monotone
                while p < n and fits(p, w):
                    p += 1
                cuts_p.append(p)
            cuts_q = []
            for w in ws:
                q = cuts_q[-1] if cuts_q else n  # suffixes are monotone
                while q > cuts_p[-1] and fits(q - 1, w):
                    q -= 1
                cuts_q.append(q)
            if rungs == 0 or (cuts_p[-1] == 0 and cuts_q[-1] == n):
                # degenerate ladder (a uniform band): keep the un-split
                # vector so the trace — and the bucket — match today's
                out.append(dyn)
                continue
            extra = cuts_p + list(reversed(cuts_q))
            out.append(
                np.concatenate(
                    [dyn, np.asarray(extra, dtype=np.int32)]
                )
            )
        return tuple(out)

    @staticmethod
    def _segment_levels(
        program: LoopProgram, sched, n_levels: int, n_stmts: int
    ) -> Tuple[Tuple[Tuple, ...], Tuple[np.ndarray, ...]]:
        """Partition the level sequence into wave segments + recurrence bands.

        A band is a maximal run of ≥ :attr:`REC_BAND_MIN` levels whose
        active (statement, table-row) pairs advance in lockstep — exactly
        what a chunked recurrence (plus any acyclic groups pipelined against
        it) produces.  Sound regardless of which statements land in a band:
        same-level groups of different scheduling units are independent by
        construction, and the band executes them in lexical order like the
        generic dispatcher.

        Returns ``(skeleton, seg_dyn)``: the bounds-free segment skeleton
        that goes into :class:`_CaseStatic` plus one ``int32`` scalar vector
        per segment (``[lo, hi, cursors0…]`` for waves, ``[n_chunks,
        row0…]`` for bands) that rides as a traced jit argument — the
        static/dynamic split that lets every bounds in a bucket share one
        trace.
        """

        import bisect

        stmt_index = {s.name: k for k, s in enumerate(program.statements)}
        level_active: List[List[Tuple[int, int]]] = [
            [] for _ in range(n_levels)
        ]
        rows_seen = [0] * n_stmts
        stmt_levels: List[List[int]] = [[] for _ in range(n_stmts)]
        for lvl, groups in enumerate(sched.levels):
            for g in groups:
                k = stmt_index[g.statement]
                level_active[lvl].append((k, rows_seen[k]))
                stmt_levels[k].append(lvl)
                rows_seen[k] += 1
        for active in level_active:
            active.sort()  # lexical statement order (groups already are)

        def cursors_at(level: int) -> Tuple[int, ...]:
            return tuple(
                bisect.bisect_left(stmt_levels[k], level)
                for k in range(n_stmts)
            )

        skeleton: List[Tuple] = []
        seg_dyn: List[np.ndarray] = []

        def wave(lo: int, hi: int) -> None:
            skeleton.append(("wave",))
            seg_dyn.append(
                np.asarray([lo, hi, *cursors_at(lo)], dtype=np.int32)
            )

        wave_start = 0
        L = 0
        while L < n_levels:
            base = level_active[L]
            run = 1
            while L + run < n_levels and len(level_active[L + run]) == len(
                base
            ) and all(
                nk == bk and nr == br + run
                for (nk, nr), (bk, br) in zip(level_active[L + run], base)
            ):
                run += 1
            if base and run >= CompiledProgram.REC_BAND_MIN:
                if wave_start < L:
                    wave(wave_start, L)
                skeleton.append(("rec", tuple(k for k, _ in base)))
                seg_dyn.append(
                    np.asarray(
                        [run, *(r0 for _, r0 in base)], dtype=np.int32
                    )
                )
                wave_start = L + run
            L += run
        if wave_start < n_levels:
            wave(wave_start, n_levels)
        return tuple(skeleton), tuple(seg_dyn)

    # ------------------------------------------------------------------ #
    # The traced executable
    # ------------------------------------------------------------------ #

    def _exec(
        self, static: _CaseStatic, n_levels, seg_dyn, tables, store,
        coverage, bad, opaque_zero,
    ):
        import jax.numpy as jnp
        from jax import lax

        # this Python body runs exactly once per jax trace — the counter IS
        # the re-trace metric the serving layer and the sustained-traffic
        # bench gate on (a warm bucket never re-enters here)
        self._trace_count += 1
        _metrics.counter("xla.traces").inc()

        K = len(static.stmts)

        def group_step(k, ss, c, store, coverage, bad, gate=None,
                       lane_cap=None):
            """Vectorized gather/compute/scatter of statement ``k``'s table
            row ``c``; returns (new write array, new coverage, bad flags).
            Read-only arrays are captured by closure — routing the whole
            store through here would force XLA to copy every array.

            ``lane_cap`` (a static int) restricts the step to the row's
            leading ``lane_cap`` lanes — the width-ladder rungs of a
            recurrence band's ramps use it to skip gathers/scatters on
            lanes that are provably padding there (mask-false, so skipping
            them is structural, not a numerical approximation)."""

            t = tables[k]

            def row(m):
                r = lax.dynamic_index_in_dim(m, c, axis=0, keepdims=False)
                return r if lane_cap is None else r[:lane_cap]

            lanes = row(t["lanemask"])
            if gate is not None:  # condless path: fold the active
                lanes = lanes & gate  # bit into the lane mask
            ridx = [row(ix) for ix in t["ridx"]]
            mask = lanes
            if ss.guard is not None:
                gix = row(t["gidx"])
                if ss.cov_guard:
                    bad = bad.at[1].set(
                        bad[1] | jnp.any(lanes & ~coverage[ss.guard][gix])
                    )
                mask = mask & (store[ss.guard][gix] > 0.0)
            for j, (a, ix) in enumerate(zip(ss.reads, ridx)):
                if ss.cov_reads[j]:
                    bad = bad.at[1].set(
                        bad[1] | jnp.any(mask & ~coverage[a][ix])
                    )
            if ss.has_oob:
                oob_row = row(t["oob"])
                bad = bad.at[0].set(bad[0] | jnp.any(mask & oob_row))
                mask = mask & ~oob_row
            vals = self._lane_values(
                k, ss, store, ridx, lanes.shape[0], opaque_zero
            )
            trash = store[ss.write].shape[0] - 1
            tgt = jnp.where(mask, row(t["widx"]), trash)
            new_write = store[ss.write].at[tgt].set(vals)
            new_cov = (
                coverage[ss.write].at[tgt].set(True) if ss.cov_write else ()
            )
            return (new_write, new_cov, bad)

        def level_body(level, carry):
            """Generic dispatcher: per-statement cursors + lax.cond."""

            store, coverage, cursors, bad = carry
            for k, ss in enumerate(static.stmts):
                c = cursors[k]
                active = (
                    lax.dynamic_index_in_dim(
                        tables[k]["glevel"], c, axis=0, keepdims=False
                    )
                    == level
                )

                # the cond returns only what the group writes (one array,
                # optionally its coverage, the flags)
                def run_group(k=k, ss=ss, c=c, bad=bad, store=store,
                              coverage=coverage):
                    return group_step(k, ss, c, store, coverage, bad)

                def skip_group(ss=ss, bad=bad, store=store,
                               coverage=coverage):
                    return (
                        store[ss.write],
                        coverage[ss.write] if ss.cov_write else (),
                        bad,
                    )

                if ss.use_cond:
                    new_write, new_cov, bad = lax.cond(
                        active, run_group, skip_group
                    )
                else:
                    new_write, new_cov, bad = group_step(
                        k, ss, c, store, coverage, bad, gate=active
                    )
                store = dict(store)
                store[ss.write] = new_write
                if ss.cov_write:
                    coverage = dict(coverage)
                    coverage[ss.write] = new_cov
                cursors = cursors.at[k].add(active.astype(jnp.int32))
            return (store, coverage, cursors, bad)

        if static.segments is None:
            store, coverage, _, bad = lax.fori_loop(
                0,
                n_levels,
                level_body,
                (store, coverage, jnp.zeros((K,), jnp.int32), bad),
            )
            return store, coverage, bad

        # Segmented form (hybrid schedules with recurrence SCCs): wave
        # segments keep the generic dispatcher; each recurrence band is its
        # own nested fori_loop with the store as the recurrence carry — no
        # cursors, no conds, only the band's statements in the body.  All
        # per-bounds scalars (extents, cursor bases, chunk counts, row
        # bases) arrive in the traced ``seg_dyn`` vectors, so the trace is
        # bounds-free: any iteration count in the bucket replays it.
        for seg, dyn in zip(static.segments, seg_dyn):
            if seg[0] == "wave":
                store, coverage, _, bad = lax.fori_loop(
                    dyn[0],
                    dyn[1],
                    level_body,
                    (store, coverage, dyn[2:].astype(jnp.int32), bad),
                )
            else:
                _tag, stmt_ks = seg
                J = len(stmt_ks)
                # Ladder depth, recovered from the dynamic vector's *shape*
                # ([run, J row bases, 2·L cut points]).  The shape is a
                # bucket component, so L is trace-stable — the module knob
                # WIDTH_LADDER_RUNGS never leaks into a warm trace.
                L = (dyn.shape[0] - 1 - J) // 2

                def rec_body(t, carry, stmt_ks=stmt_ks, dyn=dyn, cap=None):
                    store, coverage, bad = carry
                    for j, k in enumerate(stmt_ks):  # lexical stmt order
                        ss = static.stmts[k]
                        ck = (
                            None
                            if cap is None
                            or cap >= tables[k]["lanemask"].shape[1]
                            else cap
                        )
                        new_write, new_cov, bad = group_step(
                            k, ss, dyn[1 + j] + t, store, coverage, bad,
                            lane_cap=ck,
                        )
                        store = dict(store)
                        store[ss.write] = new_write
                        if ss.cov_write:
                            coverage = dict(coverage)
                            coverage[ss.write] = new_cov
                    return (store, coverage, bad)

                if L == 0:
                    store, coverage, bad = lax.fori_loop(
                        0, dyn[0], rec_body, (store, coverage, bad)
                    )
                else:
                    # Width ladder: 2·L+1 chained fori_loops over the band
                    # — ramp-up rungs at ascending lane caps, the plateau
                    # at full width, ramp-down rungs mirrored.  Ranges the
                    # ladder found empty are zero-trip at run time.
                    wpb = max(
                        tables[k]["lanemask"].shape[1] for k in stmt_ks
                    )
                    ws = [wpb >> (L - i) for i in range(L)]
                    caps = ws + [wpb] + list(reversed(ws))
                    edges = (
                        [0]
                        + [dyn[1 + J + i] for i in range(2 * L)]
                        + [dyn[0]]
                    )
                    for lo, hi, cap in zip(edges, edges[1:], caps):
                        store, coverage, bad = lax.fori_loop(
                            lo,
                            hi,
                            lambda t, carry, cap=cap: rec_body(
                                t, carry, cap=cap
                            ),
                            (store, coverage, bad),
                        )
        return store, coverage, bad

    # ------------------------------------------------------------------ #
    # Host-side execution wrapper
    # ------------------------------------------------------------------ #

    @staticmethod
    def _to_device(case: PreparedCase) -> Tuple:
        import jax.numpy as jnp

        return tuple(
            {
                k: (
                    tuple(jnp.asarray(x) for x in v)
                    if isinstance(v, tuple)
                    else jnp.asarray(v)
                )
                for k, v in t.items()
            }
            for t in case.tables
        )

    def execute(self, case: PreparedCase, dense: _DenseStore) -> WavefrontStats:
        """Run the artifact on ``dense`` (mutated in place with the result)."""

        import jax.numpy as jnp
        from jax.experimental import enable_x64

        # bucket accounting before dispatch: a fresh trace identity is the
        # only thing that may legitimately re-enter the tracer
        with self._lock:
            new_bucket = case.bucket not in self._buckets
            if new_bucket:
                self._buckets.add(case.bucket)
        _metrics.counter(
            "xla.bucket_misses" if new_bucket else "xla.bucket_hits"
        ).inc()

        with enable_x64():
            with _trace.span("xla.to_device"):
                if case._device_tables is None:
                    # conversion is idempotent, so a concurrent duplicate
                    # would cost only a wasted copy; the lock keeps
                    # assignment clean
                    with self._lock:
                        if case._device_tables is None:
                            case._device_segdyn = tuple(
                                jnp.asarray(d) for d in case.seg_dyn
                            )
                            case._device_tables = self._to_device(case)
                store = {}
                for a in case.arrays:
                    flat = np.zeros(case.padded_sizes[a], dtype=np.float64)
                    flat[: case.flat_sizes[a]] = dense.data[a].ravel()
                    store[a] = jnp.asarray(flat)
                coverage = {}
                for a in case.sparse:
                    cov = np.zeros(case.padded_sizes[a], dtype=bool)
                    cov[: case.flat_sizes[a]] = dense.mask[a].ravel()
                    coverage[a] = jnp.asarray(cov)
            # host-side band timing: one level loop per jit call, so the
            # finest host-visible unit is the whole fused level sweep
            with _trace.span("xla.execute", levels=case.n_levels):
                out_store, out_cov, bad = self._jit(
                    case.static,
                    case.n_levels,
                    case._device_segdyn,
                    case._device_tables,
                    store,
                    coverage,
                    jnp.zeros((2,), bool),
                    jnp.int64(0),
                )
                # block inside the span: the jit call returns futures, and
                # an unblocked exit would time dispatch, not execution
                bad = np.asarray(bad)
            # device→host conversion stays inside the x64 scope: jax helper
            # jits (e.g. unstack) would otherwise see f32 defaults
            with _trace.span("xla.to_host"):
                out_np = {
                    a: np.asarray(out_store[a])[: case.flat_sizes[a]].reshape(
                        case.shapes[a]
                    )
                    for a in case.arrays
                }
                cov_np = {
                    a: np.asarray(out_cov[a])[: case.flat_sizes[a]].reshape(
                        case.shapes[a]
                    )
                    for a in case.sparse
                }
        if bad[0]:
            raise KeyError(_OOB_MSG)
        if bad[1]:
            raise KeyError(_HOLE_MSG)
        dense.data.update(out_np)
        dense.mask.update(cov_np)
        sched = case.schedule
        return WavefrontStats(
            levels=sched.depth,
            batched_ops=sched.batched_ops,
            instances=sched.instances,
            max_width=sched.max_width,
        )
