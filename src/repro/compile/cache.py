"""Structural compile cache: one artifact per dependence structure.

Keyed by :func:`repro.compile.structure.structural_key` — a canonical hash of
(statement graph, retained dependences, execution model), *not* loop bounds —
so the serving path re-planning the same decode loop every batch wave and the
Pallas K-loop plan re-lowering the same ISSUE/LOAD/COMPUTE loop for different
``steps`` all resolve to the same :class:`~repro.compile.lowering.CompiledProgram`.
Below the structural level, each artifact memoizes its per-(bounds, store
layout) level tables, and jax's jit cache memoizes per-shape XLA
specializations; a warm request touches none of the analysis, scheduling or
tracing machinery.

Hit/miss counters (structural and table level) are surfaced through
``ParallelizationReport.summary()`` and the ``compile_cache_*`` benchmarks.
"""

from __future__ import annotations

import collections
import threading
from typing import Dict, Optional, Sequence, Tuple

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.core.dependence import Dependence
from repro.core.ir import LoopProgram
from repro.core.policy import SccPolicyLike
from repro.compile.structure import structural_key

_FIELDS = ("hits", "misses", "table_hits", "table_misses")


class CacheStats:
    """Hit/miss counters for one :class:`CompileCache`.

    With ``metrics_prefix`` set the four counters live in the unified
    registry (:mod:`repro.obs.metrics`) — that is how the process-global
    cache publishes ``compile_cache.hits`` etc. while keeping this object's
    historical surface (``.hits``, ``.as_dict()``, ``.note()``).  Without a
    prefix (test-local ``CompileCache()`` instances) the counters are
    private unregistered instruments, so per-instance assertions never see
    another cache's traffic.
    """

    __slots__ = ("_counters",)

    def __init__(self, metrics_prefix: Optional[str] = None):
        if metrics_prefix is None:
            self._counters = {f: _metrics.Counter(f) for f in _FIELDS}
        else:
            self._counters = {
                f: _metrics.counter(f"{metrics_prefix}.{f}") for f in _FIELDS
            }

    @property
    def hits(self) -> int:
        return self._counters["hits"].value

    @property
    def misses(self) -> int:
        return self._counters["misses"].value

    @property
    def table_hits(self) -> int:
        return self._counters["table_hits"].value

    @property
    def table_misses(self) -> int:
        return self._counters["table_misses"].value

    def as_dict(self) -> Dict[str, int]:
        return {f: self._counters[f].value for f in _FIELDS}

    def note(self, hit: bool) -> None:
        self._counters["hits" if hit else "misses"].inc()

    def note_tables(self, hit: bool) -> None:
        self._counters["table_hits" if hit else "table_misses"].inc()

    def reset(self) -> None:
        for c in self._counters.values():
            c.reset()


class CompileCache:
    """Thread-safe structural LRU cache of compiled sync-program executables.

    Bounded like the per-artifact table cache (CompiledProgram.MAX_CASES):
    a long-running server whose request *structures* vary (e.g. per-tenant
    compute functions) must not pin jitted executables for structures that
    never recur.
    """

    MAX_ENTRIES = 128

    def __init__(
        self,
        metrics_prefix: Optional[str] = None,
        factory: Optional[type] = None,
    ) -> None:
        self._entries: "collections.OrderedDict[str, CompiledProgram]" = (
            collections.OrderedDict()
        )
        self._lock = threading.Lock()
        self.stats = CacheStats(metrics_prefix)
        # the artifact class this cache builds; per-backend caches (xla vs
        # xla_spmd) install their own CompiledProgram subclass so artifacts
        # never alias across backends even though structural_key carries no
        # backend tag
        self._factory = factory

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> Tuple[str, ...]:
        return tuple(self._entries)

    def clear(self) -> None:
        # counters reset in place: the registry (and any holder of this
        # stats object) keeps observing the same instruments
        with self._lock:
            self._entries.clear()
            self.stats.reset()

    def note_tables(self, hit: bool) -> None:
        """Thread-safe table-level counter update (the second cache level
        lives inside each CompiledProgram; its hits are recorded here)."""

        with self._lock:
            self.stats.note_tables(hit)

    def get_or_compile(
        self,
        program: LoopProgram,
        retained: Sequence[Dependence],
        *,
        model: str = "doall",
        processors: Optional[Dict[str, object]] = None,
        chunk_limit: Optional[int] = None,
        scc_policy: SccPolicyLike = None,
        deps: Optional[str] = None,
    ) -> Tuple["CompiledProgram", bool]:
        """Resolve (or build) the artifact for this structure.

        Returns ``(compiled, hit)``.  The build happens *outside* the lock
        (the first one pays the jax import, seconds — holding the lock
        would stall concurrent hits on other keys); a lost build race
        re-checks on insert and reuses the winner.  ``deps`` is the
        non-affine dependence mode (``"inspect"``/``"speculate"``/None) —
        a structural knob like ``chunk_limit``; the store-dependent
        inspector graph itself lives with the artifact's per-bounds tables.
        """

        from repro.compile.lowering import CompiledProgram

        factory = self._factory if self._factory is not None else CompiledProgram
        with _trace.span("compile.structural_lookup"):
            key = structural_key(
                program, retained, model, processors, chunk_limit, scc_policy,
                deps,
            )
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self._entries.move_to_end(key)
        if entry is not None:
            self.stats.note(True)
            return entry, True
        with _trace.span("compile.build", key=key[:16]):
            built = factory(
                key,
                program,
                retained,
                model=model,
                processors=processors,
                chunk_limit=chunk_limit,
                scc_policy=scc_policy,
                deps=deps,
            )
        built.cache = self
        with self._lock:
            entry = self._entries.get(key)  # lost a build race: use theirs
            if entry is None:
                self._entries[key] = entry = built
                while len(self._entries) > self.MAX_ENTRIES:
                    self._entries.popitem(last=False)
            self.stats.note(False)
            return entry, False


GLOBAL_CACHE = CompileCache(metrics_prefix="compile_cache")


def get_or_compile(
    program: LoopProgram,
    retained: Sequence[Dependence],
    *,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: SccPolicyLike = None,
    deps: Optional[str] = None,
) -> Tuple["CompiledProgram", bool]:
    """Module-level convenience over the process-global cache."""

    return GLOBAL_CACHE.get_or_compile(
        program,
        retained,
        model=model,
        processors=processors,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
        deps=deps,
    )


def compile_cache_stats() -> Dict[str, int]:
    return GLOBAL_CACHE.stats.as_dict()


def clear_compile_cache() -> None:
    GLOBAL_CACHE.clear()
