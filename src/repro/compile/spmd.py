"""``repro.compile.spmd`` — the multi-device SPMD wavefront backend.

The fifth executor (``parallelize(..., backend="xla_spmd")``).  A wavefront
level is embarrassingly parallel across lanes — exactly the parallelism the
paper's optimized send/wait sets expose — so this backend shards each
statement's padded (group × lane) index tables across a jax mesh with
``shard_map``:

  * every jitted input (level tables, the flat padded store, coverage,
    flags) enters the mapped region **replicated** (``PartitionSpec()``);
    inside, each device slices its contiguous block of a row's lanes by
    ``lax.axis_index``, gathers/computes only those lanes, and an
    ``lax.all_gather(..., tiled=True)`` reassembles the full lane vector in
    original order before the (replicated) masked scatter;
  * recurrence bands keep the store as the loop carry — replicated, with one
    all-gather per chunk step, lanes within the chunk sharded — so hybrid
    schedules shard without any cross-device scatter;
  * the per-lane arithmetic is byte-for-byte the base lowering's laundered
    strict ops (:mod:`repro.compile.lowering`), and everything outside the
    sharded gather/compute runs full-width on replicated data, identically
    on every device — sharded executions therefore stay bit-equal to the
    sequential oracle, the contract ``tests/oracle.py`` checks differentially
    on the whole corpus.

The interesting half is the cost model: :func:`spmd_level_cost` divides the
padded lane work by the device count but charges a flat dispatch cost plus a
per-lane collective cost for the gather — so ``CostModelPolicy`` picks a
wide skewed wavefront when the lane savings beat the collective tax and
narrow single-device chunking when they don't, per SCC, with both scored
offers recorded in ``summary()["scc"]`` (diffable via SYNC_REPORTS).

Cache discipline: the backend owns :data:`SPMD_CACHE`, a separate
:class:`~repro.compile.cache.CompileCache` whose factory builds
:class:`SpmdCompiledProgram` — structural keys carry no backend tag, so the
isolation (xla and xla_spmd artifacts must never alias) lives in the cache
instance.  The shard count is part of the trace **bucket** (it rides in
:class:`_SpmdCaseStatic`, the jit static) and of the per-bounds case key,
never the structural key: re-planning the same structure on a different
mesh is a structural hit that only rebuilds tables and re-traces.

Degenerate single-device meshes take the base class's exact code path (no
``shard_map``, no collectives): the trace is literally the single-device
trace.

Import is lazy like ``repro.compile`` itself: registration costs no jax;
the mesh (built from the seed's :func:`repro.launch.mesh.make_debug_mesh`,
with :func:`repro.launch.sharding._pick` guarding lane divisibility) is
constructed on first sharded execution and cached per device count —
``obs.reset_all()`` clears those handles via :func:`reset_spmd_caches` so
tests that vary ``--xla_force_host_platform_device_count`` stay
order-independent.
"""

from __future__ import annotations

import contextvars
import dataclasses
from typing import Optional, Tuple

from repro.obs import metrics as _metrics
from repro.compile import _next_pow2
from repro.compile.cache import CompileCache
from repro.compile.lowering import CompiledProgram, _CaseStatic

__all__ = [
    "SPMD_CACHE",
    "SpmdCompiledProgram",
    "device_count",
    "force_device_count",
    "reset_spmd_caches",
    "shard_count",
    "spmd_level_cost",
]


# ---------------------------------------------------------------------- #
# Device plumbing.  Two views on purpose:
#   * device_count()  — what the COST MODEL assumes (forcible, so policy
#     tests can score an 8-device mesh from a single-device pytest run);
#   * shard_count()   — what EXECUTION actually shards over, never more
#     than the process's real devices (a forced count degrades safely to
#     an unsharded run, still bit-equal).
# Both are power-of-two floors: lane tables pad to powers of two, so a
# pow2 shard count always divides the padded width.
# ---------------------------------------------------------------------- #

_FORCED: Optional[int] = None
_ACTUAL: Optional[int] = None
_MESHES: dict = {}


def _pow2_floor(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n).bit_length() - 1)


def force_device_count(n: Optional[int]) -> None:
    """Testing seam: pin the cost model's device count (None restores the
    process's real device count)."""

    global _FORCED
    _FORCED = None if n is None else int(n)


def _actual_devices() -> int:
    global _ACTUAL
    if _ACTUAL is None:
        import jax

        _ACTUAL = _pow2_floor(jax.device_count())
    return _ACTUAL


def device_count() -> int:
    """The mesh width the collective-aware cost model charges against."""

    if _FORCED is not None:
        return _pow2_floor(_FORCED)
    return _actual_devices()


def shard_count() -> int:
    """The mesh width execution actually shards over (≤ real devices)."""

    return min(device_count(), _actual_devices())


def _mesh(n: int):
    """The cached (n, 1) debug mesh over axes ("data", "model")."""

    mesh = _MESHES.get(n)
    if mesh is None:
        from repro.launch.mesh import make_debug_mesh

        mesh = _MESHES[n] = make_debug_mesh(data=n, model=1)
    return mesh


def reset_spmd_caches() -> None:
    """Drop every process-cached mesh/device handle plus the backend's
    structural cache (the ``obs.reset_all()`` hook): the next use re-reads
    ``jax.device_count()``, so tests that vary
    ``--xla_force_host_platform_device_count`` across subprocesses stay
    order-independent."""

    global _FORCED, _ACTUAL
    _FORCED = None
    _ACTUAL = None
    _MESHES.clear()
    SPMD_CACHE.clear()


# ---------------------------------------------------------------------- #
# The collective-aware cost hook.  Same units as xla_level_cost (per-step
# padded-lane work): the lane term is divided across devices, and sharded
# steps add a flat collective dispatch plus a per-lane gather term.  At
# device_count()==1 this is exactly xla_level_cost — the degenerate mesh
# must not perturb single-device strategy selection.
# ---------------------------------------------------------------------- #

# Hand-set defaults for the collective terms, in lane units.  Like the
# constants in repro.compile these are only the profile-less fallback:
# spmd_level_cost resolves all four unit costs late through
# repro.calibrate.units(), so a warmed profile (or a monkeypatched
# constant — the old import-by-value of XLA_STEP_LANE_UNITS made patches
# invisible here) takes effect on the next auction.

# flat per-step cost of issuing the lane-gather collective, in lane units
SPMD_COLLECTIVE_UNITS = 4.0
# per-lane cost of moving one gathered lane between devices
SPMD_COLLECTIVE_LANE_UNITS = 0.125


def spmd_level_cost(plan, ctx) -> float:
    """Per-SCC cost of a strategy offer on the sharded level loop.

    ``depth × statements × (flat + lanes/devices [+ collective(lanes)])``:
    a wide skewed wavefront amortizes its padded lanes across the mesh but
    pays the all-gather per step, so it wins only when ``lanes/n`` savings
    beat the collective tax — narrow chunked schedules (lanes ≤ devices)
    keep losing to plain chunking, which is the divergence-per-SCC the
    ``spmd_wide_wavefront`` bench and ``tests/test_spmd.py`` pin.
    """

    from repro.calibrate import units as _units

    u = _units()
    n = device_count()
    width = plan.max_width if plan.max_width else max(1, round(plan.width))
    # sharded tables pad lanes up to the mesh width (see _pad_lanes)
    lanes = max(_next_pow2(max(1, int(width))), n if n > 1 else 1)
    per_step = u["xla_step"] + u["xla_lane"] * lanes / n
    if n > 1:
        per_step += (
            u["spmd_collective"] + u["spmd_collective_lane"] * lanes
        )
    return float(plan.depth) * len(ctx.statements) * per_step


# ---------------------------------------------------------------------- #
# The sharded artifact
# ---------------------------------------------------------------------- #

# set while tracing inside the shard_map region: (axis name, shard count).
# _lane_values consults it so the same group_step code shards when mapped
# and stays full-width in the degenerate path.
_SHARD_AXIS: contextvars.ContextVar = contextvars.ContextVar(
    "spmd_shard_axis", default=None
)


@dataclasses.dataclass(frozen=True)
class _SpmdCaseStatic(_CaseStatic):
    """Trace-shaping static plus the shard count: device count changes the
    traced computation (slice + all_gather per read-bearing statement), so
    it belongs in the jit static — and therefore the bucket — never in the
    structural key."""

    n_shards: int = 1


class SpmdCompiledProgram(CompiledProgram):
    """A :class:`CompiledProgram` whose lane gather/compute is sharded
    across a device mesh (see module docstring for the exact split)."""

    def _level_cost_hook(self):
        return spmd_level_cost

    def _pad_lanes(self, wp: int) -> int:
        # lane dims must divide the mesh's data axis; both are powers of
        # two, so padding up to the shard count suffices
        return max(wp, shard_count())

    def _use_cond(self, wp: int) -> bool:
        # never wrap sharded group steps in lax.cond: the all_gather inside
        # would make the branches' collective schedules diverge.  The
        # active bit folds into the lane mask instead (the narrow-statement
        # path of the base lowering), which is mask-equivalent.
        return False

    def _band_rungs(self, wpb: int) -> int:
        # no width ladder when sharded: the per-shard lane slice +
        # all_gather reassembly needs every statement at its full padded
        # width (lane counts must divide the mesh axis).  Returning 0 keeps
        # the band's dynamic vector cut-free, so the base executor derives
        # L == 0 from its shape and stays on the single-loop path.
        return 0

    def _make_static(self, stmts, segments) -> _SpmdCaseStatic:
        return _SpmdCaseStatic(
            stmts=stmts, segments=segments, n_shards=shard_count()
        )

    def _case_key_extra(self) -> Tuple:
        # re-meshing rebuilds tables (lane padding depends on the shard
        # count) without touching the structural level
        return (shard_count(),)

    def _lane_values(self, k, ss, store, ridx, width, opaque_zero):
        ax = _SHARD_AXIS.get()
        if ax is None or not ss.reads:
            # degenerate mesh, or a zero-read broadcast statement (cheaper
            # replicated than gathered)
            return super()._lane_values(
                k, ss, store, ridx, width, opaque_zero
            )
        axis, n = ax
        from jax import lax

        shard = width // n
        lo = lax.axis_index(axis) * shard
        ridx_loc = [
            lax.dynamic_slice_in_dim(ix, lo, shard) for ix in ridx
        ]
        reads = [store[a][ix] for a, ix in zip(ss.reads, ridx_loc)]
        vals = self._batched[k](reads, shard, opaque_zero)
        # tiled gather concatenates shards in device order — the contiguous
        # blocks sliced above — restoring the original lane order
        return lax.all_gather(vals, axis, tiled=True)

    def _exec(
        self, static, n_levels, seg_dyn, tables, store, coverage, bad,
        opaque_zero,
    ):
        n = getattr(static, "n_shards", 1)
        if n <= 1:
            # the degenerate mesh IS the single-device trace
            return super()._exec(
                static, n_levels, seg_dyn, tables, store, coverage, bad,
                opaque_zero,
            )
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.launch.sharding import _pick

        mesh = _mesh(n)
        if _pick(mesh, n, "data") is None:  # pragma: no cover - mesh guard
            raise AssertionError(
                f"mesh data axis does not divide shard count {n}"
            )

        def body(n_levels, seg_dyn, tables, store, coverage, bad,
                 opaque_zero):
            token = _SHARD_AXIS.set(("data", n))
            try:
                return CompiledProgram._exec(
                    self, static, n_levels, seg_dyn, tables, store,
                    coverage, bad, opaque_zero,
                )
            finally:
                _SHARD_AXIS.reset(token)

        # every input and output is replicated (P()); the only sharded
        # values live transiently between the per-device lane slice and the
        # all_gather inside _lane_values.  check_rep=False because jax
        # cannot prove the replication invariant through the gathers.
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(), P(), P()),
            out_specs=P(),
            check_rep=False,
        )(n_levels, seg_dyn, tables, store, coverage, bad, opaque_zero)

    def execute(self, case, dense):
        n = getattr(case.static, "n_shards", 1)
        _metrics.gauge("spmd.devices").set(n)
        if n > 1:
            # host-side collective accounting: one all_gather executes per
            # read-bearing statement per level (cond-less dispatch runs
            # every statement each level; band steps likewise execute one
            # row per level of the band)
            hist = _metrics.histogram("spmd.shard_width")
            gathers = 0
            for ss, t in zip(case.static.stmts, case.tables):
                if not ss.reads:
                    continue
                hist.observe(t["lanemask"].shape[1] // n)
                gathers += case.n_levels
            _metrics.counter("spmd.collectives").inc(gathers)
        return super().execute(case, dense)


# the backend-owned structural cache: same four-level hierarchy, separate
# namespace (metrics under spmd_compile_cache.*), sharded artifact factory
SPMD_CACHE = CompileCache(
    metrics_prefix="spmd_compile_cache", factory=SpmdCompiledProgram
)


# ---------------------------------------------------------------------- #
# Backend registration: plan(...).compile("xla_spmd") / parallelize(...,
# backend="xla_spmd").  Mirrors repro.compile's xla registration, routed
# through SPMD_CACHE.
# ---------------------------------------------------------------------- #

def _spmd_prepare(
    optimized,
    retained,
    *,
    chunk_limit=None,
    scc_policy=None,
    model="doall",
    processors=None,
    deps=None,
):
    compiled, hit = SPMD_CACHE.get_or_compile(
        optimized.program,
        tuple(retained),
        model=model,
        processors=processors,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
        deps=deps,
    )
    return {"compiled": compiled, "compile_hit": hit}


def _spmd_differential(sync, *, store=None, stalls=None):
    from repro.compile.executor import run_xla

    return run_xla(sync, store=store, compare=False, cache=SPMD_CACHE).store


def _spmd_run(sync, artifacts, *, store=None, stalls=None):
    from repro.compile.executor import execute_compiled, run_xla

    compiled = artifacts.get("compiled")
    if compiled is None:  # prepared elsewhere: resolve through the cache
        return run_xla(
            sync, store=store, compare=False, cache=SPMD_CACHE
        ).store
    return execute_compiled(compiled, sync, store=store)


def _register() -> None:
    from repro.core.parallelizer import BackendSpec, register_backend

    register_backend(
        BackendSpec(
            name="xla_spmd",
            prepare=_spmd_prepare,
            accepts=(
                "chunk_limit", "scc_policy", "model", "processors", "deps",
            ),
            level_cost=spmd_level_cost,
            differential=_spmd_differential,
            run=_spmd_run,
            description=(
                "multi-device SPMD wavefront: lanes sharded across a jax "
                "mesh via shard_map, collective-aware strategy costing "
                "(repro.compile.spmd)"
            ),
        )
    )


_register()
