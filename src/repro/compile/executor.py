"""``run_xla`` — the compiled-executor entry point, API-parallel to
:func:`repro.core.wavefront.run_wavefront` and
:func:`repro.core.executor.run_threaded` so the differential harness
(``tests/oracle.py``) can drive all registered backends uniformly.

Resolution path per call: structural cache (artifact) → per-bounds table
cache (level buffers) → jax jit cache (XLA specialization) → execute.  A
fully warm call touches only the last step plus host/device store conversion.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.core.ir import run_sequential
from repro.core.policy import SccPolicyLike
from repro.core.sync import SyncProgram
from repro.core.wavefront import (
    WavefrontSchedule,
    WavefrontStats,
    _DenseStore,
    _sync_dependences,
)
from repro.compile.cache import GLOBAL_CACHE, CompileCache


def execute_compiled(
    compiled,
    sync: SyncProgram,
    *,
    store: Optional[Mapping[str, dict]] = None,
) -> dict:
    """Run an already-resolved :class:`CompiledProgram` and return the store.

    The :class:`~repro.core.parallelizer.Executable` runner for the xla
    backend: no structural-cache lookup (the artifact is in hand), only the
    per-(bounds, layout) table cache and jax's jit cache underneath — which
    is what makes ``plan once, compile once, run many`` the warm path.
    """

    prog = sync.program
    init = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    dense = _DenseStore(init)
    case, table_hit = compiled.prepare(prog, dense)
    if compiled.cache is not None:
        compiled.cache.note_tables(table_hit)
    compiled.execute(case, dense)
    if (
        getattr(compiled, "deps_mode", None) == "speculate"
        and prog.has_indirect()
    ):
        # the artifact ran the optimistic (affine-retained) schedule; check
        # it against the inspector's exact instance graph and, on any
        # violated edge, discard the result and re-run the conservative
        # deps=None artifact from the untouched initial store
        from repro.core.inspector import (
            inspect_dependences,
            speculation_violations,
        )
        from repro.compile.cache import GLOBAL_CACHE

        inspection = inspect_dependences(prog, init)
        _metrics.counter("speculation.validations").inc()
        with _trace.span("speculate.validate", backend="xla"):
            violated = bool(
                speculation_violations(
                    prog, inspection.edges, case.schedule.level_of()
                )
            )
        if violated:
            _metrics.counter("speculation.rollbacks").inc()
            with _trace.span("speculate.rollback", backend="xla"):
                cache = (
                    compiled.cache if compiled.cache is not None else GLOBAL_CACHE
                )
                fallback, _ = cache.get_or_compile(
                    prog,
                    compiled.retained,
                    model=compiled.model,
                    processors=compiled.processors,
                    chunk_limit=compiled.chunk_limit,
                    scc_policy=compiled.scc_policy,
                )
                return execute_compiled(fallback, sync, store=init)
    return dense.to_dicts()


@dataclasses.dataclass
class XlaReport:
    """Mirror of :class:`~repro.core.wavefront.WavefrontReport` plus the
    compile-cache provenance of this call."""

    store: dict
    schedule: WavefrontSchedule
    stats: WavefrontStats
    matches_sequential: bool
    compiled: object  # CompiledProgram
    cache_events: Dict[str, str]  # {"structural": hit|miss, "tables": ...}


def run_xla(
    sync: SyncProgram,
    *,
    schedule: Optional[WavefrontSchedule] = None,
    store: Optional[Mapping[str, dict]] = None,
    compare: bool = True,
    model: str = "doall",
    processors: Optional[Dict[str, object]] = None,
    cache: Optional[CompileCache] = None,
    chunk_limit: Optional[int] = None,
    scc_policy: SccPolicyLike = None,
    deps: Optional[str] = None,
) -> XlaReport:
    """Execute ``sync`` through the structural compile cache.

    Same store format and ``matches_sequential`` contract as the other
    executors.  ``schedule`` (when given, e.g. from a wavefront-backend
    report) contributes its retained dependence set *and* its execution
    model — the artifact still builds its own level tables per bounds,
    because one structural entry serves many bounds, but it must layer them
    under the schedule's model (a procmap schedule re-layered as doall would
    silently drop same-processor orders).
    """

    cache = cache if cache is not None else GLOBAL_CACHE
    prog = sync.program
    if schedule is not None:
        retained = tuple(schedule.retained)
        model = schedule.model
        if processors is None:
            processors = schedule.processors
        if chunk_limit is None:
            chunk_limit = schedule.chunk_limit
        if scc_policy is None:
            scc_policy = schedule.scc_policy
    else:
        retained = tuple(_sync_dependences(sync))
    compiled, hit = cache.get_or_compile(
        prog,
        retained,
        model=model,
        processors=processors,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
        deps=deps,
    )

    init = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    if deps == "speculate" and prog.has_indirect():
        # validation + rollback live in execute_compiled; the report's
        # schedule/stats describe the *speculative* attempt either way
        result = execute_compiled(compiled, sync, store=init)
        case, table_hit = compiled.prepare(prog, _DenseStore(init))
        sched = case.schedule
        stats = WavefrontStats(
            levels=sched.depth,
            batched_ops=sched.batched_ops,
            instances=sched.instances,
            max_width=sched.max_width,
        )
    else:
        dense = _DenseStore(init)
        case, table_hit = compiled.prepare(prog, dense)
        cache.note_tables(table_hit)
        stats = compiled.execute(case, dense)
        result = dense.to_dicts()

    matches = True
    if compare:
        matches = run_sequential(prog, init) == result
    return XlaReport(
        store=result,
        schedule=case.schedule,
        stats=stats,
        matches_sequential=matches,
        compiled=compiled,
        cache_events={
            "structural": "hit" if hit else "miss",
            "tables": "hit" if table_hit else "miss",
        },
    )
