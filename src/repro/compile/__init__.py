"""``repro.compile`` — JAX/XLA compilation of optimized SyncPrograms.

The fourth executor (see ROADMAP "Execution backends").  Where the wavefront
backend (:mod:`repro.core.wavefront`) *interprets* the dependence-level
schedule in NumPy, this package *compiles* it: the whole level loop becomes
one jitted ``lax.fori_loop`` over padded, mask-guarded level buffers
(:mod:`repro.compile.lowering`), cached structurally — by a canonical hash of
(statement graph, retained dependences, execution model), never loop bounds
(:mod:`repro.compile.structure`, :mod:`repro.compile.cache`) — so repeated
requests with the same dependence structure skip re-analysis and re-jit
entirely.

Registered as ``parallelize(..., backend="xla")`` and differentially checked
against the sequential oracle / threaded machine / NumPy wavefront by
``tests/oracle.py`` on every program, like any other backend.

Import is lazy: pulling this package costs no jax import until an artifact
is actually built (``run_xla`` / ``get_or_compile``), which keeps the
structural-hash helpers available to the parallelizer's analysis memo for
free.
"""

from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

from repro.compile.structure import (
    compute_fingerprint,
    program_fingerprint,
    structural_key,
)

_LAZY = {
    "CompileCache": "repro.compile.cache",
    "GLOBAL_CACHE": "repro.compile.cache",
    "clear_compile_cache": "repro.compile.cache",
    "compile_cache_stats": "repro.compile.cache",
    "get_or_compile": "repro.compile.cache",
    "CompiledProgram": "repro.compile.lowering",
    "XlaLoweringError": "repro.compile.lowering",
    "XlaReport": "repro.compile.executor",
    "run_xla": "repro.compile.executor",
}

if TYPE_CHECKING:  # pragma: no cover - typing aid only
    from repro.compile.cache import (  # noqa: F401
        CompileCache,
        GLOBAL_CACHE,
        clear_compile_cache,
        compile_cache_stats,
        get_or_compile,
    )
    from repro.compile.executor import XlaReport, run_xla  # noqa: F401
    from repro.compile.lowering import (  # noqa: F401
        CompiledProgram,
        XlaLoweringError,
    )


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


__all__ = sorted(
    ["compute_fingerprint", "program_fingerprint", "structural_key", *_LAZY]
)


# ---------------------------------------------------------------------- #
# Backend registration: parallelize(..., backend="xla").  The callables
# defer jax-heavy imports until the backend is actually exercised.
# ---------------------------------------------------------------------- #

def _xla_prepare(optimized, retained, **options):
    from repro.compile.cache import get_or_compile

    compiled, _hit = get_or_compile(
        optimized.program,
        tuple(retained),
        model="doall",
        chunk_limit=options.get("chunk_limit"),
        scc_policy=options.get("scc_policy"),
    )
    return {"compiled": compiled}


def _xla_differential(sync, *, store=None, stalls=None):
    from repro.compile.executor import run_xla

    return run_xla(sync, store=store, compare=False).store


def _register() -> None:
    from repro.core.parallelizer import BackendSpec, register_backend

    register_backend(
        BackendSpec(
            name="xla",
            prepare=_xla_prepare,
            differential=_xla_differential,
            description=(
                "structurally cached jitted XLA level loop "
                "(repro.compile; one artifact per dependence structure)"
            ),
        )
    )


_register()
