"""``repro.compile`` — JAX/XLA compilation of optimized SyncPrograms.

The fourth executor (see ROADMAP "Execution backends").  Where the wavefront
backend (:mod:`repro.core.wavefront`) *interprets* the dependence-level
schedule in NumPy, this package *compiles* it: the whole level loop becomes
one jitted ``lax.fori_loop`` over padded, mask-guarded level buffers
(:mod:`repro.compile.lowering`), cached structurally — by a canonical hash of
(statement graph, retained dependences, execution model), never loop bounds
(:mod:`repro.compile.structure`, :mod:`repro.compile.cache`) — so repeated
requests with the same dependence structure skip re-analysis and re-jit
entirely.

Registered as ``parallelize(..., backend="xla")`` and differentially checked
against the sequential oracle / threaded machine / NumPy wavefront by
``tests/oracle.py`` on every program, like any other backend.

Import is lazy: pulling this package costs no jax import until an artifact
is actually built (``run_xla`` / ``get_or_compile``), which keeps the
structural-hash helpers available to the parallelizer's analysis memo for
free.
"""

from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

from repro.compile.structure import (
    compute_fingerprint,
    program_fingerprint,
    structural_key,
)

_LAZY = {
    "CompileCache": "repro.compile.cache",
    "GLOBAL_CACHE": "repro.compile.cache",
    "clear_compile_cache": "repro.compile.cache",
    "compile_cache_stats": "repro.compile.cache",
    "get_or_compile": "repro.compile.cache",
    "CompiledProgram": "repro.compile.lowering",
    "XlaLoweringError": "repro.compile.lowering",
    "XlaReport": "repro.compile.executor",
    "execute_compiled": "repro.compile.executor",
    "run_xla": "repro.compile.executor",
}

if TYPE_CHECKING:  # pragma: no cover - typing aid only
    from repro.compile.cache import (  # noqa: F401
        CompileCache,
        GLOBAL_CACHE,
        clear_compile_cache,
        compile_cache_stats,
        get_or_compile,
    )
    from repro.compile.executor import (  # noqa: F401
        XlaReport,
        execute_compiled,
        run_xla,
    )
    from repro.compile.lowering import (  # noqa: F401
        CompiledProgram,
        XlaLoweringError,
    )


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


# ---------------------------------------------------------------------- #
# Backend capability: the xla cost hook.  Import-light on purpose (no jax,
# no numpy) — the core report path consults it through
# BackendSpec.level_cost without touching the heavy lowering machinery.
# ---------------------------------------------------------------------- #

# Hand-set default cost units, in padded-lane units.  Measured shape
# (ROADMAP "XLA band-step cost vs lane width"): a chunk=1 band costs
# ~1.5µs/step and the per-step cost grows roughly linearly with the padded
# lane width, with the flat dispatch share worth about one lane.  These
# are only the *defaults*: repro.calibrate replaces them with per-host
# measured values once a profile is warmed, and every consumer (including
# spmd_level_cost) resolves them late through calibrate.units(), so
# monkeypatching them here takes effect everywhere.
XLA_STEP_LANE_UNITS = 1.0   # flat per-step overhead of one band step
XLA_LANE_UNITS = 1.0        # cost of one padded lane on top of it


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def xla_level_cost(plan, ctx) -> float:
    """Per-SCC cost of a strategy offer *on the compiled level loop*.

    The NumPy interpreter pays per level dispatched, so the default cost
    model scores depth × statement groups.  The jitted ``lax.fori_loop``
    instead pays per level a near-flat step cost plus work proportional to
    the *padded* lane width of each statement's table row — so a skewed
    wavefront whose widest diagonal pads to 64 lanes loses its depth
    advantage against narrow sequential chunks (the open item this hook
    closes).  Cost model: ``depth × statements × (step + lane ×
    next_pow2(width))``, with the unit costs resolved through the host's
    calibration profile (:mod:`repro.calibrate`) — the hand-set constants
    above when nothing is warmed.
    """

    from repro.calibrate import units as _units

    u = _units()
    width = plan.max_width if plan.max_width else max(1, round(plan.width))
    lanes = _next_pow2(max(1, int(width)))
    return float(plan.depth) * len(ctx.statements) * (
        u["xla_step"] + u["xla_lane"] * lanes
    )


__all__ = sorted(
    [
        "compute_fingerprint",
        "program_fingerprint",
        "structural_key",
        "xla_level_cost",
        *_LAZY,
    ]
)


# ---------------------------------------------------------------------- #
# Backend registration: plan(...).compile("xla") / parallelize(...,
# backend="xla").  The callables defer jax-heavy imports until the backend
# is actually exercised.
# ---------------------------------------------------------------------- #

def _xla_prepare(
    optimized,
    retained,
    *,
    chunk_limit=None,
    scc_policy=None,
    model="doall",
    processors=None,
    deps=None,
):
    from repro.compile.cache import get_or_compile

    compiled, hit = get_or_compile(
        optimized.program,
        tuple(retained),
        model=model,
        processors=processors,
        chunk_limit=chunk_limit,
        scc_policy=scc_policy,
        deps=deps,
    )
    # compile_hit stays on Executable.artifacts (it is per-compile-call
    # provenance, not a report field)
    return {"compiled": compiled, "compile_hit": hit}


def _xla_differential(sync, *, store=None, stalls=None):
    from repro.compile.executor import run_xla

    return run_xla(sync, store=store, compare=False).store


def _xla_run(sync, artifacts, *, store=None, stalls=None):
    from repro.compile.executor import execute_compiled, run_xla

    compiled = artifacts.get("compiled")
    if compiled is None:  # prepared elsewhere: resolve through the cache
        return run_xla(sync, store=store, compare=False).store
    return execute_compiled(compiled, sync, store=store)


def _register() -> None:
    from repro.core.parallelizer import BackendSpec, register_backend

    register_backend(
        BackendSpec(
            name="xla",
            prepare=_xla_prepare,
            accepts=("chunk_limit", "scc_policy", "model", "processors", "deps"),
            level_cost=xla_level_cost,
            differential=_xla_differential,
            run=_xla_run,
            description=(
                "structurally cached jitted XLA level loop "
                "(repro.compile; one artifact per dependence structure)"
            ),
        )
    )


_register()
