"""The staged Plan→Executable API (ISSUE 5).

Covers what is *specific* to the redesign — execution semantics ride on
tests/oracle.py as always:

  * PlanOptions: frozen, validated, hashable typed options;
  * plan reuse: one ``plan()`` + N ``compile()``s runs dependence analysis
    and elimination exactly once (counting spy + analysis_cache_stats), and
    ``Executable.run`` stores are bit-equal to the oracle;
  * backend capability contracts: undeclared options raise (never silently
    dropped), legacy registrants included;
  * the backend-aware cost model: one plan, different strategies on
    wavefront vs xla for the same SCC, both bit-equal;
  * back-compat: the ``parallelize()`` shim produces a field-for-field
    identical report and shares the structural compile-cache entry with the
    staged entry point (warm hit across old/new).
"""

import typing
import warnings

import pytest

from oracle import assert_equivalent
from repro.core import (
    ArrayRef,
    BackendSpec,
    LoopProgram,
    PlanOptions,
    SccPolicyLike,
    SchedulingPolicy,
    Statement,
    analysis_cache_stats,
    backend_accepted_options,
    clear_analysis_cache,
    get_backend,
    parallelize,
    paper_alg6,
    plan,
    register_backend,
    run_sequential,
)
from repro.core.dependence import analyze
from repro.core.fission import fission
from repro.core.sync import insert_synchronization, strip_dependences


def wide_serialized(ni=5, nj=16):
    """{(0,1), (1,-1)} self-recurrence: the per-backend cost hooks disagree
    (the interpreter skews, the compiled level loop chunks)."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, ni), (0, nj)),
    )


# ---------------------------------------------------------------------- #
# PlanOptions
# ---------------------------------------------------------------------- #

class TestPlanOptions:
    def test_frozen_and_hashable(self):
        a = PlanOptions(method="both", chunk_limit=3, scc_policy="chunk")
        b = PlanOptions(method="both", chunk_limit=3, scc_policy="chunk")
        assert a == b and hash(a) == hash(b)
        assert len({a, b}) == 1
        with pytest.raises(dataclasses_frozen_error()):
            a.method = "isd"  # type: ignore[misc]

    def test_deps_normalized_to_tuple(self):
        deps = analyze(paper_alg6(6))
        opts = PlanOptions(deps=deps)
        assert isinstance(opts.deps, tuple)
        hash(opts)

    def test_processors_normalized_and_hashable(self):
        opts = PlanOptions(
            method="isd", model="procmap", processors={"S1": "p0"}
        )
        assert opts.processors == (("S1", "p0"),)
        assert opts.processor_map == {"S1": "p0"}
        hash(opts)

    @pytest.mark.parametrize("bad", (0, -1, True, 2.5, "4"))
    def test_chunk_limit_validated(self, bad):
        with pytest.raises(ValueError, match="chunk_limit"):
            PlanOptions(chunk_limit=bad)

    def test_method_validated(self):
        with pytest.raises(ValueError, match="elimination method"):
            PlanOptions(method="magic")

    def test_scc_policy_validated(self):
        with pytest.raises(ValueError, match="scc_policy"):
            PlanOptions(scc_policy="diagonal")

    def test_model_validated(self):
        with pytest.raises(ValueError, match="execution model"):
            PlanOptions(model="simd")
        with pytest.raises(ValueError, match="processors"):
            PlanOptions(model="procmap")
        with pytest.raises(ValueError, match="procmap"):
            PlanOptions(processors={"S1": "p0"})
        with pytest.raises(ValueError, match="doall"):
            PlanOptions(method="pattern", model="dswp")

    def test_plan_rejects_options_plus_overrides(self):
        with pytest.raises(TypeError, match="not both"):
            plan(paper_alg6(4), PlanOptions(), method="isd")

    def test_scc_policy_like_alias_is_exported(self):
        """Satellite: a real SccPolicyLike alias, used in the signatures."""

        import inspect

        args = typing.get_args(SccPolicyLike)
        assert type(None) in args and str in args
        assert SchedulingPolicy in args
        for fn, param in (
            (parallelize, "scc_policy"),
            (plan_options_field_type(), None),
        ):
            if param is None:
                assert fn == "SccPolicyLike"
                continue
            ann = inspect.signature(fn).parameters[param].annotation
            assert "SccPolicyLike" in str(ann)
        from repro.core.wavefront import schedule_levels

        ann = inspect.signature(schedule_levels).parameters["scc_policy"]
        assert "SccPolicyLike" in str(ann.annotation)


def dataclasses_frozen_error():
    import dataclasses

    return dataclasses.FrozenInstanceError


def plan_options_field_type() -> str:
    import dataclasses

    (ann,) = [
        f.type
        for f in dataclasses.fields(PlanOptions)
        if f.name == "scc_policy"
    ]
    return str(ann)


# ---------------------------------------------------------------------- #
# Plan reuse: analysis exactly once, Executable.run bit-equal
# ---------------------------------------------------------------------- #

class TestPlanReuse:
    def test_elimination_runs_exactly_once_across_backends(self, monkeypatch):
        """Satellite: plan once + compile wavefront AND xla = one
        elimination (counting spy on the transitive reduction) and one
        analysis-memo miss, zero extra lookups."""

        import repro.core.parallelizer as par

        calls = {"n": 0}
        real = par.eliminate_transitive

        def spy(*args, **kwargs):
            calls["n"] += 1
            return real(*args, **kwargs)

        monkeypatch.setattr(par, "eliminate_transitive", spy)
        clear_analysis_cache()
        prog = wide_serialized(4, 7)
        p = plan(prog, method="isd")
        assert calls["n"] == 1
        stats = analysis_cache_stats()
        assert stats == {"hits": 0, "misses": 1}

        exe_wf = p.compile("wavefront")
        exe_xla = p.compile("xla")
        assert calls["n"] == 1, "compile() must not re-run elimination"
        assert analysis_cache_stats() == stats, (
            "compile() must not even consult the analysis memo"
        )

        oracle = run_sequential(prog, prog.initial_store())
        assert exe_wf.run() == oracle
        assert exe_xla.run() == oracle

    def test_executable_run_through_oracle_matrix(self):
        """Satellite: Executable.run stores bit-equal via the existing
        differential harness (run_all_backends routes the optimized variant
        through Executable.run for every registered backend)."""

        assert_equivalent(wide_serialized(4, 6), methods=("none", "isd"))
        assert_equivalent(paper_alg6(7))

    def test_uniform_run_contract_signature(self):
        p = plan(paper_alg6(6))
        oracle = run_sequential(paper_alg6(6), paper_alg6(6).initial_store())
        for backend in ("threaded", "wavefront", "xla"):
            exe = p.compile(backend)
            # positional store, keyword stalls — the uniform contract
            assert exe.run(None, stalls=None) == oracle, backend


# ---------------------------------------------------------------------- #
# Capability contracts
# ---------------------------------------------------------------------- #

class TestCapabilityContract:
    def test_declared_contracts(self):
        # threaded accepts "deps" as a documented no-op: its conservative
        # send/wait execution already enforces a superset of any inspector
        # graph, so inspect/speculate plans stay compilable for it
        assert backend_accepted_options(get_backend("threaded")) == ("deps",)
        assert set(backend_accepted_options(get_backend("wavefront"))) == {
            "chunk_limit", "scc_policy", "model", "processors", "deps",
        }
        assert set(backend_accepted_options(get_backend("xla"))) == {
            "chunk_limit", "scc_policy", "model", "processors", "deps",
        }

    def test_threaded_rejects_scheduling_knobs(self):
        with pytest.raises(ValueError, match="threaded.*chunk_limit"):
            plan(paper_alg6(4), chunk_limit=2).compile("threaded")
        with pytest.raises(ValueError, match="threaded.*scc_policy"):
            plan(paper_alg6(4)).compile("threaded", scc_policy="chunk")

    def test_unknown_option_names_accepted_set(self):
        with pytest.raises(ValueError, match="frobnicate") as ei:
            plan(paper_alg6(4)).compile("wavefront", frobnicate=1)
        assert "chunk_limit" in str(ei.value)
        assert "scc_policy" in str(ei.value)

    def test_unknown_option_rejected_even_when_none_valued(self):
        """A misspelled knob must error even when its value is None — the
        None-filter only removes *declared* plan-level knobs."""

        with pytest.raises(ValueError, match="chunk_limt"):
            plan(paper_alg6(4)).compile("wavefront", chunk_limt=None)

    def test_legacy_registrant_contract_inferred_and_enforced(self):
        """A pre-knob registrant (prepare(optimized, retained)) accepts
        nothing: the knob that used to be silently dropped now errors."""

        name = "legacy-test-backend"
        register_backend(
            BackendSpec(
                name=name,
                prepare=lambda optimized, retained: {},
                differential=None,
            )
        )
        try:
            assert backend_accepted_options(get_backend(name)) == ()
            p = plan(paper_alg6(4), chunk_limit=2)
            with pytest.raises(ValueError, match="legacy-test-backend"):
                p.compile(name)
            # without the knob it still compiles (no artifacts, no runner)
            exe = plan(paper_alg6(4)).compile(name)
            assert exe.artifacts == {}
        finally:
            import repro.core.parallelizer as par

            par._REGISTRY.pop(name, None)

    def test_var_kwargs_registrant_accepts_everything(self):
        name = "kwargs-test-backend"
        seen = {}
        register_backend(
            BackendSpec(
                name=name,
                prepare=lambda optimized, retained, **kw: seen.update(kw)
                or {},
                differential=None,
            )
        )
        try:
            assert backend_accepted_options(get_backend(name)) is None
            plan(paper_alg6(4), chunk_limit=2).compile(name, custom_knob=7)
            assert seen == {"chunk_limit": 2, "custom_knob": 7}
        finally:
            import repro.core.parallelizer as par

            par._REGISTRY.pop(name, None)

    def test_compile_override_beats_plan_knob_and_none_removes(self):
        # Δ=(1,-1) stencil: carried_min = nj-1 = 8, so the caps are visible
        stencil = LoopProgram(
            statements=(
                Statement(
                    "S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)
                ),
            ),
            bounds=((0, 4), (0, 9)),
        )
        p = plan(stencil, chunk_limit=1, scc_policy="chunk")
        rep = p.compile("wavefront", chunk_limit=2).report()
        assert rep.chunk_limit == 2
        (rec,) = rep.wavefront.scc.recurrences
        assert rec.chunk == 2
        # an explicit None override removes the plan-level knob entirely
        rep2 = p.compile("wavefront", chunk_limit=None).report()
        assert rep2.chunk_limit is None
        assert rep2.wavefront.scc.recurrences[0].chunk == 8


# ---------------------------------------------------------------------- #
# Backend-aware cost model
# ---------------------------------------------------------------------- #

class TestBackendAwareCostModel:
    def test_one_plan_two_strategies_both_bit_equal(self):
        """ISSUE acceptance: the level_cost hook makes xla and wavefront
        choose different strategies for the same SCC; both bit-equal."""

        prog = wide_serialized(5, 16)
        p = plan(prog, method="isd")
        exe_wf = p.compile("wavefront")
        exe_xla = p.compile("xla")
        (rec_wf,) = exe_wf.report().summary()["scc"]["recurrences"]
        (rec_xla,) = exe_xla.report().summary()["scc"]["recurrences"]
        assert rec_wf["strategy"] == "skew"
        assert rec_xla["strategy"] == "chunk"
        assert "xla_level_cost" in rec_xla["reason"]

        oracle = run_sequential(prog, prog.initial_store())
        assert exe_wf.run() == oracle
        assert exe_xla.run() == oracle

    def test_xla_artifact_actually_schedules_its_own_strategy(self):
        """The divergence is not a reporting artifact: the compiled level
        tables are built from the xla-cost schedule."""

        from repro.compile import run_xla

        prog = wide_serialized(5, 16)
        p = plan(prog, method="isd")
        p.compile("xla")
        r = run_xla(p.optimized_sync, compare=True)
        (rec,) = r.schedule.scc.recurrences
        assert rec.strategy == "chunk"
        assert r.matches_sequential

    def test_forced_policy_wins_over_backend_hook(self):
        prog = wide_serialized(5, 16)
        rep = plan(prog).compile("xla", scc_policy="skew").report()
        (rec,) = rep.summary()["scc"]["recurrences"]
        assert rec["strategy"] == "skew"

    def test_acyclic_programs_unaffected_by_hook(self):
        p = plan(paper_alg6(8))
        s_wf = p.compile("wavefront").report().summary()
        s_xla = p.compile("xla").report().summary()
        assert s_wf["scc"]["recurrences"] == []
        assert s_xla["scc"]["recurrences"] == []

    def test_procmap_report_scc_summary_uses_plan_model(self):
        """A procmap plan's xla report must condense under procmap, not
        silently fall back to doall (the schedule-less summary path)."""

        from repro.kernels.pipelined_matmul.schedule import (
            PROCESSORS,
            _kloop_options,
            make_kloop_program,
        )

        p = plan(make_kloop_program(8), _kloop_options(2))
        s = p.compile("xla").report().summary()
        assert s["scc"]["model"] == "procmap"
        s_wf = p.compile("wavefront").report().summary()
        assert s_wf["scc"]["model"] == "procmap"
        assert PROCESSORS  # the map participated (procmap requires it)

    def test_policy_signature_distinguishes_level_cost_hooks(self):
        from repro.core import CostModelPolicy
        from repro.core.policy import policy_signature

        a = policy_signature(CostModelPolicy(level_cost=lambda p, c: 1.0))
        b = policy_signature(CostModelPolicy(level_cost=lambda p, c: 2.0))
        assert a != b
        assert policy_signature(CostModelPolicy()) == policy_signature("auto")


# ---------------------------------------------------------------------- #
# Back-compat: the parallelize() shim
# ---------------------------------------------------------------------- #

class TestBackCompatShim:
    def _reference_report_fields(self, prog, method="isd"):
        """The pre-redesign pipeline, reimplemented from its own pieces —
        the golden the shim is held to, independent of plan()/compile()."""

        from repro.core.elimination import eliminate_transitive

        dep_list = analyze(prog)
        fiss = fission(prog, dep_list)
        naive = insert_synchronization(prog, dep_list, merge=False)
        elim = eliminate_transitive(prog, dep_list)
        optimized = strip_dependences(naive, elim.eliminated)
        return dep_list, fiss, naive, elim, optimized

    def test_shim_emits_deprecation_warning(self):
        with pytest.warns(DeprecationWarning, match="parallelize"):
            parallelize(paper_alg6(4), method="isd")

    def test_report_equal_field_for_field_to_pre_redesign_golden(self):
        """Satellite: shim report vs an independently computed golden —
        summary dict included."""

        prog = paper_alg6(8)
        deps, fiss, naive, elim, optimized = self._reference_report_fields(
            prog
        )
        with pytest.warns(DeprecationWarning):
            rep = parallelize(prog, method="isd")
        assert rep.program is prog
        assert list(rep.dependences) == list(deps)
        assert rep.fission.loop_names() == fiss.loop_names()
        assert (
            rep.naive_sync.sync_instruction_count()
            == naive.sync_instruction_count()
        )
        assert rep.elimination.retained == elim.retained
        assert rep.elimination.eliminated == elim.eliminated
        assert rep.elimination.witnesses == elim.witnesses
        assert (
            rep.optimized_sync.sync_instruction_count()
            == optimized.sync_instruction_count()
        )
        golden_summary = {
            "dependences": 2,
            "loop_carried": 2,
            "eliminated": 1,
            "naive_sync_instructions": 4,
            "optimized_sync_instructions": 2,
            "naive_runtime_sync_ops": 28,
            "optimized_runtime_sync_ops": 14,
            "method": "isd-transitive-reduction[doall]",
            "backend": "threaded",
            "scc": {
                "sccs": 2,
                "cyclic": 1,
                "recurrences": [],
                "model": "doall",
                "policy": "auto",
            },
            # observability pointers (PR 7): deterministic — export
            # locations and the tracing flag only, never live counters
            "obs": {
                "tracing": False,
                "trace_export": (
                    "Executable.trace_json() / obs.trace.trace_json()"
                ),
                "metrics_export": "obs.metrics.snapshot()",
                # calibration pointer (PR 10): the profile *state*, never
                # measured unit values (tests pin the default state via the
                # reset fixture / REPRO_CALIBRATE handling)
                "calibration": {
                    "enabled": True,
                    "source": "default",
                    "generation": 0,
                    "profile_export": (
                        "repro.calibrate.active_profile() / profile_path()"
                    ),
                },
                "backend": "threaded",
            },
        }
        assert rep.summary() == golden_summary

    def test_shim_report_bit_identical_to_staged_entry(self):
        prog = wide_serialized(4, 9)
        staged = plan(prog, method="isd").compile("wavefront").report()
        with pytest.warns(DeprecationWarning):
            shim = parallelize(prog, method="isd", backend="wavefront")
        assert shim.summary() == staged.summary()
        assert shim.wavefront.levels == staged.wavefront.levels
        assert shim.elimination == staged.elimination

    def test_structural_cache_key_parity_warm_hit_across_entries(self):
        """Satellite: the structural compile key is unchanged — computed by
        the pre-redesign key function on the reference pipeline's retained
        set — and a new-entry compile warms the cache for the old entry."""

        from repro.compile import clear_compile_cache, compile_cache_stats
        from repro.compile.structure import structural_key

        prog = paper_alg6(9)
        *_rest, elim, _opt = self._reference_report_fields(prog)
        golden_key = structural_key(
            prog, tuple(elim.retained), "doall", None, None, None
        )

        clear_compile_cache()
        exe = plan(prog, method="isd").compile("xla")  # new entry: cold
        assert exe.compiled.key == golden_key
        assert compile_cache_stats()["misses"] == 1
        with pytest.warns(DeprecationWarning):
            rep = parallelize(prog, method="isd", backend="xla")  # old entry
        assert rep.compiled is exe.compiled
        stats = compile_cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 1
