"""Property tests for the structural compile-cache key (seeded-random
programs; no hypothesis dependency, so this guard always runs — same idiom
as test_strip_properties.py).

The key must be exactly as fine as the lowering specializes:

  * **invariant** under anything the compiled artifact absorbs — loop
    bounds (the per-bounds tables are a second-level cache), re-created
    program/dependence objects, and compute-function *identity* (a
    behaviorally identical function compiled from the same code maps to the
    same key, or the serving path would never hit);
  * **sensitive** to anything that changes the generated executable — the
    statement graph (accesses, offsets, guards, compute code, captured
    constants), the retained dependence set, and the execution model.

A false hit here is a silent wrong-code cache — these tests are the no-false-
hits guard."""

import dataclasses
import random
import types

import pytest

from repro.core import ArrayRef, LoopProgram, Statement, analyze, loop_carried
from repro.compile import structural_key

ARRAYS = ["a", "b", "c", "d"]
SEEDS = list(range(30))


def random_program(seed: int, scale: float = 1.0) -> LoopProgram:
    rng = random.Random(seed)
    stmts = []
    for k in range(rng.randint(1, 5)):
        reads = tuple(
            ArrayRef(rng.choice(ARRAYS), -rng.randint(0, 3))
            for _ in range(rng.randint(0, 3))
        )
        stmts.append(
            Statement(
                f"S{k+1}",
                ArrayRef(rng.choice(ARRAYS), 0),
                reads,
                compute=make_compute(rng.uniform(0.5, 2.0) * scale),
            )
        )
    return LoopProgram(
        statements=tuple(stmts), bounds=((1, 1 + rng.randint(3, 9)),)
    )


def make_compute(weight: float):
    def compute(*reads: float) -> float:
        acc = weight
        for k, r in enumerate(reads):
            acc = acc + r / (k + 2)
        return acc

    return compute


def clone_function(fn):
    """A new function object with the same code/closure/defaults — a pure
    identity change."""

    out = types.FunctionType(
        fn.__code__, fn.__globals__, fn.__name__, fn.__defaults__, fn.__closure__
    )
    assert out is not fn
    return out


def rebuild(prog: LoopProgram, *, bounds=None, clone_computes=False):
    stmts = tuple(
        dataclasses.replace(
            s, compute=clone_function(s.compute) if clone_computes else s.compute
        )
        for s in prog.statements
    )
    return LoopProgram(statements=stmts, bounds=bounds or prog.bounds)


def key_of(prog: LoopProgram, deps=None, model="doall") -> str:
    retained = list(loop_carried(deps if deps is not None else analyze(prog)))
    return structural_key(prog, retained, model)


class TestInvariance:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_bounds_change_keeps_key(self, seed):
        prog = random_program(seed)
        lo = prog.bounds[0][0]
        grown = rebuild(prog, bounds=((lo, lo + 517),))
        assert key_of(prog) == key_of(grown)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_compute_identity_change_keeps_key(self, seed):
        prog = random_program(seed)
        cloned = rebuild(prog, clone_computes=True)
        assert key_of(prog) == key_of(cloned)

    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_object_identity_of_dependences_irrelevant(self, seed):
        prog = random_program(seed)
        deps1 = analyze(prog)
        deps2 = analyze(prog)  # fresh Dependence objects
        assert key_of(prog, deps1) == key_of(prog, deps2)

    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_retained_order_irrelevant(self, seed):
        prog = random_program(seed)
        retained = list(loop_carried(analyze(prog)))
        assert structural_key(prog, retained) == structural_key(
            prog, list(reversed(retained))
        )


class TestSensitivity:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_dropping_a_retained_dep_changes_key(self, seed):
        prog = random_program(seed)
        retained = list(loop_carried(analyze(prog)))
        if not retained:
            pytest.skip("no loop-carried dependences in this draw")
        assert structural_key(prog, retained) != structural_key(
            prog, retained[1:]
        )

    @pytest.mark.parametrize("seed", SEEDS)
    def test_distance_edit_changes_key(self, seed):
        prog = random_program(seed)
        retained = list(loop_carried(analyze(prog)))
        if not retained:
            pytest.skip("no loop-carried dependences in this draw")
        bumped = [
            dataclasses.replace(
                d, distance=tuple(x + 1 for x in d.distance)
            )
            if i == 0
            else d
            for i, d in enumerate(retained)
        ]
        assert structural_key(prog, retained) != structural_key(prog, bumped)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_statement_graph_edit_changes_key(self, seed):
        prog = random_program(seed)
        s0 = prog.statements[0]
        edited = (
            dataclasses.replace(
                s0, reads=s0.reads + (ArrayRef("d", -1),)
            ),
        ) + prog.statements[1:]
        other = LoopProgram(statements=edited, bounds=prog.bounds)
        assert key_of(prog) != key_of(other)

    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_offset_edit_changes_key(self, seed):
        prog = random_program(seed)
        s0 = prog.statements[0]
        edited = (
            dataclasses.replace(s0, write=ArrayRef(s0.write.array, 1)),
        ) + prog.statements[1:]
        other = LoopProgram(statements=edited, bounds=prog.bounds)
        assert key_of(prog) != key_of(other)

    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_captured_constant_changes_key(self, seed):
        """Two computes from the same source but different closure values
        are behaviorally different — must not share a key."""

        prog = random_program(seed)
        other = LoopProgram(
            statements=tuple(
                dataclasses.replace(s, compute=make_compute(3.14159))
                for s in prog.statements
            ),
            bounds=prog.bounds,
        )
        assert key_of(prog) != key_of(other)

    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_model_changes_key(self, seed):
        prog = random_program(seed)
        assert key_of(prog, model="doall") != key_of(prog, model="dswp")

    def test_referenced_global_value_changes_key(self):
        """Identical bytecode reading different module globals must not
        collide (that would be wrong-code cache reuse)."""

        from repro.compile import compute_fingerprint

        f1 = eval("lambda x: x * SCALE", {"SCALE": 2.0})
        f2 = eval("lambda x: x * SCALE", {"SCALE": 3.0})
        f3 = eval("lambda x: x * SCALE", {"SCALE": 2.0})
        assert compute_fingerprint(f1) == compute_fingerprint(f3)
        assert compute_fingerprint(f1) != compute_fingerprint(f2)

    def test_kwonly_default_changes_key(self):
        from repro.compile import compute_fingerprint

        def make(s):
            return lambda a, *, scale=s: a * scale

        assert compute_fingerprint(make(2.0)) == compute_fingerprint(make(2.0))
        assert compute_fingerprint(make(2.0)) != compute_fingerprint(make(3.0))

    def test_large_captured_array_contents_change_key(self):
        """repr() truncates big arrays — the fingerprint must hash their
        full contents, or distinct lookup tables collide."""

        import numpy as np

        from repro.compile import compute_fingerprint

        t1 = np.zeros(2000)
        t2 = t1.copy()
        t2[500] = 9.0

        def make(table):
            return lambda a: a + table[0]

        assert compute_fingerprint(make(t1)) == compute_fingerprint(
            make(t1.copy())
        )
        assert compute_fingerprint(make(t1)) != compute_fingerprint(make(t2))

    def test_stateful_callable_object_state_changes_key(self):
        from repro.compile import compute_fingerprint

        class Scaler:
            def __init__(self, s):
                self.s = s

            def __call__(self, a):
                return a * self.s

        assert compute_fingerprint(Scaler(2.0)) == compute_fingerprint(
            Scaler(2.0)
        )
        assert compute_fingerprint(Scaler(2.0)) != compute_fingerprint(
            Scaler(3.0)
        )

    def test_captured_object_state_changes_key(self):
        """Default reprs embed reusable addresses — captured objects must be
        fingerprinted by (type, state), never by repr address."""

        from repro.compile import compute_fingerprint

        class Cfg:
            def __init__(self, k):
                self.k = k

        def make(cfg):
            return lambda a: a * cfg.k

        assert compute_fingerprint(make(Cfg(2))) == compute_fingerprint(
            make(Cfg(2))
        )
        assert compute_fingerprint(make(Cfg(2))) != compute_fingerprint(
            make(Cfg(3))
        )

    def test_uninspectable_captured_value_never_hits(self):
        """A captured value with no introspectable state and an
        address-bearing repr fingerprints uniquely every time — a forced
        miss beats a possible wrong-code hit (addresses get reused)."""

        from repro.compile import compute_fingerprint

        v = object()
        mk = eval("lambda v: (lambda a: a if v else a)", {})
        assert compute_fingerprint(mk(v)) != compute_fingerprint(mk(v))

    def test_module_attribute_constant_changes_key(self):
        """``config.SCALE`` (one attribute hop through a module global)
        participates by value — mutating the module constant changes the
        key instead of silently reusing the stale artifact."""

        import types as _types

        from repro.compile import compute_fingerprint

        config = _types.ModuleType("fake_config")
        config.SCALE = 2.0
        fn = eval("lambda a: a * config.SCALE", {"config": config})
        fp2 = compute_fingerprint(fn)
        assert compute_fingerprint(fn) == fp2
        config.SCALE = 3.0
        assert compute_fingerprint(fn) != fp2

    def test_module_and_class_references_are_stable(self):
        """np-style module/class references fingerprint by name — no forced
        miss, no recursion into module dicts."""

        import numpy as np

        from repro.compile import compute_fingerprint

        fn = eval("lambda a: np.float64(a)", {"np": np})
        assert compute_fingerprint(fn) == compute_fingerprint(fn)

    def test_recursive_global_reference_terminates(self):
        ns = {}
        exec("def f(x):\n    return f(x - 1) if x > 0 else x", ns)
        from repro.compile import compute_fingerprint

        assert compute_fingerprint(ns["f"])  # no RecursionError

    def test_bound_method_receiver_state_changes_key(self):
        from repro.compile import compute_fingerprint

        class Scaler:
            def __init__(self, k):
                self.k = k

            def scale(self, x):
                return x * self.k

        assert compute_fingerprint(Scaler(2).scale) == compute_fingerprint(
            Scaler(2).scale
        )
        assert compute_fingerprint(Scaler(2).scale) != compute_fingerprint(
            Scaler(3).scale
        )

    def test_partial_function_binding_changes_key(self):
        import functools

        from repro.compile import compute_fingerprint

        def apply(f, x):
            return f(x)

        double = lambda v: v * 2  # noqa: E731
        triple = lambda v: v * 3  # noqa: E731
        assert compute_fingerprint(
            functools.partial(apply, double)
        ) != compute_fingerprint(functools.partial(apply, triple))

    def test_set_element_state_changes_key(self):
        from repro.compile import compute_fingerprint

        class Tagged:
            def __init__(self, k):
                self.k = k

            def __repr__(self):
                return "Tagged"  # state-free repr: must not collide

            def __hash__(self):
                return 0

            def __eq__(self, other):
                return self is other

        mk = lambda s: eval("lambda a: a + len(s)", {"s": s})  # noqa: E731
        f2 = mk(frozenset({Tagged(2)}))
        f3 = mk(frozenset({Tagged(3)}))
        assert compute_fingerprint(f2) != compute_fingerprint(f3)

    def test_cyclic_captured_container_terminates(self):
        from repro.compile import compute_fingerprint

        d = {}
        d["self"] = d
        fn = eval("lambda a: a + (d and 1)", {"d": d})
        fp = compute_fingerprint(fn)  # no RecursionError
        assert fp == compute_fingerprint(fn)

    def test_numpy_ufunc_compute_keys_stably(self):
        """np.abs-style ufuncs must fingerprint stably (a forced miss per
        call would silently defeat the structural cache for every
        numpy-using compute fn)."""

        import numpy as np

        from repro.compile import compute_fingerprint

        f1 = eval("lambda a: np.abs(a)", {"np": np})
        f2 = eval("lambda a: np.abs(a)", {"np": np})
        assert compute_fingerprint(f1) == compute_fingerprint(f2)
        g = eval("lambda a: np.exp(a)", {"np": np})
        assert compute_fingerprint(f1) != compute_fingerprint(g)

    def test_guard_changes_key(self):
        base = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("p", 0), (ArrayRef("p", -1),)),
                Statement("S2", ArrayRef("a", 0), (ArrayRef("a", -1),)),
            ),
            bounds=((1, 6),),
        )
        guarded = LoopProgram(
            statements=(
                base.statements[0],
                dataclasses.replace(
                    base.statements[1], guard=ArrayRef("p", -1)
                ),
            ),
            bounds=base.bounds,
        )
        assert key_of(base) != key_of(guarded)

    def test_processor_map_changes_key(self):
        from repro.kernels.pipelined_matmul.schedule import (
            kloop_dependences,
            make_kloop_program,
        )

        prog = make_kloop_program(8)
        deps = kloop_dependences(2)
        k1 = structural_key(
            prog, deps, "procmap",
            {"ISSUE": "mxu", "COMPUTE": "mxu", "LOAD": "dma"},
        )
        k2 = structural_key(
            prog, deps, "procmap",
            {"ISSUE": "dma", "COMPUTE": "mxu", "LOAD": "dma"},
        )
        assert k1 != k2


class TestEndToEndNoFalseHits:
    """The cache itself honors the key: bounds-only changes share an
    artifact, compute-code changes do not (wrong-code reuse would be
    silent)."""

    def test_code_change_gets_fresh_artifact(self):
        from repro.compile import CompileCache, run_xla
        from repro.core import insert_synchronization, run_sequential

        cache = CompileCache()

        def prog_with(compute):
            return LoopProgram(
                statements=(
                    Statement(
                        "S1", ArrayRef("a", 0), (ArrayRef("a", -1),),
                        compute=compute,
                    ),
                ),
                bounds=((1, 6),),
            )

        doubler = prog_with(lambda r: r * 2.0)
        halver = prog_with(lambda r: r / 2.0)
        for prog in (doubler, halver):
            sync = insert_synchronization(prog, analyze(prog))
            init = prog.initial_store()
            r = run_xla(sync, store=init, cache=cache, compare=False)
            assert r.store == run_sequential(prog, init)
        assert cache.stats.misses == 2 and cache.stats.hits == 0
