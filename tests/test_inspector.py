"""Inspector-stage tests: exact instance graphs vs brute force, degenerate
index patterns, speculation rollback, and the full backend × deps-mode
bit-equality matrix over the non-affine corpus.

The inspector (:mod:`repro.core.inspector`) computes its graph in one
near-linear last-writer/readers sweep; the reference implementation here is
the O(n²) pairwise subscript comparison it replaces.  Two directions are
checked on every program:

  * soundness — every inspector edge is a genuine same-cell conflict pair;
  * sufficiency — a schedule layered from the inspector graph (plus the
    affine retained set) honors *every* pairwise conflict, including the
    transitively covered ones the sweep intentionally drops.

Semantics stays with the sequential oracle: each deps mode on each
registered backend must reproduce its store bit for bit.
"""

import random

import pytest

from _hypothesis_compat import HealthCheck, given, settings, st
from oracle import assert_equivalent
from programs import NONAFFINE_PROGRAMS
from repro.core import (
    ArrayRef,
    IndirectRef,
    LoopProgram,
    PlanOptions,
    Statement,
    affine_retained,
    analyze,
    clear_inspector_cache,
    execution_backends,
    gather_scatter,
    histogram,
    indexed_store,
    inspect_dependences,
    inspector_cache_stats,
    plan,
    ref_cell,
    run_sequential,
    sparse_matvec,
    speculation_violations,
)
from repro.core.wavefront import schedule_levels

MODES = (None, "inspect", "speculate")


# ---------------------------------------------------------------------- #
# Reference implementation: O(n²) pairwise subscript comparison
# ---------------------------------------------------------------------- #

def brute_force_pairs(prog, store):
    """All (earlier, later) instance pairs conflicting on an inspected
    array — the quadratic reference the inspector's sweep must agree with.
    Same conventions as the inspector: guards conservatively always read,
    same-iteration pairs omitted (program order covers them)."""

    targets = set(inspect_dependences(prog, store).arrays)
    accesses = []  # (instance, frozenset of read cells, write cell or None)
    for it in prog.iterations():
        for s in prog.statements:
            reads = list(s.reads)
            if s.guard is not None:
                reads.append(s.guard)
            rcells = frozenset(
                (r.array, ref_cell(r, it, store))
                for r in reads
                if r.array in targets
            )
            wcell = (
                (s.write.array, ref_cell(s.write, it, store))
                if s.write.array in targets
                else None
            )
            accesses.append(((s.name, it), rcells, wcell))
    pairs = set()
    for i, (u, ur, uw) in enumerate(accesses):
        for v, vr, vw in accesses[i + 1:]:
            if u[1] == v[1]:
                continue
            if (
                (uw is not None and (uw in vr or uw == vw))
                or (vw is not None and vw in ur)
            ):
                pairs.add((u, v))
    return pairs


def exact_schedule(prog, store):
    """The deps="inspect" schedule: affine retained set + instance edges."""

    p = plan(prog, PlanOptions(deps="inspect"))
    return schedule_levels(
        prog,
        list(affine_retained(p.retained)),
        instance_edges=inspect_dependences(prog, store).edges,
    )


def assert_graph_cross_checks(prog, store):
    """Soundness + sufficiency of the inspector graph vs brute force."""

    insp = inspect_dependences(prog, store)
    pairs = brute_force_pairs(prog, store)
    extra = set(insp.edges) - pairs
    assert not extra, f"inspector invented non-conflicting edges: {extra}"
    sched = exact_schedule(prog, store)
    violated = speculation_violations(prog, sorted(pairs), sched.level_of())
    assert not violated, (
        f"exact schedule breaks pairwise conflicts: {violated[:5]}"
    )


def assert_modes_bit_equal(prog, store=None, backends=None):
    init = {
        a: dict(c) for a, c in (store or prog.initial_store()).items()
    }
    oracle = run_sequential(prog, init)
    names = backends if backends is not None else tuple(execution_backends())
    for mode in MODES:
        p = plan(prog, PlanOptions(deps=mode))
        for backend in names:
            out = p.compile(backend).run(store=init)
            assert out == oracle, f"deps={mode!r} backend={backend} diverged"


# ---------------------------------------------------------------------- #
# Seeded random non-affine programs
# ---------------------------------------------------------------------- #

def random_nonaffine(seed, n_iter=6):
    """Random 1–3 statement program mixing indirect and affine accesses to
    a shared array — returns (program, store with random index contents)."""

    rng = random.Random(seed)
    index_arrays = ["i1", "i2"]
    stmts = []
    for k in range(rng.randint(1, 3)):
        if rng.random() < 0.6:
            write = IndirectRef("a", ArrayRef(rng.choice(index_arrays), 0))
        else:
            write = ArrayRef(rng.choice(["b", "c"]), 0)
        reads = []
        for _ in range(rng.randint(0, 2)):
            r = rng.random()
            if r < 0.4:
                reads.append(
                    IndirectRef("a", ArrayRef(rng.choice(index_arrays), 0))
                )
            elif r < 0.7:
                reads.append(ArrayRef("a", -rng.randint(0, 2)))
            else:
                reads.append(ArrayRef(rng.choice(["b", "c"]), -rng.randint(0, 1)))
        stmts.append(Statement(f"S{k+1}", write, tuple(reads)))
    prog = LoopProgram(statements=tuple(stmts), bounds=((0, n_iter),))
    if not prog.has_indirect():  # force at least one indirect access
        return random_nonaffine(seed + 10_000, n_iter)
    store = indexed_store(
        prog,
        {
            arr: [rng.randint(0, n_iter + 1) for _ in range(n_iter)]
            for arr in prog.index_arrays()
        },
    )
    return prog, store


# ---------------------------------------------------------------------- #
# Cross-check suites
# ---------------------------------------------------------------------- #

class TestGraphVsBruteForce:
    @pytest.mark.parametrize(
        "name,prog", NONAFFINE_PROGRAMS, ids=[n for n, _ in NONAFFINE_PROGRAMS]
    )
    def test_corpus_programs(self, name, prog):
        assert_graph_cross_checks(prog, prog.initial_store())

    @pytest.mark.parametrize("seed", range(20))
    def test_seeded_random_programs(self, seed):
        prog, store = random_nonaffine(seed)
        assert_graph_cross_checks(prog, store)
        # cheap executable check on every seed (full matrix below)
        assert_modes_bit_equal(prog, store, backends=("wavefront",))

    @pytest.mark.parametrize("seed", (0, 5, 10, 15))
    def test_seeded_random_all_backends(self, seed):
        prog, store = random_nonaffine(seed)
        assert_modes_bit_equal(prog, store)

    @given(st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=25, deadline=None)
    def test_property_random_programs(self, seed):
        prog, store = random_nonaffine(seed)
        assert_graph_cross_checks(prog, store)

    @given(st.lists(st.integers(0, 7), min_size=8, max_size=8))
    @settings(max_examples=25, deadline=None)
    def test_property_histogram_bins(self, bins):
        prog = histogram(8)
        store = indexed_store(prog, {"bin": bins})
        assert_graph_cross_checks(prog, store)
        # exact depth equals the busiest bin's multiplicity
        depth = exact_schedule(prog, store).depth
        assert depth == max(bins.count(b) for b in set(bins))


# ---------------------------------------------------------------------- #
# Degenerate index patterns
# ---------------------------------------------------------------------- #

class TestDegeneratePatterns:
    def test_all_distinct_is_pure_doall(self):
        prog = histogram(8)
        store = indexed_store(prog, {"bin": list(range(8))})
        insp = inspect_dependences(prog, store)
        assert insp.conflict_free
        assert exact_schedule(prog, store).depth == 1
        assert_modes_bit_equal(prog, store)

    def test_all_same_fully_serializes(self):
        prog = histogram(8)
        store = indexed_store(prog, {"bin": [3] * 8})
        insp = inspect_dependences(prog, store)
        assert len(insp.edges) == 7  # the covering chain i -> i+1
        assert exact_schedule(prog, store).depth == 8
        assert_modes_bit_equal(prog, store)

    def test_permutation_is_pure_doall(self):
        prog = histogram(8)
        store = indexed_store(prog, {"bin": [5, 2, 7, 0, 4, 1, 6, 3]})
        assert inspect_dependences(prog, store).conflict_free
        assert exact_schedule(prog, store).depth == 1
        assert_modes_bit_equal(prog, store)

    def test_identity_gather_scatter_keeps_program_order_only(self):
        prog = gather_scatter(8)
        store = indexed_store(
            prog, {"idx": list(range(8)), "perm": list(range(8))}
        )
        # S1 reads a[i] and S2 writes a[i] in the SAME iteration — covered
        # by intra-iteration program order, so no instance edges remain
        assert inspect_dependences(prog, store).conflict_free
        assert exact_schedule(prog, store).depth == 2
        assert_modes_bit_equal(prog, store)

    def test_sparse_matvec_depth_tracks_row_multiplicity(self):
        prog = sparse_matvec(8)
        rows = [0, 1, 0, 2, 1, 0, 3, 2]  # row 0 hit three times
        store = indexed_store(
            prog, {"row": rows, "col": list(range(8))}
        )
        assert exact_schedule(prog, store).depth == 3
        assert_modes_bit_equal(prog, store)


# ---------------------------------------------------------------------- #
# Full oracle matrix: every registered backend × every deps mode
# ---------------------------------------------------------------------- #

class TestOracleMatrix:
    @pytest.mark.parametrize(
        "name,prog", NONAFFINE_PROGRAMS, ids=[n for n, _ in NONAFFINE_PROGRAMS]
    )
    def test_all_backends_all_methods(self, name, prog):
        """The standard differential harness (plan methods × backends ×
        naive/optimized) picks the non-affine corpus up unchanged."""

        assert_equivalent(prog)

    @pytest.mark.parametrize(
        "name,prog", NONAFFINE_PROGRAMS, ids=[n for n, _ in NONAFFINE_PROGRAMS]
    )
    def test_all_backends_all_deps_modes(self, name, prog):
        assert_modes_bit_equal(prog)

    def test_nonaffine_proxies_serialize_conservatively(self):
        """deps=None keeps the Δ=1 proxy chain: the schedule must be fully
        serial even when the runtime indices are conflict-free."""

        prog = histogram(6)
        store = indexed_store(prog, {"bin": list(range(6))})
        deps = analyze(prog)
        assert any(d.nonaffine for d in deps)
        wf = plan(prog).compile("wavefront").artifacts["wavefront"]
        assert wf.depth == 6
        assert_modes_bit_equal(prog, store, backends=("wavefront",))


# ---------------------------------------------------------------------- #
# Speculation: validation failure forces rollback, result stays bit-equal
# ---------------------------------------------------------------------- #

class TestSpeculationRollback:
    def _forced_violation(self):
        prog = histogram(8)
        store = indexed_store(prog, {"bin": [4] * 8})
        return prog, store

    def test_optimistic_schedule_is_actually_violated(self):
        """The forcing condition: the doall-optimistic schedule breaks the
        inspector graph, so the rollback path (not the happy path) is what
        the bit-equality below certifies."""

        prog, store = self._forced_violation()
        ex = plan(prog, PlanOptions(deps="speculate")).compile("wavefront")
        speculative = ex.artifacts["speculative"]
        assert speculative.depth == 1  # optimistic: everything level 0
        violated = speculation_violations(
            prog,
            inspect_dependences(prog, store).edges,
            speculative.level_of(),
        )
        assert violated, "expected the all-same pattern to violate doall"

    def test_rollback_bit_equal_on_wavefront(self):
        prog, store = self._forced_violation()
        init = {a: dict(c) for a, c in store.items()}
        out = (
            plan(prog, PlanOptions(deps="speculate"))
            .compile("wavefront")
            .run(store=init)
        )
        assert out == run_sequential(prog, init)

    def test_rollback_bit_equal_on_xla(self):
        prog, store = self._forced_violation()
        init = {a: dict(c) for a, c in store.items()}
        out = (
            plan(prog, PlanOptions(deps="speculate"))
            .compile("xla")
            .run(store=init)
        )
        assert out == run_sequential(prog, init)

    def test_validation_passes_without_conflicts(self):
        prog = histogram(8)
        store = indexed_store(prog, {"bin": list(range(8))})
        ex = plan(prog, PlanOptions(deps="speculate")).compile("wavefront")
        assert not speculation_violations(
            prog,
            inspect_dependences(prog, store).edges,
            ex.artifacts["speculative"].level_of(),
        )
        init = {a: dict(c) for a, c in store.items()}
        assert ex.run(store=init) == run_sequential(prog, init)


# ---------------------------------------------------------------------- #
# Cache placement and plumbing
# ---------------------------------------------------------------------- #

class TestInspectorPlumbing:
    def test_inspector_memo_hits_and_content_sensitivity(self):
        clear_inspector_cache()
        prog = histogram(8)
        s1 = indexed_store(prog, {"bin": list(range(8))})
        s2 = indexed_store(prog, {"bin": [0] * 8})
        r1 = inspect_dependences(prog, s1)
        r1b = inspect_dependences(prog, s1)
        r2 = inspect_dependences(prog, s2)
        assert r1 is r1b  # memo hit on identical contents
        assert inspector_cache_stats()["hits"] >= 1
        assert r1.conflict_free and not r2.conflict_free

    def test_structural_key_is_content_free_but_mode_aware(self):
        """Two stores with different index contents share one structural
        artifact; the deps knob (a structural option) splits it."""

        from repro.compile.structure import structural_key

        prog = histogram(8)
        retained = tuple(plan(prog).retained)
        base = structural_key(prog, retained, "doall", None, None, None, None)
        same = structural_key(prog, retained, "doall", None, None, None, None)
        inspect_key = structural_key(
            prog, retained, "doall", None, None, None, "inspect"
        )
        assert base == same
        assert base != inspect_key

    def test_unknown_deps_mode_rejected(self):
        with pytest.raises(ValueError, match="deps mode"):
            PlanOptions(deps="optimistic")

    def test_index_array_write_rejected(self):
        with pytest.raises(ValueError, match="index"):
            LoopProgram(
                statements=(
                    Statement(
                        "S1",
                        ArrayRef("bin", 0),
                        (IndirectRef("h", ArrayRef("bin", 0)),),
                    ),
                ),
                bounds=((0, 4),),
            )

    def test_affine_program_inspects_empty(self):
        from programs import DIFFERENTIAL_PROGRAMS

        for _name, prog in DIFFERENTIAL_PROGRAMS[:3]:
            insp = inspect_dependences(prog)
            assert insp.arrays == () and insp.conflict_free
