"""repro.compile: the jitted XLA backend and its structural cache.

Differential equivalence itself rides on tests/oracle.py (which iterates
every registered backend, xla included — see test_wavefront.py); this module
covers what is *specific* to the compiled path: cache key semantics
(structural hits across bounds, misses across structure), the two cache
levels and their counters, report integration, error parity with the NumPy
backend, and the under-synchronization failure mode staying deterministic.
"""

import pytest

from oracle import assert_equivalent
from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    analyze,
    insert_synchronization,
    plan,
    paper_alg4,
    paper_alg6,
    registered_backends,
    run_sequential,
)
from repro.core.dependence import paper_alg4_dependences
from repro.compile import (
    CompileCache,
    clear_compile_cache,
    compile_cache_stats,
    run_xla,
)


def _chain_program(n: int) -> LoopProgram:
    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            Statement("S2", ArrayRef("b", 0), (ArrayRef("a", -2),)),
        ),
        bounds=((1, n),),
    )


class TestBackendRegistration:
    def test_xla_is_registered(self):
        assert "xla" in registered_backends()

    def test_parallelize_attaches_compiled_artifact(self):
        rep = plan(paper_alg6(8), method="isd").compile("xla").report()
        assert rep.compiled is not None
        assert rep.backend == "xla"
        s = rep.summary()
        assert s["compile_key"] == rep.compiled.key[:16]
        assert set(s["compile_cache"]) == {
            "hits", "misses", "table_hits", "table_misses",
        }

    def test_oracle_runs_xla_automatically(self):
        res = assert_equivalent(_chain_program(7), methods=("isd",))
        assert "xla/isd/optimized" in res


class TestStructuralCache:
    def test_bounds_change_is_structural_hit(self):
        cache = CompileCache()
        sync8 = insert_synchronization(_chain_program(8), analyze(_chain_program(8)))
        sync64 = insert_synchronization(_chain_program(64), analyze(_chain_program(64)))
        r1 = run_xla(sync8, cache=cache)
        r2 = run_xla(sync64, cache=cache)
        assert r1.cache_events == {"structural": "miss", "tables": "miss"}
        assert r2.cache_events == {"structural": "hit", "tables": "miss"}
        assert r1.compiled is r2.compiled
        assert r1.matches_sequential and r2.matches_sequential

    def test_warm_call_hits_both_levels(self):
        cache = CompileCache()
        sync = insert_synchronization(_chain_program(9), analyze(_chain_program(9)))
        run_xla(sync, cache=cache)
        r = run_xla(sync, cache=cache)
        assert r.cache_events == {"structural": "hit", "tables": "hit"}
        assert cache.stats.as_dict() == {
            "hits": 1, "misses": 1, "table_hits": 1, "table_misses": 1,
        }

    def test_different_retained_deps_miss(self):
        """naive vs optimized sync of the same loop retain different
        dependence sets — distinct artifacts, no false sharing."""

        cache = CompileCache()
        rep = plan(paper_alg6(8), method="isd").compile("threaded").report()
        r_naive = run_xla(rep.naive_sync, cache=cache)
        r_opt = run_xla(rep.optimized_sync, cache=cache)
        assert r_opt.cache_events["structural"] == "miss"
        assert r_naive.compiled is not r_opt.compiled

    def test_store_layout_participates_in_table_cache(self):
        cache = CompileCache()
        prog = _chain_program(6)
        sync = insert_synchronization(prog, analyze(prog))
        run_xla(sync, cache=cache)  # default initial_store layout
        wide = prog.initial_store(pad=12)
        r = run_xla(sync, store=wide, cache=cache)
        assert r.cache_events == {"structural": "hit", "tables": "miss"}
        assert r.matches_sequential

    def test_clear_compile_cache_resets_counters(self):
        sync = insert_synchronization(_chain_program(5), analyze(_chain_program(5)))
        run_xla(sync)
        clear_compile_cache()
        s = compile_cache_stats()
        assert s == {
            "hits": 0, "misses": 0, "table_hits": 0, "table_misses": 0,
        }

    def test_kloop_replans_are_structural_hits(self):
        from repro.kernels.pipelined_matmul.schedule import compile_kloop

        c16, _ = compile_kloop(2, 16)
        c128, hit = compile_kloop(2, 128)
        assert hit and c16 is c128
        _c, hit_depth1 = compile_kloop(1, 16)
        assert not hit_depth1  # depth changes the retained deps

    def test_serving_wave_plans_share_one_artifact(self):
        from repro.launch.serve import plan_wave_sync

        p1 = plan_wave_sync(16)
        p2 = plan_wave_sync(16)
        p3 = plan_wave_sync(64)  # bounds only — same structure
        assert p1.compiled is p2.compiled is p3.compiled


class TestExecutionSemantics:
    def test_under_synchronized_mis_executes_deterministically(self):
        """The paper's own Alg. 5 graph misses S2 δf(b,Δ=1) S1; like the
        NumPy layering, the compiled path mis-executes it deterministically."""

        sync = insert_synchronization(paper_alg4(8), paper_alg4_dependences())
        assert not run_xla(sync).matches_sequential

    def test_guarded_program_bit_equal(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("p", 0), (ArrayRef("p", -1),)),
                Statement(
                    "S2",
                    ArrayRef("a", 0),
                    (ArrayRef("a", -1),),
                    guard=ArrayRef("p", -1),
                ),
            ),
            bounds=((1, 7),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        assert run_xla(sync).matches_sequential

    def test_procmap_model_kloop(self):
        from repro.core.elimination import synchronized_set
        from repro.core.wavefront import schedule_levels
        from repro.kernels.pipelined_matmul.schedule import (
            PROCESSORS,
            kloop_dependences,
            make_kloop_program,
        )

        prog = make_kloop_program(8)
        deps = kloop_dependences(2)
        retained = synchronized_set(deps, "procmap", PROCESSORS)
        sched = schedule_levels(
            prog, retained, model="procmap", processors=PROCESSORS
        )
        sync = insert_synchronization(prog, deps)
        r = run_xla(
            sync, schedule=sched, model="procmap", processors=PROCESSORS
        )
        assert r.matches_sequential

    def test_schedule_carries_its_model(self):
        """Passing a procmap schedule alone must not re-layer it as doall
        (run_wavefront parity: the schedule is the complete hand-off)."""

        from repro.core.elimination import synchronized_set
        from repro.core.wavefront import schedule_levels
        from repro.kernels.pipelined_matmul.schedule import (
            PROCESSORS,
            kloop_dependences,
            make_kloop_program,
        )

        prog = make_kloop_program(8)
        deps = kloop_dependences(2)
        retained = synchronized_set(deps, "procmap", PROCESSORS)
        sched = schedule_levels(
            prog, retained, model="procmap", processors=PROCESSORS
        )
        sync = insert_synchronization(prog, deps)
        r = run_xla(sync, schedule=sched)  # no model/processors kwargs
        assert r.schedule.depth == sched.depth
        assert r.matches_sequential

    def test_truthiness_branching_compute_raises(self):
        """`if lane:` can't be vectorized — it must fail loudly
        (XlaLoweringError), never silently take one branch for all lanes."""

        from repro.compile import XlaLoweringError

        prog = LoopProgram(
            statements=(
                Statement(
                    "S1",
                    ArrayRef("b", 0),
                    (ArrayRef("a", -1),),
                    compute=lambda a: 1.0 if a else 2.0,
                ),
            ),
            bounds=((1, 6),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        with pytest.raises(XlaLoweringError, match="not traceable"):
            run_xla(sync, compare=False)

    def test_equality_comparison_in_compute(self):
        """``==`` inside a compute fn must compare lane *values*, not proxy
        identity (object identity would be silently False everywhere)."""

        prog = LoopProgram(
            statements=(
                Statement(
                    "S1",
                    ArrayRef("a", 0),
                    (ArrayRef("a", -1),),
                    compute=lambda x: (x == x * 1.0) * 2.0 + 1.0,
                ),
            ),
            bounds=((1, 6),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        init = prog.initial_store()
        r = run_xla(sync, store=init, compare=False)
        assert r.store == run_sequential(prog, init)

    def test_report_mirrors_wavefront_stats(self):
        rep = plan(paper_alg6(6), method="isd").compile("wavefront").report()
        r = run_xla(rep.optimized_sync, schedule=rep.wavefront)
        assert r.stats.levels == rep.wavefront.depth
        assert r.stats.instances == rep.wavefront.instances
        assert r.schedule.depth == rep.wavefront.depth


class TestErrorParity:
    """Same KeyError contract as the NumPy wavefront backend."""

    def test_out_of_store_read_raises(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -20),)),
            ),
            bounds=((0, 4),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        with pytest.raises(KeyError, match="initialized store"):
            run_xla(sync)

    def test_out_of_store_write_raises(self):
        prog = LoopProgram(
            statements=(Statement("S1", ArrayRef("a", 20), ()),),
            bounds=((0, 2),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        with pytest.raises(KeyError, match="initialized store"):
            run_xla(sync, store={"a": {(i,): 0.0 for i in range(4)}})

    def test_sparse_store_hole_read_raises(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            ),
            bounds=((1, 4),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        sparse = {
            "a": {(i,): 0.0 for i in range(0, 5)},
            "b": {(0,): 1.0, (4,): 2.0},  # holes at 1..3
        }
        with pytest.raises(KeyError, match="uninitialized"):
            run_xla(sync, store=sparse)

    def test_sparse_store_covered_accesses_work(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            ),
            bounds=((1, 4),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        store = {
            "a": {(i,): 0.0 for i in range(0, 5)},
            "b": {(i,): float(i) for i in (0, 1, 2, 4)},  # (3,) unused hole
        }
        r = run_xla(sync, store=store, compare=False)
        assert r.store == run_sequential(prog, store)

    def test_missing_array_raises(self):
        prog = _chain_program(4)
        sync = insert_synchronization(prog, analyze(prog))
        with pytest.raises(KeyError, match="missing arrays"):
            run_xla(sync, store={"a": {(i,): 0.0 for i in range(-8, 12)}})

    def test_empty_array_in_store_raises_keyerror(self):
        """An empty cells dict must produce the KeyError contract, not a
        numpy reduction ValueError (parity with run_sequential's failure
        on first access)."""

        from repro.core import run_wavefront

        prog = _chain_program(4)
        sync = insert_synchronization(prog, analyze(prog))
        store = {"a": {(i,): 0.0 for i in range(-8, 12)}, "b": {}}
        with pytest.raises(KeyError, match="no initialized cells"):
            run_xla(sync, store=store)
        with pytest.raises(KeyError, match="no initialized cells"):
            run_wavefront(sync, store=store)

    def test_structural_cache_is_bounded(self):
        from repro.compile import CompileCache

        cache = CompileCache()
        cache.MAX_ENTRIES = 4
        for k in range(9):
            prog = LoopProgram(
                statements=(
                    Statement("S1", ArrayRef("a", 0), (ArrayRef(f"b{k}", -1),)),
                ),
                bounds=((1, 5),),
            )
            sync = insert_synchronization(prog, analyze(prog))
            run_xla(sync, cache=cache, compare=False)
        assert len(cache) <= 4


class TestAnalysisMemo:
    def test_elimination_memoized_across_bounds(self):
        from repro.core import analysis_cache_stats, clear_analysis_cache

        clear_analysis_cache()
        plan(_chain_program(8), method="isd").compile("threaded").report()
        before = analysis_cache_stats()
        rep = plan(_chain_program(200), method="isd").compile("threaded").report()  # upper bound only
        after = analysis_cache_stats()
        assert after["hits"] == before["hits"] + 1
        assert rep.optimized_sync.program.bounds == ((1, 200),)


@pytest.mark.slow
class TestWarmSpeed:
    def test_warm_xla_beats_numpy_wavefront_alg6_1024(self):
        """The acceptance bar of ISSUE 2: warm-cache xla under the NumPy
        wavefront interpreter's time on Alg. 6 @ 1024 (min-of-5 each)."""

        import time

        from repro.core import run_wavefront

        rep = plan(paper_alg6(1025), method="isd").compile("xla").report()
        wrep = plan(paper_alg6(1025), method="isd").compile("wavefront").report()
        fn_xla = lambda: run_xla(rep.optimized_sync, compare=False)
        fn_np = lambda: run_wavefront(
            wrep.optimized_sync, schedule=wrep.wavefront, compare=False
        )
        fn_xla(), fn_np()  # warm both sides
        t_xla = t_np = float("inf")
        for _ in range(7):  # interleaved so load inflates both sides alike
            t0 = time.perf_counter()
            fn_xla()
            t_xla = min(t_xla, time.perf_counter() - t0)
            t0 = time.perf_counter()
            fn_np()
            t_np = min(t_np, time.perf_counter() - t0)
        assert t_xla < t_np, f"xla {t_xla*1e3:.2f}ms vs numpy {t_np*1e3:.2f}ms"
