"""Shared pytest configuration.

Registers the ``slow`` marker (belt-and-suspenders with pyproject.toml, so
a bare ``pytest tests/`` from any rootdir still knows it).  Missing
``hypothesis`` no longer errors at collection either: the property-based
modules import through ``_hypothesis_compat``, which keeps their plain
tests running and individually skips each ``@given`` test until the
``test`` extra is installed (``pip install -e ".[test]"``).
"""

from _hypothesis_compat import HAVE_HYPOTHESIS


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (full CI job only)"
    )


def pytest_report_header(config):
    if not HAVE_HYPOTHESIS:
        return (
            "hypothesis not installed — every property-based (@given) test "
            "reports as skipped; install the 'test' extra "
            "(pip install -e '.[test]') to run them"
        )
    return None


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    """Explain the skip block in CI logs: without the ``test`` extra the
    ``@given`` suites skip as a group, which otherwise reads like a
    regression in the skip count."""

    if HAVE_HYPOTHESIS:
        return
    skipped = terminalreporter.stats.get("skipped", [])
    n = sum(
        1
        for rep in skipped
        if "hypothesis not installed" in str(getattr(rep, "longrepr", ""))
    )
    if n:
        terminalreporter.write_line(
            f"note: {n} skip(s) are property-based (@given) tests awaiting "
            "the 'test' extra (pip install -e '.[test]'); they are not "
            "regressions"
        )
