"""Shared pytest configuration.

Registers the ``slow`` marker (belt-and-suspenders with pyproject.toml, so
a bare ``pytest tests/`` from any rootdir still knows it).  Missing
``hypothesis`` no longer errors at collection either: the property-based
modules import through ``_hypothesis_compat``, which keeps their plain
tests running and individually skips each ``@given`` test until the
``test`` extra is installed (``pip install -e ".[test]"``).
"""

from _hypothesis_compat import HAVE_HYPOTHESIS


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (full CI job only)"
    )


def pytest_report_header(config):
    if not HAVE_HYPOTHESIS:
        return (
            "hypothesis not installed — property-based (@given) tests "
            "will be skipped"
        )
    return None
