"""Pallas kernels (interpret mode on CPU) vs pure-jnp oracles: shape/dtype
sweeps, plus the paper-derived pipeline synchronization plans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.pipelined_matmul.ops import matmul
from repro.kernels.pipelined_matmul.ref import matmul_ref
from repro.kernels.pipelined_matmul.schedule import (
    PROCESSORS,
    min_buffers,
    plan_pipeline,
)
from repro.models.attention import attention_reference


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-5


class TestFlashAttention:
    @pytest.mark.parametrize("S,blk", [(128, 64), (256, 128), (192, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_reference(self, S, blk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, S, 4, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (2, S, 2, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (2, S, 2, 64)).astype(dtype)
        out = flash_attention(q, k, v, causal=True, blk_q=blk, blk_k=blk)
        ref = attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(
            out.astype(jnp.float32),
            ref.astype(jnp.float32),
            atol=_tol(dtype),
            rtol=_tol(dtype),
        )

    @pytest.mark.parametrize("window", [32, 100, 1000])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 256, 2, 32))
        k = jax.random.normal(ks[1], (1, 256, 2, 32))
        v = jax.random.normal(ks[2], (1, 256, 2, 32))
        out = flash_attention(q, k, v, causal=True, window=window, blk_q=64, blk_k=64)
        ref = attention_reference(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_unaligned_lengths_are_padded(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 193, 4, 32))
        k = jax.random.normal(ks[1], (1, 201, 4, 32))
        v = jax.random.normal(ks[2], (1, 201, 4, 32))
        out = flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64)
        ref = attention_reference(q, k, v, causal=False)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_kernel_ref_matches_model_oracle(self):
        """ref.py and the model-level reference implement the same contract."""

        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 64, 4, 16))
        k = jax.random.normal(ks[1], (2, 64, 4, 16))
        v = jax.random.normal(ks[2], (2, 64, 4, 16))
        a = flash_attention_ref(
            q.transpose(0, 2, 1, 3).reshape(8, 64, 16),
            k.transpose(0, 2, 1, 3).reshape(8, 64, 16),
            v.transpose(0, 2, 1, 3).reshape(8, 64, 16),
            causal=True,
        ).reshape(2, 4, 64, 16).transpose(0, 2, 1, 3)
        b = attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        sq=st.integers(16, 128),
        h=st.sampled_from([1, 2, 4]),
        kv=st.sampled_from([1, 2]),
        hd=st.sampled_from([16, 32, 64]),
    )
    def test_property_gqa_shapes(self, sq, h, kv, hd):
        if h % kv:
            kv = 1
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(ks[0], (1, sq, h, hd))
        k = jax.random.normal(ks[1], (1, sq, kv, hd))
        v = jax.random.normal(ks[2], (1, sq, kv, hd))
        out = flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32)
        assert out.shape == q.shape
        ref = attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


class TestPipelinedMatmul:
    @pytest.mark.parametrize(
        "M,K,N,blk", [(128, 128, 128, 128), (256, 512, 128, 128), (300, 257, 130, 64)]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, M, K, N, blk, dtype):
        a = jax.random.normal(jax.random.PRNGKey(0), (M, K)).astype(dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (K, N)).astype(dtype)
        out = matmul(a, b, blk_m=blk, blk_n=blk, blk_k=blk)
        ref = matmul_ref(a, b)
        np.testing.assert_allclose(
            out.astype(jnp.float32),
            ref.astype(jnp.float32),
            atol=_tol(dtype) * K**0.5,
            rtol=_tol(dtype),
        )

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(8, 200),
        k=st.integers(8, 200),
        n=st.integers(8, 130),
    )
    def test_property_shapes(self, m, k, n):
        a = jax.random.normal(jax.random.PRNGKey(2), (m, k))
        b = jax.random.normal(jax.random.PRNGKey(3), (k, n))
        out = matmul(a, b, blk_m=64, blk_n=64, blk_k=64)
        assert out.shape == (m, n)
        np.testing.assert_allclose(
            out, matmul_ref(a, b), atol=1e-4 * k**0.5, rtol=1e-4
        )


class TestPipelinePlan:
    """The paper's transitive reduction derives the double-buffering theorem."""

    def test_single_buffering_needs_credit_wait(self):
        plan = plan_pipeline(depth=1)
        assert plan.credit_wait_needed
        kinds = {d.kind for d in plan.retained}
        assert "anti" in kinds

    def test_double_buffering_covers_anti_dep(self):
        plan = plan_pipeline(depth=2)
        assert not plan.credit_wait_needed
        gone = {(d.kind, d.source, d.sink) for d in plan.eliminated}
        assert ("anti", "COMPUTE", "LOAD") in gone
        # the arrival (flow) wait must survive — it IS the semaphore
        kept = {(d.kind, d.source, d.sink) for d in plan.retained}
        assert ("flow", "LOAD", "COMPUTE") in kept

    def test_min_buffers_is_two(self):
        assert min_buffers() == 2

    def test_processors_mapping(self):
        assert PROCESSORS["ISSUE"] == PROCESSORS["COMPUTE"] != PROCESSORS["LOAD"]
