"""Property tests for strip_dependences and merged sends (seeded-random
programs; no hypothesis dependency, so this guard always runs).

Central invariants after eliminating a dependence set:

  * every surviving register still synchronizes ≥ 1 retained dependence and
    still has exactly one send, placed at the register's source statement;
  * every surviving wait corresponds to a retained dependence of its
    register (matching sink, distance and array) — no orphaned waits;
  * every retained dependence still has both halves of its pair;
  * under merging, a register's send carries the union of its dependences'
    arrays — the ``registers.get(r, (d,))`` vars path in core/sync.py.
"""

import random

import pytest

from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    analyze,
    eliminate_transitive,
    insert_synchronization,
    loop_carried,
    strip_dependences,
)

ARRAYS = ["a", "b", "c", "d"]


def random_program(seed: int) -> LoopProgram:
    rng = random.Random(seed)
    stmts = []
    for k in range(rng.randint(1, 5)):
        reads = tuple(
            ArrayRef(rng.choice(ARRAYS), -rng.randint(0, 3))
            for _ in range(rng.randint(0, 3))
        )
        stmts.append(Statement(f"S{k+1}", ArrayRef(rng.choice(ARRAYS), 0), reads))
    return LoopProgram(
        statements=tuple(stmts), bounds=((1, 1 + rng.randint(3, 7)),)
    )


def dep_key(d):
    return (d.source, d.sink, d.array, d.distance, d.kind)


SEEDS = list(range(40))


class TestStripInvariants:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_surviving_registers_have_exactly_one_send(self, seed):
        prog = random_program(seed)
        deps = analyze(prog)
        sync = insert_synchronization(prog, deps)
        res = eliminate_transitive(prog, deps)
        stripped = strip_dependences(sync, res.eliminated)

        send_count = {}
        for name, sends in stripped.post_sends.items():
            for s in sends:
                send_count[s.reg] = send_count.get(s.reg, 0) + 1
                # the send sits at the source statement of its register's deps
                assert all(
                    d.source == name for d in stripped.registers[s.reg]
                )
        for reg, ds in stripped.registers.items():
            assert ds, f"register {reg} survived with no dependences"
            assert send_count.get(reg) == 1

    @pytest.mark.parametrize("seed", SEEDS)
    def test_every_wait_has_a_retained_dependence(self, seed):
        prog = random_program(seed)
        deps = analyze(prog)
        sync = insert_synchronization(prog, deps)
        res = eliminate_transitive(prog, deps)
        stripped = strip_dependences(sync, res.eliminated)

        retained = {dep_key(d) for d in res.retained}
        for name, waits in stripped.pre_waits.items():
            for w in waits:
                matching = [
                    d
                    for d in stripped.registers[w.reg]
                    if d.sink == name
                    and d.distance == w.distance
                    and d.array in w.vars
                ]
                assert matching, f"orphaned wait {w} at {name}"
                assert all(dep_key(d) in retained for d in matching)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_every_retained_dep_keeps_its_pair(self, seed):
        prog = random_program(seed)
        deps = analyze(prog)
        sync = insert_synchronization(prog, deps)
        res = eliminate_transitive(prog, deps)
        stripped = strip_dependences(sync, res.eliminated)

        for d in res.retained:
            regs = [
                r for r, ds in stripped.registers.items()
                if dep_key(d) in {dep_key(x) for x in ds}
            ]
            assert len(regs) == 1
            (reg,) = regs
            assert any(s.reg == reg for s in stripped.post_sends[d.source])
            assert any(
                w.reg == reg and w.distance == d.distance
                for w in stripped.pre_waits[d.sink]
            )

    @pytest.mark.parametrize("seed", SEEDS)
    def test_no_eliminated_dep_survives_anywhere(self, seed):
        prog = random_program(seed)
        deps = analyze(prog)
        sync = insert_synchronization(prog, deps)
        res = eliminate_transitive(prog, deps)
        stripped = strip_dependences(sync, res.eliminated)

        gone = {dep_key(d) for d in res.eliminated}
        live = {
            dep_key(d) for ds in stripped.registers.values() for d in ds
        }
        assert not (gone & live)
        # instruction counts never grow
        assert (
            stripped.sync_instruction_count()["total"]
            <= sync.sync_instruction_count()["total"]
        )


class TestMergedSends:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_merged_send_vars_are_union_of_register_arrays(self, seed):
        """The ``registers.get(r, (d,))`` path: a merged register's single
        send must name every array its dependences synchronize."""

        prog = random_program(seed)
        deps = analyze(prog)
        merged = insert_synchronization(prog, deps, merge=True)

        for name, sends in merged.post_sends.items():
            for s in sends:
                ds = merged.registers[s.reg]
                assert set(s.vars) == {d.array for d in ds}
                assert all(d.source == name for d in ds)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_merge_one_register_per_source(self, seed):
        prog = random_program(seed)
        deps = analyze(prog)
        merged = insert_synchronization(prog, deps, merge=True)

        carried = loop_carried(deps)
        sources = {d.source for d in carried}
        assert len(merged.registers) == len(sources)
        total_sends = sum(len(v) for v in merged.post_sends.values())
        assert total_sends == len(sources)
        # waits stay per-dependence: merging never drops a wait
        unmerged = insert_synchronization(prog, deps, merge=False)
        assert (
            sum(len(v) for v in merged.pre_waits.values())
            == sum(len(v) for v in unmerged.pre_waits.values())
        )

    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_merged_optimized_sync_still_correct(self, seed):
        """End to end: merge + eliminate + strip still executes correctly
        on the wavefront backend (differential vs sequential)."""

        from repro.core import run_wavefront

        prog = random_program(seed)
        deps = analyze(prog)
        res = eliminate_transitive(prog, deps)
        merged_opt = insert_synchronization(prog, list(res.retained), merge=True)
        assert run_wavefront(merged_opt).matches_sequential
