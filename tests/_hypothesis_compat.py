"""Import shim: real hypothesis when installed, skip-stubs otherwise.

The property-based modules (test_kernels.py, test_models.py,
test_property_sync.py) import hypothesis at module scope; without this shim
a missing hypothesis kills the whole module at collection — including its
plain (non-property) tests.  With it, the plain tests always run and each
``@given`` test individually reports as skipped until the ``test`` extra is
installed (``pip install -e ".[test]"``).

The stubs only honor the call shapes those modules use: strategy builders
(``st.integers(...)``, ``st.sampled_from(...)``, ``@st.composite``),
``settings(...)`` as decorator/decorator-factory, ``HealthCheck`` attribute
access, and ``@given(...)``.
"""

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            def build(*args, **kwargs):
                return None

            return build

        @staticmethod
        def composite(fn):
            def strategy(*args, **kwargs):
                return None

            return strategy

    st = _Strategies()

    class HealthCheck:
        def __getattr__(self, name):
            return None

    HealthCheck = HealthCheck()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason=(
                "hypothesis not installed — install the 'test' extra "
                "(pip install -e '.[test]') to run property-based tests"
            )
        )

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
