"""Differential coverage for cyclic / mixed-Δ programs across every
registered backend.

Until the SCC-condensed hybrid (repro.core.scc) existed, every program here
except Alg. 4 was rejected by both fast backends with WavefrontError; now
each runs through ``tests/oracle.py`` — sequential / threaded / wavefront /
xla × naive / optimized synchronization — and must reproduce the sequential
store bit for bit.

The property section follows tests/test_strip_properties.py form: a
seeded-random generator of cyclic 2-D programs that always runs, plus a
hypothesis ``@given`` version (skipped without the ``test`` extra) drawing
random cyclic graphs and asserting the SCC-hybrid schedules store-bit-equal
to the oracle on both fast backends.
"""

import random

import pytest

from _hypothesis_compat import HealthCheck, given, settings, st
from oracle import assert_equivalent
from programs import CYCLIC_PROGRAMS, mixed_cycle_pm1, skew_recurrence
from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    analyze,
    plan,
    run_threaded,
    run_wavefront,
)

ARRAYS = ["a", "b", "c", "d"]


class TestCyclicDifferential:
    @pytest.mark.parametrize(
        "name,prog", CYCLIC_PROGRAMS, ids=[n for n, _ in CYCLIC_PROGRAMS]
    )
    def test_all_backends_bit_equal(self, name, prog):
        assert_equivalent(prog)

    def test_acceptance_example_on_both_fast_backends(self):
        """ISSUE acceptance: a cyclic Δ-sign mix executes bit-equal to the
        sequential oracle on backend="wavefront" AND backend="xla"."""

        prog = mixed_cycle_pm1()
        for backend in ("wavefront", "xla"):
            rep = plan(prog, method="isd").compile(backend).report()
            assert rep.summary()["scc"]["recurrences"], backend
            if backend == "wavefront":
                out = run_wavefront(rep.optimized_sync, schedule=rep.wavefront)
            else:
                from repro.compile import run_xla

                out = run_xla(rep.optimized_sync, schedule=rep.wavefront)
            assert out.matches_sequential, backend

    def test_chunk_limit_knob_still_bit_equal(self):
        prog = skew_recurrence(6, 9)
        rep = plan(prog, method="isd").compile("threaded").report()
        for chunk_limit in (1, 2, 3):
            out = run_wavefront(
                rep.optimized_sync,
                chunk_limit=chunk_limit,
                scc_policy="chunk",
                compare=True,
            )
            (rec,) = out.schedule.scc.recurrences
            assert rec.chunk == chunk_limit
            assert out.matches_sequential

    def test_xla_structural_cache_covers_partition_and_knob(self):
        """Same structure at different bounds is a structural hit; a
        different chunk_limit is a miss (the key covers the knob)."""

        from repro.compile import run_xla

        r1 = run_xla(_sync(skew_recurrence(5, 5)), compare=False)
        r2 = run_xla(_sync(skew_recurrence(9, 5)), compare=False)
        assert r2.cache_events["structural"] == "hit"
        r3 = run_xla(_sync(skew_recurrence(5, 5)), compare=False, chunk_limit=2)
        assert r3.cache_events["structural"] == "miss"
        assert r1.compiled is not r3.compiled


def _sync(prog):
    from repro.core import insert_synchronization

    return insert_synchronization(prog, analyze(prog))


# ---------------------------------------------------------------------- #
# Random cyclic graphs: seeded (always runs) + hypothesis (test extra)
# ---------------------------------------------------------------------- #

def random_cyclic_program(seed: int) -> LoopProgram:
    """Random 2-D loop nest biased toward mixed-sign carried dependences.

    Read offsets draw di ∈ {-1, 0} and dj ∈ [-2, 2]; the analyzer orients
    every conflicting pair into a lexicographically non-negative dependence,
    so the retained set is always valid, and di=-1 with dj≥1 produces the
    mixed-sign distances that force recurrence SCCs.
    """

    rng = random.Random(seed)
    stmts = []
    for k in range(rng.randint(1, 3)):
        reads = tuple(
            ArrayRef(
                rng.choice(ARRAYS),
                (-rng.randint(0, 1), rng.randint(-2, 2)),
            )
            for _ in range(rng.randint(1, 3))
        )
        stmts.append(
            Statement(f"S{k+1}", ArrayRef(rng.choice(ARRAYS), (0, 0)), reads)
        )
    return LoopProgram(
        statements=tuple(stmts),
        bounds=((0, rng.randint(3, 4)), (0, rng.randint(3, 5))),
    )


class TestRandomCyclic:
    @pytest.mark.parametrize("seed", range(20))
    def test_seeded_hybrid_bit_equal_fast_backends(self, seed):
        prog = random_cyclic_program(seed)
        assert_equivalent(
            prog,
            methods=("none", "isd"),
            threaded=False,
            backends=("wavefront", "xla"),
        )

    @pytest.mark.parametrize("seed", (3, 7))
    def test_seeded_threaded_included(self, seed):
        assert_equivalent(random_cyclic_program(seed), methods=("isd",))

    @given(st.integers(min_value=0, max_value=10**9))
    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_property_scc_hybrid_matches_oracle(self, seed):
        prog = random_cyclic_program(seed)
        rep = plan(prog, method="isd").compile("wavefront").report()
        out = run_wavefront(rep.optimized_sync, schedule=rep.wavefront)
        assert out.matches_sequential


@pytest.mark.slow
class TestCyclicSpeedup:
    def test_hybrid_at_least_5x_faster_than_threads(self):
        """Acceptance bar for cyclic_recurrence_1024: the chunked DOACROSS
        beats the one-thread-per-iteration machine ≥ 5× on 1024 iterations."""

        import time

        prog = skew_recurrence(64, 16)  # 1024 iterations, chunk 15
        rep = plan(prog, method="isd").compile("wavefront").report()
        assert rep.summary()["scc"]["recurrences"]
        run_wavefront(rep.optimized_sync, schedule=rep.wavefront, compare=False)
        t0 = time.perf_counter()
        run_wavefront(rep.optimized_sync, schedule=rep.wavefront, compare=False)
        t_hybrid = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_threaded(rep.optimized_sync, compare=False, timeout=180.0)
        t_threads = time.perf_counter() - t0
        assert t_threads / t_hybrid >= 5.0
