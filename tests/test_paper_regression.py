"""End-to-end regression of the paper's Alg. 5 example, locked as goldens.

Flow (paper §4): Alg. 4's loop + its stated dependence graph → Alg. 5's
send/wait program (6 sync instructions) → ISD transitive reduction
eliminates the Δ=2 b-dependence via the alternating S2/S3 witness chain
(and the Δ=1 a-dependence via the same machinery) → the optimized program
keeps a single send/wait pair.  Every number and the witness path itself is
asserted verbatim so any drift in analysis, windowing, elimination order or
stripping shows up as a diff against the paper, not as a silent behavior
change.  The optimized program is then executed on all three backends.
"""

import pytest

from oracle import assert_equivalent
from programs import PAPER_PROGRAMS
from repro.core import (
    eliminate_transitive,
    insert_synchronization,
    paper_alg4,
    paper_alg6,
    plan,
    run_threaded,
    run_wavefront,
    strip_dependences,
)
from repro.core.dependence import paper_alg4_dependences


class TestAlg5Golden:
    """The paper's own 3-dependence graph (Fig. 5): δf(a,1), δf(b,2), δf(c,1)."""

    def setup_method(self):
        self.prog = paper_alg4(8)
        self.deps = paper_alg4_dependences()
        self.naive = insert_synchronization(self.prog, self.deps)
        self.elim = eliminate_transitive(self.prog, self.deps)
        self.opt = strip_dependences(self.naive, self.elim.eliminated)

    def test_naive_sync_count(self):
        assert self.naive.sync_instruction_count() == {
            "sends": 3,
            "waits": 3,
            "total": 6,
        }

    def test_delta2_eliminated_with_isd_witness(self):
        gone = [d.pretty() for d in self.elim.eliminated]
        assert gone == ["S2 δf(b, Δ=2) S3", "S1 δf(a, Δ=1) S3"]
        assert [d.pretty() for d in self.elim.retained] == [
            "S3 δf(c, Δ=1) S2"
        ]
        # the Δ=2 witness is the alternating S2/S3 chain riding the retained
        # c-dependence (S3 δf(c,Δ=1) S2) plus intra-iteration program order
        delta2 = next(d for d in self.elim.eliminated if d.distance == (2,))
        assert self.elim.witnesses[delta2] == (
            ("S2", (1,)),
            ("S3", (1,)),
            ("S2", (2,)),
            ("S3", (2,)),
            ("S2", (3,)),
            ("S3", (3,)),
        )

    def test_optimized_sync_count(self):
        assert self.opt.sync_instruction_count() == {
            "sends": 1,
            "waits": 1,
            "total": 2,
        }
        # runtime ops over the 7 iterations: 42 → 14
        assert self.naive.runtime_sync_ops() == 42
        assert self.opt.runtime_sync_ops() == 14

    def test_optimized_still_correct_when_graph_is_complete(self):
        """The paper's graph itself is under-synchronized (missing
        S2 δf(b,Δ=1) S1 — see test_executor.py), so correctness is asserted
        on the *complete* graph's optimized program instead."""

        rep = plan(self.prog, method="isd").compile("wavefront").report()
        assert rep.naive_sync.sync_instruction_count()["total"] == 8
        assert rep.optimized_sync.sync_instruction_count()["total"] == 4
        assert [d.pretty() for d in rep.elimination.eliminated] == [
            "S2 δf(b, Δ=2) S3",
            "S1 δf(a, Δ=1) S3",
        ]
        assert [d.pretty() for d in rep.elimination.retained] == [
            "S2 δf(b, Δ=1) S1",
            "S3 δf(c, Δ=1) S2",
        ]
        assert run_threaded(rep.optimized_sync).matches_sequential
        assert run_wavefront(
            rep.optimized_sync, schedule=rep.wavefront
        ).matches_sequential


class TestAlg6Golden:
    """Fig. 6: the synchronization-elimination example, same lock-down."""

    def test_end_to_end_counts_and_witness(self):
        rep = plan(paper_alg6(8), method="isd").compile("wavefront").report()
        assert rep.naive_sync.sync_instruction_count()["total"] == 4
        assert rep.optimized_sync.sync_instruction_count()["total"] == 2
        assert rep.naive_sync.runtime_sync_ops() == 28
        assert rep.optimized_sync.runtime_sync_ops() == 14
        assert [d.pretty() for d in rep.elimination.eliminated] == [
            "S1 δf(a, Δ=2) S3"
        ]
        (path,) = rep.elimination.witnesses.values()
        assert path == (
            ("S1", (1,)),
            ("S2", (1,)),
            ("S3", (1,)),
            ("S2", (2,)),
            ("S3", (2,)),
            ("S2", (3,)),
            ("S3", (3,)),
        )
        # wavefront lowering of the optimized program: S1 fully batched at
        # level 0, the retained c-chain sequential → depth 2·7 + 1
        assert rep.wavefront.depth == 15
        lvl = rep.wavefront.level_of()
        assert all(lvl[("S1", (i,))] == 0 for i in range(1, 8))

    @pytest.mark.parametrize(
        "name,prog", PAPER_PROGRAMS, ids=[n for n, _ in PAPER_PROGRAMS]
    )
    def test_differential_equivalence(self, name, prog):
        assert_equivalent(prog)
