"""SCC / topological sort / loop fission — paper §3 (Alg. 1 → Alg. 2 → Alg. 3)."""

import pytest

from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    analyze,
    fission,
    paper_alg1,
    paper_alg4,
    run_sequential,
)
from repro.core.dependence import paper_alg4_dependences
from repro.core.executor import run_loops_sequence
from repro.core.graph import (
    CondensedGraph,
    DepGraph,
    condense,
    pipeline_stages,
    tarjan_scc,
    topological_order,
)


class TestSCC:
    def test_alg4_paper_graph_scc(self):
        """With the paper's 3-dep graph, {S2,S3} form the SCC (cycle via
        b/c), S1 stays alone (§3.2)."""

        prog = paper_alg4()
        graph = DepGraph.build(prog, paper_alg4_dependences())
        cond = condense(graph)
        comps = {n.statements for n in cond.nodes}
        assert frozenset({"S2", "S3"}) in comps
        assert frozenset({"S1"}) in comps

    def test_alg4_full_graph_is_one_scc(self):
        """With the missed S2→S1 dep included, the cycle closes through S1."""

        prog = paper_alg4()
        cond = condense(DepGraph.build(prog, analyze(prog)))
        assert {n.statements for n in cond.nodes} == {
            frozenset({"S1", "S2", "S3"})
        }

    def test_tarjan_on_dag(self):
        adj = {"a": ["b"], "b": ["c"], "c": []}
        sccs = tarjan_scc(["a", "b", "c"], adj)
        assert all(len(s) == 1 for s in sccs)

    def test_tarjan_two_cycles(self):
        adj = {"a": ["b"], "b": ["a", "c"], "c": ["d"], "d": ["c"]}
        sccs = {frozenset(s) for s in tarjan_scc(list("abcd"), adj)}
        assert sccs == {frozenset("ab"), frozenset("cd")}

    def test_self_cycle_not_parallel(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("a", -1),)),
            ),
            bounds=((1, 5),),
        )
        cond = condense(DepGraph.build(prog, analyze(prog)))
        assert not cond.nodes[0].is_parallel


class TestTopoAndFission:
    def test_alg2_topological_order(self):
        """The paper's valid order for Alg. 1 is S2, S1, S4, S3 (Fig. 3b)."""

        prog = paper_alg1()
        cond = condense(DepGraph.build(prog, analyze(prog)))
        order = topological_order(cond, prog)
        labels = [sorted(cond.nodes[k].statements) for k in order]
        assert labels == [["S2"], ["S1"], ["S4"], ["S3"]]

    def test_alg3_fission_groups_s1_s4(self):
        res = fission(paper_alg1())
        assert res.loop_names() == [("S2",), ("S1", "S4"), ("S3",)]
        assert all(l.parallel for l in res.loops)

    def test_alg2_fission_without_regroup(self):
        res = fission(paper_alg1(), regroup=False)
        assert res.loop_names() == [("S2",), ("S1",), ("S4",), ("S3",)]

    def test_fission_preserves_semantics(self):
        prog = paper_alg1(10)
        res = fission(prog)
        expect = run_sequential(prog)
        got = run_loops_sequence(res.loops, prog)
        assert got == expect

    def test_fission_parallel_loops_safe_under_reversal(self):
        """run_loops_sequence executes parallel loops in *reversed* iteration
        order — only legal because fission removed loop-carried deps."""

        prog = paper_alg1(12)
        res = fission(prog, regroup=True)
        assert run_loops_sequence(res.loops, prog) == run_sequential(prog)

    def test_regroup_requires_shared_reads(self):
        # S1 reads b, S4 reads e (disjoint) → no locality grouping
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
                Statement("S2", ArrayRef("b", 0), (ArrayRef("c", -1),)),
                Statement(
                    "S3",
                    ArrayRef("t", 0),
                    (ArrayRef("a", -1), ArrayRef("b", 0), ArrayRef("d", -2)),
                ),
                Statement("S4", ArrayRef("d", 0), (ArrayRef("e", -2),)),
            ),
            bounds=((1, 8),),
        )
        res = fission(prog)
        assert ("S1", "S4") not in res.loop_names()


class TestPipelineStages:
    def test_dswp_stage_assignment(self):
        """Fig. 4: the SCC is pipelined across threads in topological order."""

        prog = paper_alg4()
        cond = condense(DepGraph.build(prog, paper_alg4_dependences()))
        stages = pipeline_stages(cond, prog, num_threads=2)
        assert len(stages) == 2
        flat = [s for stage in stages for k in stage for s in cond.nodes[k].statements]
        assert set(flat) == {"S1", "S2", "S3"}

    def test_stage_order_respects_topology(self):
        prog = paper_alg1()
        cond = condense(DepGraph.build(prog, analyze(prog)))
        stages = pipeline_stages(cond, prog, num_threads=4)
        seen = []
        for st in stages:
            for k in st:
                seen.append(k)
        assert seen == topological_order(cond, prog)
