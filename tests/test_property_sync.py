"""Hypothesis property tests for the system's central invariant:

    *Any* loop program, synchronized after elimination, still produces
    sequential semantics on real threads — i.e. the eliminations of §4.2
    never remove a needed synchronization.

Programs are drawn with random statement counts, array access offsets and
loop bounds; the adversarial scheduler injects stalls derived from the same
draw so thread interleavings vary deterministically per example.
"""

import pytest
from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    analyze,
    eliminate_transitive,
    fission,
    insert_synchronization,
    plan,
    run_sequential,
    run_threaded,
)
from repro.core.executor import run_loops_sequence

ARRAYS = ["a", "b", "c", "d"]


@st.composite
def loop_programs(draw):
    n_stmt = draw(st.integers(min_value=1, max_value=4))
    n_iter = draw(st.integers(min_value=3, max_value=6))
    stmts = []
    for k in range(n_stmt):
        warr = draw(st.sampled_from(ARRAYS))
        n_reads = draw(st.integers(min_value=0, max_value=3))
        reads = tuple(
            ArrayRef(
                draw(st.sampled_from(ARRAYS)),
                draw(st.integers(min_value=-3, max_value=0)),
            )
            for _ in range(n_reads)
        )
        stmts.append(Statement(f"S{k+1}", ArrayRef(warr, 0), reads))
    return LoopProgram(statements=tuple(stmts), bounds=((1, 1 + n_iter),))


@st.composite
def programs_with_stalls(draw):
    prog = draw(loop_programs())
    stalls = {}
    n_stalls = draw(st.integers(min_value=0, max_value=2))
    for _ in range(n_stalls):
        stmt = draw(st.sampled_from([s.name for s in prog.statements]))
        it = draw(
            st.integers(min_value=prog.bounds[0][0], max_value=prog.bounds[0][1] - 1)
        )
        stalls[(stmt, (it,))] = 0.02
    return prog, stalls


common = settings(
    max_examples=30,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


class TestSyncSoundness:
    @common
    @given(programs_with_stalls())
    def test_naive_sync_preserves_semantics(self, case):
        prog, stalls = case
        sync = insert_synchronization(prog, analyze(prog))
        assert run_threaded(sync, stalls=stalls).matches_sequential

    @common
    @given(programs_with_stalls())
    def test_isd_optimized_sync_preserves_semantics(self, case):
        prog, stalls = case
        rep = plan(prog, method="isd").compile("threaded").report()
        assert run_threaded(rep.optimized_sync, stalls=stalls).matches_sequential

    @common
    @given(programs_with_stalls())
    def test_pattern_optimized_sync_preserves_semantics(self, case):
        prog, stalls = case
        rep = plan(prog, method="pattern").compile("threaded").report()
        assert run_threaded(rep.optimized_sync, stalls=stalls).matches_sequential

    @common
    @given(programs_with_stalls())
    def test_combined_methods_preserve_semantics(self, case):
        prog, stalls = case
        rep = plan(prog, method="both").compile("threaded").report()
        assert run_threaded(rep.optimized_sync, stalls=stalls).matches_sequential


class TestEliminationInvariants:
    @common
    @given(loop_programs())
    def test_elimination_is_monotone(self, prog):
        """retained ∪ eliminated = loop-carried deps; no dep in both."""

        deps = analyze(prog)
        res = eliminate_transitive(prog, deps)
        ret = {(d.source, d.sink, d.array, d.distance, d.kind) for d in res.retained}
        elim = {(d.source, d.sink, d.array, d.distance, d.kind) for d in res.eliminated}
        assert not (ret & elim)
        carried = {
            (d.source, d.sink, d.array, d.distance, d.kind)
            for d in deps
            if d.loop_carried
        }
        assert ret | elim == carried

    @common
    @given(loop_programs())
    def test_witness_paths_are_valid(self, prog):
        """Every witness path starts at the eliminated dep's source instance,
        ends at its sink instance, and never uses the eliminated dep."""

        deps = analyze(prog)
        res = eliminate_transitive(prog, deps)
        for dep, path in res.witnesses.items():
            if not path:
                continue
            (s0, i0), (sn, iN) = path[0], path[-1]
            assert s0 == dep.source and sn == dep.sink
            assert tuple(a - b for a, b in zip(iN, i0)) == dep.distance

    @common
    @given(loop_programs())
    def test_fission_preserves_semantics(self, prog):
        res = fission(prog)
        assert run_loops_sequence(res.loops, prog) == run_sequential(prog)


class TestDSWPProperties:
    """The same soundness invariant under the pipelined execution model:
    one thread per statement, cross-statement deps synchronized."""

    @common
    @given(programs_with_stalls())
    def test_dswp_naive_sync_preserves_semantics(self, case):
        prog, stalls = case
        from repro.core import analyze, insert_synchronization, run_threaded

        sync = insert_synchronization(prog, analyze(prog), model="dswp")
        rep = run_threaded(sync, stalls=stalls, model="dswp")
        assert rep.matches_sequential

    @common
    @given(programs_with_stalls())
    def test_dswp_optimized_sync_preserves_semantics(self, case):
        prog, stalls = case
        from repro.core import (
            analyze,
            eliminate_transitive,
            insert_synchronization,
            run_threaded,
            strip_dependences,
        )

        deps = analyze(prog)
        naive = insert_synchronization(prog, deps, model="dswp")
        elim = eliminate_transitive(prog, deps, model="dswp")
        opt = strip_dependences(naive, elim.eliminated)
        rep = run_threaded(opt, stalls=stalls, model="dswp")
        assert rep.matches_sequential


class TestMultiDimElimination:
    def test_2d_nest_transitive_reduction(self):
        """2-D iteration space: a (1,1)-distance dep covered by (1,0) and
        (0,1) deps via the doall program order."""

        from repro.core import (
            ArrayRef,
            LoopProgram,
            Statement,
            analyze,
            eliminate_transitive,
        )

        prog = LoopProgram(
            statements=(
                Statement(
                    "S1",
                    ArrayRef("a", (0, 0)),
                    (ArrayRef("a", (-1, 0)), ArrayRef("a", (0, -1))),
                ),
                Statement(
                    "S2",
                    ArrayRef("c", (0, 0)),
                    (ArrayRef("a", (-1, -1)),),
                ),
            ),
            bounds=((0, 4), (0, 4)),
        )
        deps = analyze(prog)
        res = eliminate_transitive(prog, deps)
        gone = {(d.source, d.sink, d.distance) for d in res.eliminated}
        # S1→S2 (1,1) covered by the S1 self-dep chain (1,0)+(0,1) plus
        # program order S1(i+1,j+1)→S2(i+1,j+1)
        assert ("S1", "S2", (1, 1)) in gone
        retained = {(d.source, d.sink, d.distance) for d in res.retained}
        assert ("S1", "S1", (1, 0)) in retained
        assert ("S1", "S1", (0, 1)) in retained

    def test_2d_semantics_preserved(self):
        from repro.core import (
            ArrayRef,
            LoopProgram,
            Statement,
            plan,
            run_threaded,
        )

        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("b", (-1, 0)),)),
                Statement("S2", ArrayRef("b", (0, 0)), (ArrayRef("a", (0, -1)),)),
            ),
            bounds=((0, 3), (0, 3)),
        )
        rep = plan(prog, method="isd").compile("threaded").report()
        run = run_threaded(rep.optimized_sync, stalls={("S2", (0, 1)): 0.05})
        assert run.matches_sequential
