"""Launch layer: sharding rules (divisibility across every cell), HLO
collective parser, analytic cost model, mesh helpers, input specs.

Everything here is device-free (fake meshes / synthetic HLO), so it runs in
milliseconds and still pins down the invariants the 512-device dry-run
depends on.
"""

import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHITECTURES, SHAPES, cell_is_applicable, get_config
from repro.launch import analytic, hlo_analysis
from repro.launch.sharding import (
    batch_pspecs,
    cache_pspecs,
    fsdp_pspecs,
    param_spec,
    params_pspecs,
)
from repro.models import model_zoo as zoo


class FakeMesh:
    """Shape-only stand-in for a jax Mesh (sharding rules never touch
    devices)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH1 = FakeMesh(data=16, model=16)
MESH2 = FakeMesh(pod=2, data=16, model=16)


def _axis_size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return n


def _assert_divisible(tree_specs, tree_shapes, mesh, where):
    def walk(path, spec, leaf):
        dims = list(leaf.shape)
        entries = tuple(spec) + (None,) * (len(dims) - len(tuple(spec)))
        for dim, ax in zip(dims, entries):
            size = _axis_size(mesh, ax)
            assert dim % size == 0, (
                f"{where}: {jax.tree_util.keystr(path)} shape {leaf.shape} "
                f"spec {spec} — {dim} % {size}"
            )

    jax.tree_util.tree_map_with_path(
        walk, tree_specs, tree_shapes, is_leaf=lambda x: isinstance(x, P)
    )


@pytest.mark.parametrize("arch", ARCHITECTURES)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["pod", "multipod"])
class TestShardingDivisibility:
    def test_params_divisible(self, arch, mesh):
        cfg = get_config(arch)
        shapes = zoo.abstract_params(cfg)
        specs = params_pspecs(cfg, mesh, shapes)
        _assert_divisible(specs, shapes, mesh, f"{arch} params")

    def test_fsdp_divisible(self, arch, mesh):
        cfg = get_config(arch)
        shapes = zoo.abstract_params(cfg)
        specs = fsdp_pspecs(cfg, mesh, shapes)
        _assert_divisible(specs, shapes, mesh, f"{arch} fsdp")

    def test_fsdp_never_shards_stack_dim(self, arch, mesh):
        cfg = get_config(arch)
        shapes = zoo.abstract_params(cfg)
        specs = fsdp_pspecs(cfg, mesh, shapes)

        def walk(path, spec, leaf):
            names = tuple(
                p.key if hasattr(p, "key") else str(p) for p in path
            )
            if any(n in ("blocks", "enc_blocks", "dec_blocks") for n in names):
                if len(tuple(spec)):
                    assert tuple(spec)[0] is None

        jax.tree_util.tree_map_with_path(
            walk, specs, shapes, is_leaf=lambda x: isinstance(x, P)
        )

    def test_caches_divisible(self, arch, mesh):
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape.kind == "train":
                continue
            ok, _ = cell_is_applicable(cfg, shape)
            if not ok:
                continue
            cache = zoo.abstract_cache(cfg, shape.global_batch, shape.seq_len)
            specs = cache_pspecs(cfg, mesh, cache)
            _assert_divisible(specs, cache, mesh, f"{arch}/{shape.name} cache")


class TestParamSpecRules:
    def test_vocab_sharded_after_padding(self):
        cfg = get_config("granite_3_2b")  # vocab 49155 → padded 49664
        spec = param_spec(("embed", "tok"), (cfg.padded_vocab_size, 2048), cfg, MESH1)
        assert spec[0] == "model"

    def test_padded_heads_shard(self):
        cfg = get_config("llava_next_34b")  # 56 → 64 heads
        assert cfg.padded_num_heads == 64
        spec = param_spec(
            ("blocks", "pos0", "attn", "wq"), (60, 7168, 64, 128), cfg, MESH1
        )
        assert spec == P(None, None, "model", None)

    def test_small_kv_heads_replicated(self):
        cfg = get_config("yi_6b")  # kv=4 < 16
        spec = param_spec(
            ("blocks", "pos0", "attn", "wk"), (32, 4096, 4, 128), cfg, MESH1
        )
        assert spec == P(None, None, None, None)

    def test_norms_replicated(self):
        cfg = get_config("yi_6b")
        spec = param_spec(
            ("blocks", "pos0", "norm1", "scale"), (32, 4096), cfg, MESH1
        )
        assert spec == P(None, None)


class TestCacheSpecRules:
    def test_seq_takes_model_when_kv_small(self):
        cfg = get_config("internlm2_20b")  # kv=8
        cache = zoo.abstract_cache(cfg, 128, 32768)
        specs = cache_pspecs(cfg, MESH1, cache)
        k_spec = specs["blocks"]["pos0"]["k"]
        assert k_spec == P(None, "data", "model", None, None)

    def test_batch1_seq_takes_all_axes(self):
        cfg = get_config("jamba_v01_52b")
        cache = zoo.abstract_cache(cfg, 1, 524288)
        specs = cache_pspecs(cfg, MESH1, cache)
        k_spec = specs["blocks"]["pos4"]["k"]  # the attention position
        assert k_spec[2] == ("data", "model")

    def test_quantized_cache_specs(self):
        cfg = get_config("deepseek_moe_16b").scaled(kv_quant=True)
        cache = zoo.abstract_cache(cfg, 128, 32768)
        specs = cache_pspecs(cfg, MESH1, cache)
        assert specs["blocks"]["pos0"]["k_q"][1] == "data"
        assert specs["blocks"]["pos0"]["k_s"][1] == "data"


class TestHLOParser:
    HLO = """
  %ar = f32[16,4096]{1,0} all-reduce(f32[16,4096]{1,0} %x), replica_groups={}
  %ag = bf16[256,128]{1,0} all-gather(bf16[16,128]{1,0} %y), dimensions={0}
  %rs = bf16[16,128]{1,0} reduce-scatter(bf16[256,128]{1,0} %z), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %w), channel_id=1
  %dot = f32[4,4]{1,0} dot(f32[4,4]{1,0} %a, f32[4,4]{1,0} %b)
"""

    def test_counts(self):
        stats = hlo_analysis.parse_collectives(self.HLO)
        assert stats.counts == {
            "all-reduce": 1,
            "all-gather": 1,
            "reduce-scatter": 1,
            "collective-permute": 1,
        }

    def test_traffic_heuristics(self):
        stats = hlo_analysis.parse_collectives(self.HLO)
        assert stats.bytes_by_kind["all-reduce"] == 2 * 16 * 4096 * 4
        assert stats.bytes_by_kind["all-gather"] == 256 * 128 * 2
        assert stats.bytes_by_kind["reduce-scatter"] == 256 * 128 * 2
        assert stats.bytes_by_kind["collective-permute"] == 8 * 8 * 2

    def test_f32_adjustment(self):
        stats = hlo_analysis.parse_collectives(self.HLO)
        ar = 2 * 16 * 4096 * 4
        assert stats.f32_bytes == ar
        assert stats.tpu_adjusted_bytes == stats.total_bytes - ar // 2

    def test_roofline_terms(self):
        t = hlo_analysis.roofline(
            flops_per_chip=197e12,
            bytes_per_chip=819e9,
            collective_bytes_per_chip=50e9,
            model_flops=197e12 * 256,
            chips=256,
        )
        assert abs(t.compute_s - 1.0) < 1e-9
        assert abs(t.memory_s - 1.0) < 1e-9
        assert abs(t.collective_s - 1.0) < 1e-9
        assert t.mfu == pytest.approx(1.0)


class TestAnalyticModel:
    def test_dense_train_flops_match_6nd(self):
        from repro.configs.base import shape_by_name

        cfg = get_config("yi_6b")
        shape = shape_by_name("train_4k")
        n = 6_000_000_000
        flops = analytic.step_flops(cfg, shape, n)
        # (3 + remat) × 2·N·D plus attention — within 2× of 8·N·D
        base = 8 * n * shape.global_batch * shape.seq_len
        assert base < flops < 2 * base

    def test_decode_linear_in_cache(self):
        from repro.configs.base import shape_by_name

        cfg = get_config("yi_6b")
        s1 = analytic.forward_flops(cfg, shape_by_name("decode_32k"), 10**9)
        # attention part scales with S; linear part with B — just sanity
        assert s1 > 0

    def test_kv_quant_halves_cache_bytes(self):
        from repro.configs.base import shape_by_name

        cfg = get_config("yi_6b")
        shape = shape_by_name("decode_32k")
        full = analytic._cache_bytes_total(cfg, shape)
        quant = analytic._cache_bytes_total(cfg.scaled(kv_quant=True), shape)
        assert quant < 0.55 * full

    def test_window_caps_attention(self):
        from repro.configs.base import shape_by_name

        gem = get_config("gemma3_27b")   # 5:1 local, window 1024
        shape = shape_by_name("prefill_32k")
        f_local = analytic._attn_layer_flops_fwd(gem, 32768, 32768, True, 1024)
        f_full = analytic._attn_layer_flops_fwd(gem, 32768, 32768, True, None)
        assert f_local < 0.1 * f_full


class TestMeshHelpers:
    def test_data_axes(self):
        from repro.launch.mesh import data_axes

        assert data_axes(MESH1) == ("data",)
        assert data_axes(MESH2) == ("pod", "data")
