"""Serving-path optimizations: int8 KV cache (scale-folded attention),
grouped-GQA decode, and the MoE expert-sharding rule."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as attn_lib
from repro.models import model_zoo as zoo


class TestQuantizedKV:
    def test_quantize_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
        q, s = attn_lib.quantize_kv(x)
        back = attn_lib.dequantize_kv(q, s, x.dtype)
        np.testing.assert_allclose(back, x, atol=float(jnp.max(jnp.abs(x))) / 100)

    def test_scale_folding_equals_dequantize(self):
        """decode_attention_q == decode_attention on the dequantized cache."""

        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        B, S, KV, G, hd = 2, 12, 2, 3, 16
        H = KV * G
        q = jax.random.normal(ks[0], (B, 1, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        kq, ksc = attn_lib.quantize_kv(k)
        vq, vsc = attn_lib.quantize_kv(v)
        cache = {"k_q": kq, "k_s": ksc, "v_q": vq, "v_s": vsc}
        out_q = attn_lib.decode_attention_q(q, cache, jnp.int32(S))
        kd = attn_lib.dequantize_kv(kq, ksc, q.dtype)
        vd = attn_lib.dequantize_kv(vq, vsc, q.dtype)
        out_d = attn_lib.decode_attention(q, kd, vd, jnp.int32(S))
        np.testing.assert_allclose(out_q, out_d, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("arch", ["yi_6b", "gemma3_27b", "mixtral_8x7b"])
    def test_end_to_end_decode_close_to_fullprec(self, arch):
        cfg = get_smoke_config(arch).scaled(dtype="float32", kv_quant=True)
        if cfg.has_moe:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0)
            )
        params = zoo.init(jax.random.PRNGKey(0), cfg)
        B, S, Smax = 2, 10, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        full, _ = zoo.forward_logits(params, {"tokens": toks}, cfg)
        cache = zoo.init_cache(cfg, B, Smax)
        _, cache = zoo.prefill(params, {"tokens": toks[:, :6]}, cfg, cache)
        cl = 6
        for t in range(6, S):
            lg, cache = zoo.decode_step(
                params, toks[:, t : t + 1], cfg, cache, jnp.int32(cl)
            )
            cl += 1
            # int8 rounding: within ~1% of the logit scale
            scale = float(jnp.max(jnp.abs(full[:, t])))
            lim = max(0.05, 0.01 * min(scale, 100.0))
            assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))) < lim

    def test_cache_is_half_size(self):
        cfg = get_smoke_config("yi_6b")
        full = zoo.init_cache(cfg, 2, 64)
        cfgq = cfg.scaled(kv_quant=True)
        quant = zoo.init_cache(cfgq, 2, 64)
        b_full = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(full))
        b_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(quant))
        assert b_q < 0.63 * b_full  # int8 + f32/head_dim scales


class TestGroupedGQADecode:
    @pytest.mark.parametrize("KV,G", [(1, 4), (2, 2), (4, 1)])
    def test_matches_reference_row(self, KV, G):
        H, hd, S = KV * G, 16, 12
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q_all = jax.random.normal(ks[0], (2, S, H, hd))
        k_all = jax.random.normal(ks[1], (2, S, KV, hd))
        v_all = jax.random.normal(ks[2], (2, S, KV, hd))
        ref = attn_lib.attention_reference(q_all, k_all, v_all, causal=True)
        out = attn_lib.decode_attention(
            q_all[:, -1:], k_all, v_all, jnp.int32(S)
        )
        np.testing.assert_allclose(out[:, 0], ref[:, -1], atol=2e-5, rtol=2e-5)

    def test_window_masking(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        S, W = 16, 5
        q = jax.random.normal(ks[0], (1, S, 4, 8))
        k = jax.random.normal(ks[1], (1, S, 2, 8))
        v = jax.random.normal(ks[2], (1, S, 2, 8))
        ref = attn_lib.attention_reference(q, k, v, causal=True, window=W)
        out = attn_lib.decode_attention(q[:, -1:], k, v, jnp.int32(S), window=W)
        np.testing.assert_allclose(out[:, 0], ref[:, -1], atol=2e-5, rtol=2e-5)


class TestMoEShardRule:
    def test_auto_prefers_ep_when_divisible(self):
        import numpy as np

        from repro.configs import get_config
        from repro.launch.sharding import param_spec

        cfg = get_config("deepseek_moe_16b")  # 64 experts, divisible by 16
        mesh = None

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        spec = param_spec(
            ("blocks", "pos0", "moe", "w_gate"), (28, 64, 2048, 1408), cfg, FakeMesh()
        )
        assert spec[1] == "model"  # experts dim sharded (EP)

    def test_auto_falls_back_to_tp(self):
        from repro.configs import get_config
        from repro.launch.sharding import param_spec

        cfg = get_config("mixtral_8x7b")  # 8 experts, not divisible by 16

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        spec = param_spec(
            ("blocks", "pos0", "moe", "w_gate"), (32, 8, 4096, 14336), cfg, FakeMesh()
        )
        assert spec[1] is None and spec[3] == "model"  # ff sharded (TP)
