"""Pipeline-schedule lift of the sync optimizer (core/schedule.py)."""

import pytest

from repro.core import (
    StageGraph,
    analyze,
    insert_synchronization,
    plan_pipeline_sync,
    run_threaded,
)
from repro.core.schedule import build_pipeline_program, events_by_kind, stage_of


class TestChainPipeline:
    def test_plain_chain_keeps_all_handoffs(self):
        plan = plan_pipeline_sync(StageGraph(num_stages=4, num_microbatches=3))
        assert len(plan.events) == 3  # F0→F1, F1→F2, F2→F3
        assert len(plan.elimination.eliminated) == 0

    def test_chain_events_are_neighbor_hops(self):
        plan = plan_pipeline_sync(StageGraph(num_stages=5, num_microbatches=2))
        for e in plan.events:
            assert stage_of(e.dst_stmt) - stage_of(e.src_stmt) == 1
            assert e.distance == 0


class TestSkipElimination:
    def test_skip_dependences_eliminated(self):
        """Encoder-output fan-out (whisper-style): stage 0 feeds stages 2..5;
        the chain hand-offs transitively cover every skip."""

        S = 6
        skips = tuple((0, d) for d in range(2, S))
        plan = plan_pipeline_sync(
            StageGraph(num_stages=S, num_microbatches=3, skips=skips)
        )
        assert len(plan.elimination.eliminated) == len(skips)
        assert len(plan.events) == S - 1  # only the chain remains

    def test_sync_reduction_grows_with_fanout(self):
        for S in (4, 8, 12):
            skips = tuple((0, d) for d in range(2, S))
            plan = plan_pipeline_sync(
                StageGraph(num_stages=S, num_microbatches=2, skips=skips)
            )
            s = plan.summary()
            assert (
                s["synchronized_deps_naive"] - s["synchronized_deps_optimized"]
                == S - 2
            )

    def test_cross_stage_residual(self):
        plan = plan_pipeline_sync(
            StageGraph(num_stages=4, num_microbatches=2, skips=((1, 3),))
        )
        gone = {(d.source, d.sink) for d in plan.elimination.eliminated}
        assert ("F1", "F3") in gone


class TestBackwardAndAccumulation:
    def test_grad_accumulation_chain_is_free(self):
        """The gacc self-chain is per-stage (same processor) — no sync."""

        plan = plan_pipeline_sync(
            StageGraph(
                num_stages=3,
                num_microbatches=4,
                with_backward=True,
                grad_accumulation=True,
            )
        )
        for e in plan.events:
            # accumulation statements only ever sync locally (same stage)
            if e.src_stmt.startswith("A") or e.dst_stmt.startswith("A"):
                assert stage_of(e.src_stmt) == stage_of(e.dst_stmt)

    def test_backward_chain_retained(self):
        plan = plan_pipeline_sync(
            StageGraph(num_stages=3, num_microbatches=3, with_backward=True)
        )
        pairs = {(e.src_stmt, e.dst_stmt) for e in plan.events}
        assert ("B2", "B1") in pairs or ("B1", "B0") in pairs

    def test_dswp_execution_of_plan_is_correct(self):
        """Execute the optimized pipeline program on one thread per statement
        with the retained sync only — results must match sequential."""

        graph = StageGraph(
            num_stages=3, num_microbatches=4, skips=((0, 2),)
        )
        plan = plan_pipeline_sync(graph)
        rep = run_threaded(
            plan.optimized_sync,
            model="dswp",
            stalls={("F1", (1,)): 0.1},
        )
        assert rep.matches_sequential

    def test_dswp_naive_also_correct_but_more_syncs(self):
        graph = StageGraph(num_stages=4, num_microbatches=3, skips=((0, 2), (0, 3)))
        plan = plan_pipeline_sync(graph)
        naive = run_threaded(plan.naive_sync, model="dswp")
        opt = run_threaded(plan.optimized_sync, model="dswp")
        assert naive.matches_sequential and opt.matches_sequential
        assert opt.stats.waits < naive.stats.waits


class TestEventClassification:
    def test_events_by_kind(self):
        plan = plan_pipeline_sync(
            StageGraph(num_stages=3, num_microbatches=2, with_backward=True)
        )
        kinds = events_by_kind(plan)
        assert all(
            stage_of(e.src_stmt) != stage_of(e.dst_stmt)
            for e in kinds["cross_stage"]
        )
        assert all(
            stage_of(e.src_stmt) == stage_of(e.dst_stmt) for e in kinds["local"]
        )
