"""The multi-device SPMD wavefront backend (:mod:`repro.compile.spmd`).

Four contracts under test:

* **Collective-aware policy divergence** — the same ``SyncPlan`` compiled
  for ``xla`` and ``xla_spmd`` picks *different* strategies for the same
  recurrence SCC when a mesh is available: the wide ``{(0,1),(1,-1)}``
  recurrence chunks on one device but skews on eight (lane savings beat
  the collective tax), while a narrow blocked recurrence keeps chunking on
  eight (sharding loses) — both auctions recorded in
  ``summary()["scc"]`` offers.
* **Degenerate mesh** — a 1-device mesh takes the base lowering's exact
  code path: no ``shard_map``, zero ``spmd.collectives``, bit-equal.
* **Reset discipline** — ``obs.reset_all()`` clears the forced device
  count, the cached mesh handles and the backend's structural cache, so
  tests that vary device counts stay order-independent.
* **Real 8-device sharding** (subprocess — ``XLA_FLAGS`` must be set
  before jax imports): a mini-corpus (wide recurrence, the paper's cyclic
  alg6, non-affine inspect programs) stays bit-equal to the sequential
  oracle under real sharding, and re-meshing the same structure is a
  structural cache HIT whose per-device-count cases land in different
  buckets (``_SpmdCaseStatic.n_shards`` rides the jit static, never the
  structural key).

Plus the PR's lowering satellite: inspect-scheduled (instance-edge)
programs now lower through the recurrence-band path instead of the
generic per-level cursor loop.
"""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

import repro.obs as obs
from repro.obs import metrics
from repro.core import (
    ArrayRef,
    LoopProgram,
    PlanOptions,
    Statement,
    histogram,
    indexed_store,
    paper_alg6,
    plan,
    registered_backends,
    run_sequential,
)
from repro.compile import spmd

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _fresh(prog: LoopProgram) -> dict:
    return {a: dict(c) for a, c in prog.initial_store().items()}


def wide_recurrence(ni: int, nj: int) -> LoopProgram:
    """{(0,1), (1,-1)}: chunking is fully serial (unit chunks) while a
    unimodular skew runs an ``nj``-wide diagonal wavefront — the sharding
    sweet spot."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, ni), (0, nj)),
    )


def narrow_blocked_recurrence(n: int) -> LoopProgram:
    """{(0,-32), (-1,1)}: the (0,-32) dep admits 32-iteration DOACROSS
    chunks, so chunking is cheap and the skewed wavefront's lanes never
    amortize the collective tax — sharding should lose here."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -32)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, n), (0, n)),
    )


# ---------------------------------------------------------------------- #
# Registration
# ---------------------------------------------------------------------- #

def test_backend_registered():
    assert "xla_spmd" in registered_backends()


def test_unknown_option_rejected_naming_accepted_set():
    prog = wide_recurrence(4, 4)
    with pytest.raises(ValueError) as exc:
        plan(prog, method="isd").compile("xla_spmd", bogus_knob=1)
    assert "bogus_knob" in str(exc.value)


# ---------------------------------------------------------------------- #
# Collective-aware policy divergence (cost model only — execution still
# degrades to one device inside a single-device pytest process)
# ---------------------------------------------------------------------- #

def test_wide_recurrence_diverges_shard_vs_chunk():
    obs.reset_all()
    spmd.force_device_count(8)
    try:
        prog = wide_recurrence(40, 96)
        p = plan(prog, method="isd")
        exe_xla = p.compile("xla")
        exe_spmd = p.compile("xla_spmd")
        (rec_x,) = exe_xla.report().summary()["scc"]["recurrences"]
        (rec_s,) = exe_spmd.report().summary()["scc"]["recurrences"]
        # one device: chunking wins; eight devices: the skewed wavefront's
        # 96 lanes split 8 ways beat the per-step all_gather
        assert rec_x["strategy"] == "chunk"
        assert rec_s["strategy"] == "skew"
        # both auctions scored both offers — the SYNC_REPORTS-diffable part
        assert rec_x["offers"]["chunk"] < rec_x["offers"]["skew"]
        assert rec_s["offers"]["skew"] < rec_s["offers"]["chunk"]
        # and both executions stay bit-equal to the oracle
        oracle = run_sequential(prog, _fresh(prog))
        assert exe_xla.run(store=_fresh(prog)) == oracle
        assert exe_spmd.run(store=_fresh(prog)) == oracle
    finally:
        spmd.force_device_count(None)


def test_narrow_recurrence_keeps_chunking_on_wide_mesh():
    obs.reset_all()
    spmd.force_device_count(8)
    try:
        prog = narrow_blocked_recurrence(32)
        exe = plan(prog, method="isd").compile("xla_spmd")
        (rec,) = exe.report().summary()["scc"]["recurrences"]
        # sharding loses: the auction keeps chunking even with 8 devices
        assert rec["strategy"] == "chunk"
        assert rec["offers"]["chunk"] < rec["offers"]["skew"]
        # the (0,-32) read reaches 32 cells back — widen the store pad
        init = {a: dict(c) for a, c in prog.initial_store(pad=33).items()}
        assert exe.run(
            store={a: dict(c) for a, c in init.items()}
        ) == run_sequential(prog, init)
    finally:
        spmd.force_device_count(None)


def test_degenerate_cost_model_matches_xla():
    """At device_count()==1 the spmd cost hook must equal xla_level_cost —
    the degenerate mesh must not perturb single-device auctions."""

    obs.reset_all()
    prog = wide_recurrence(40, 96)
    p = plan(prog, method="isd")
    (rec_x,) = p.compile("xla").report().summary()["scc"]["recurrences"]
    (rec_s,) = p.compile("xla_spmd").report().summary()["scc"]["recurrences"]
    assert rec_s["strategy"] == rec_x["strategy"]
    assert rec_s["offers"] == rec_x["offers"]


# ---------------------------------------------------------------------- #
# Degenerate single-device mesh
# ---------------------------------------------------------------------- #

def test_single_device_mesh_collapses_to_base_trace():
    obs.reset_all()
    prog = paper_alg6(16)
    exe = plan(prog, method="isd").compile("xla_spmd")
    out = exe.run(store=_fresh(prog))
    assert out == run_sequential(prog, _fresh(prog))
    # no shard_map, no collectives — the single-device trace, literally
    assert metrics.counter("spmd.collectives").value == 0
    assert metrics.gauge("spmd.devices").value == 1
    for case in exe.compiled._cases.values():
        assert case.static.n_shards == 1


def test_spmd_artifacts_never_alias_xla_artifacts():
    """Same structure, both backends: each backend's cache hands back its
    own artifact class (structural keys carry no backend tag — the
    isolation lives in the cache instance)."""

    obs.reset_all()
    prog = paper_alg6(12)
    p = plan(prog, method="isd")
    exe_xla = p.compile("xla")
    exe_spmd = p.compile("xla_spmd")
    assert exe_xla.compiled is not exe_spmd.compiled
    assert type(exe_spmd.compiled) is spmd.SpmdCompiledProgram
    assert type(exe_xla.compiled) is not spmd.SpmdCompiledProgram


# ---------------------------------------------------------------------- #
# Reset discipline (the obs.reset_all() satellite)
# ---------------------------------------------------------------------- #

def test_reset_all_clears_forced_count_and_mesh_handles():
    spmd.force_device_count(8)
    assert spmd.device_count() == 8
    spmd._MESHES[99] = object()  # stand-in for a cached mesh handle
    obs.reset_all()
    assert spmd._FORCED is None
    assert spmd._ACTUAL is None  # re-read from jax on next use
    assert spmd._MESHES == {}
    assert spmd.device_count() == spmd._actual_devices()
    assert spmd.shard_count() == spmd._actual_devices()


# ---------------------------------------------------------------------- #
# Inspect-scheduled programs lower through the recurrence-band path
# ---------------------------------------------------------------------- #

def test_inspect_schedule_takes_recurrence_band_path():
    obs.reset_all()
    prog = histogram(8)
    # every iteration hits the same bin: the instance graph is a serial
    # chain, i.e. eight single-lane levels — a recurrence band
    store = indexed_store(prog, {"bin": [3] * 8})
    init = {a: dict(c) for a, c in store.items()}
    oracle = run_sequential(prog, init)
    for backend in ("xla", "xla_spmd"):
        p = plan(prog, PlanOptions(deps="inspect"))
        exe = p.compile(backend)
        assert exe.run(store=init) == oracle
        (case,) = exe.compiled._cases.values()
        assert case.static.segments is not None
        assert any(seg[0] == "rec" for seg in case.static.segments), (
            backend,
            case.static.segments,
        )


def test_inspect_parallel_rows_stay_bit_equal():
    obs.reset_all()
    prog = histogram(8)
    # distinct bins: fully parallel — no band, still bit-equal
    store = indexed_store(prog, {"bin": list(range(8))})
    init = {a: dict(c) for a, c in store.items()}
    oracle = run_sequential(prog, init)
    p = plan(prog, PlanOptions(deps="inspect"))
    assert p.compile("xla_spmd").run(store=init) == oracle


# ---------------------------------------------------------------------- #
# Real 8-device sharding (subprocess: XLA_FLAGS precedes jax import)
# ---------------------------------------------------------------------- #

_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import jax

    assert jax.device_count() == 8, jax.device_count()

    import repro.obs as obs
    from repro.obs import metrics
    from repro.compile import spmd
    from repro.compile.spmd import SPMD_CACHE
    from repro.core import (
        ArrayRef, LoopProgram, PlanOptions, Statement, histogram,
        indexed_store, paper_alg6, plan, run_sequential, sparse_matvec,
    )

    def fresh(prog, store=None):
        src = store if store is not None else prog.initial_store()
        return {a: dict(c) for a, c in src.items()}

    def wide(ni, nj):
        return LoopProgram(
            statements=(
                Statement(
                    "S1",
                    ArrayRef("a", (0, 0)),
                    (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
                ),
            ),
            bounds=((0, ni), (0, nj)),
        )

    # -- mini-corpus bit-equality under real sharding ------------------- #
    cases = []
    w = wide(40, 96)
    cases.append((w, PlanOptions(), None))
    cases.append((paper_alg6(24), PlanOptions(), None))  # cyclic SCC
    h = histogram(8)  # non-affine, serial chain -> recurrence band
    cases.append((h, PlanOptions(deps="inspect"),
                  indexed_store(h, {"bin": [3] * 8})))
    sp = sparse_matvec(8)  # non-affine, two-rows-serial
    cases.append((sp, PlanOptions(deps="inspect"),
                  indexed_store(sp, {"row": [0, 0, 1, 1, 2, 2, 3, 3],
                                     "col": list(range(8))})))
    for prog, opts, store in cases:
        init = fresh(prog, store)
        oracle = run_sequential(prog, init)
        exe = plan(prog, opts).compile("xla_spmd")
        assert exe.run(store=fresh(prog, store)) == oracle, prog
    assert metrics.gauge("spmd.devices").value == 8
    assert metrics.counter("spmd.collectives").value > 0
    assert metrics.histogram("spmd.shard_width").snapshot()["count"] > 0

    # -- bucket identity across device counts --------------------------- #
    obs.reset_all()  # clears SPMD_CACHE + forced count + mesh handles
    prog = wide(40, 96)
    oracle = run_sequential(prog, fresh(prog))
    spmd.force_device_count(2)
    exe2 = plan(prog, method="isd").compile("xla_spmd")
    assert exe2.run(store=fresh(prog)) == oracle
    assert SPMD_CACHE.stats.misses == 1
    spmd.force_device_count(8)
    exe8 = plan(prog, method="isd").compile("xla_spmd")
    assert exe8.run(store=fresh(prog)) == oracle
    # same structure on a different mesh: structural HIT, same artifact...
    assert SPMD_CACHE.stats.misses == 1
    assert SPMD_CACHE.stats.hits >= 1
    assert exe8.compiled is exe2.compiled
    # ...but the device count bucketed two distinct cases/traces
    shards = sorted(
        c.static.n_shards for c in exe8.compiled._cases.values()
    )
    assert shards == [2, 8], shards
    assert exe8.compiled.bucket_count == 2
    spmd.force_device_count(None)
    print("SPMD-SUBPROCESS-OK")
    """
)


@pytest.mark.slow
def test_real_eight_device_sharding_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT],
        capture_output=True,
        text=True,
        timeout=480,
        env=env,
        cwd=str(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SPMD-SUBPROCESS-OK" in proc.stdout
