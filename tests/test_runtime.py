"""Runtime substrate: data determinism, checkpoint round-trip + crash
recovery, fault-tolerant training loop, elastic planning, straggler
detection, gradient compression, pipeline executor."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, Snapshot
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, DataIterator, DataState, make_batch
from repro.optim.compression import Int8Compressor, TopKCompressor
from repro.optim.optimizer import AdamW
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    StragglerDetector,
    WorkerFailure,
    plan_elastic_mesh,
)
from repro.runtime.pipeline import PipelineRunner
from repro.runtime.trainer import train_loop

CFG = get_smoke_config("yi_6b")
DC = DataConfig(global_batch=4, seq_len=16, seed=3)
# convergence-check optimizer: warmup/LR sized to a ~10-step smoke run
SMOKE_OPT = AdamW(learning_rate=1e-2, warmup_steps=2, total_steps=12)


class TestDataPipeline:
    def test_deterministic(self):
        b1 = make_batch(DC, CFG, DataState(seed=3, step=5))
        b2 = make_batch(DC, CFG, DataState(seed=3, step=5))
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        b1 = make_batch(DC, CFG, DataState(seed=3, step=5))
        b2 = make_batch(DC, CFG, DataState(seed=3, step=6))
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        b = make_batch(DC, CFG, DataState(seed=3, step=0))
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions_global_batch(self):
        full = make_batch(
            dataclasses.replace(DC, num_hosts=1, host_id=0),
            CFG,
            DataState(seed=3, step=2),
        )
        parts = [
            make_batch(
                dataclasses.replace(DC, num_hosts=2, host_id=h),
                CFG,
                DataState(seed=3, step=2),
            )
            for h in range(2)
        ]
        np.testing.assert_array_equal(
            np.concatenate([p["tokens"] for p in parts]), full["tokens"]
        )

    def test_iterator_resume(self):
        it = DataIterator(DC, CFG)
        seq1 = [next(it)["tokens"] for _ in range(5)]
        state3 = DataState(seed=3, step=3)
        it2 = DataIterator(DC, CFG, state=state3)
        np.testing.assert_array_equal(next(it2)["tokens"], seq1[3])

    def test_tokens_in_vocab(self):
        b = make_batch(DC, CFG, DataState(seed=3, step=9))
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < CFG.vocab_size


class TestCheckpointManager:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_writes=False)
        tree = {"a": np.arange(6).reshape(2, 3), "b": {"c": np.ones(4)}}
        mgr.save(Snapshot(step=7, tree=tree, data_state=DataState(1, 9)))
        snap = mgr.restore()
        assert snap.step == 7
        np.testing.assert_array_equal(snap.tree["a"], tree["a"])
        assert snap.data_state == DataState(1, 9)

    def test_async_write_and_wait(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_writes=True)
        mgr.save(Snapshot(step=1, tree={"x": np.ones(3)}))
        mgr.wait()
        assert mgr.committed_steps() == [1]
        mgr.close()

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_writes=False)
        for s in (1, 2, 3, 4):
            mgr.save(Snapshot(step=s, tree={"x": np.ones(2) * s}))
        assert mgr.committed_steps() == [3, 4]

    def test_crash_mid_write_ignored(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_writes=False)
        mgr.save(Snapshot(step=1, tree={"x": np.ones(2)}))
        # simulate a crash: stale tmp dir + missing manifest
        (tmp_path / "step_000000002.tmp").mkdir()
        (tmp_path / "step_000000003").mkdir()
        assert mgr.restore().step == 1
        # a new manager garbage-collects the tmp
        mgr2 = CheckpointManager(tmp_path, async_writes=False)
        assert not (tmp_path / "step_000000002.tmp").exists()

    def test_namedtuple_restore_with_target(self, tmp_path):
        opt = AdamW()
        params = {"w": jnp.ones((2, 2))}
        state = opt.init(params)
        mgr = CheckpointManager(tmp_path, async_writes=False)
        mgr.save(Snapshot(step=5, tree={"params": params, "opt": state}))
        snap = mgr.restore(target={"params": params, "opt": state})
        assert snap.tree["opt"].step.shape == ()
        np.testing.assert_array_equal(snap.tree["params"]["w"], params["w"])


class TestFaultTolerance:
    def test_heartbeat_timeout(self):
        t = [0.0]
        mon = HeartbeatMonitor(["w0", "w1"], timeout_s=5, clock=lambda: t[0])
        t[0] = 3.0
        mon.heartbeat("w0")
        t[0] = 7.0
        assert mon.check() == ["w1"]
        assert mon.alive() == ["w0"]

    def test_straggler_detection(self):
        det = StragglerDetector(min_samples=3)
        for _ in range(6):
            for w in ("a", "b", "c"):
                det.record(w, 1.0)
            det.record("slow", 2.5)
        assert det.stragglers() == ["slow"]

    def test_elastic_plan_shrinks_data_axis(self):
        plan = plan_elastic_mesh(240, model_axis=16, global_batch=256)
        assert plan.model == 16
        assert plan.data == 8  # 240//16 = 15 healthy → 8 is largest pow2
        assert plan.chips == 128

    def test_elastic_plan_raises_below_tp(self):
        with pytest.raises(RuntimeError):
            plan_elastic_mesh(8, model_axis=16)


class TestTrainLoop:
    def test_loss_decreases(self):
        # smoke-scale optimizer: the default production LR/warmup moves a
        # 12-step run by less than the per-batch loss noise, which made this
        # assertion a coin flip (the loop, not the hyperparameters, is
        # under test — the stream's learnable marginal is what it learns)
        res = train_loop(CFG, DC, total_steps=12, opt=SMOKE_OPT)
        assert res.final_step == 12
        assert np.mean(res.losses[-3:]) < np.mean(res.losses[:3])

    def test_microbatched_matches_steps(self):
        res = train_loop(CFG, DC, total_steps=4, microbatches=2)
        assert res.final_step == 4
        assert all(np.isfinite(l) for l in res.losses)

    def test_checkpoint_resume_is_exact(self, tmp_path):
        """12 straight steps == 8 steps + restart + 4 steps, bitwise on the
        loss trace after the restore point."""

        mgr1 = CheckpointManager(tmp_path / "a", async_writes=False, keep=10)
        full = train_loop(CFG, DC, total_steps=12, ckpt=mgr1, ckpt_every=4)

        mgr2 = CheckpointManager(tmp_path / "b", async_writes=False, keep=10)
        part1 = train_loop(CFG, DC, total_steps=8, ckpt=mgr2, ckpt_every=4)
        part2 = train_loop(CFG, DC, total_steps=12, ckpt=mgr2, ckpt_every=4)
        assert part2.final_step == 12
        np.testing.assert_allclose(
            full.losses[8:], part2.losses, rtol=1e-6, atol=1e-6
        )

    def test_failure_recovery(self, tmp_path):
        """A worker failure at step 6 rolls back to the step-4 checkpoint and
        the run still completes all 10 steps."""

        mgr = CheckpointManager(tmp_path, async_writes=False, keep=10)
        fired = []

        def injector(step):
            if step == 6 and not fired:
                fired.append(True)
                raise WorkerFailure("w0")

        res = train_loop(
            CFG,
            DC,
            total_steps=10,
            ckpt=mgr,
            ckpt_every=4,
            failure_injector=injector,
        )
        assert res.restarts == 1
        assert res.final_step == 10


class TestCompression:
    def test_int8_roundtrip_accuracy(self):
        comp = Int8Compressor()
        g = {"w": jnp.array([[0.5, -1.0], [2.0, 0.01]])}
        res = comp.init(g)
        out, res = comp.apply(g, res)
        np.testing.assert_allclose(out["w"], g["w"], atol=2.0 / 127)

    def test_error_feedback_accumulates(self):
        """Summed compressed grads converge to summed true grads (EF)."""

        comp = Int8Compressor()
        g = {"w": jnp.full((4,), 0.003)}
        res = comp.init(g)
        total = jnp.zeros(4)
        for _ in range(50):
            out, res = comp.apply(g, res)
            total = total + out["w"]
        np.testing.assert_allclose(total, 50 * g["w"], rtol=0.05)

    def test_int8_bytes_are_4x_smaller(self):
        g = {"w": jnp.ones((128, 64))}
        assert Int8Compressor.raw_bytes(g) == 4 * Int8Compressor.compressed_bytes(g)

    def test_topk_keeps_largest(self):
        comp = TopKCompressor(fraction=0.25)
        g = {"w": jnp.array([10.0, 0.1, -20.0, 0.2, 0.3, 1.0, 0.0, 0.05])}
        out, res = comp.apply(g, comp.init(g))
        kept = np.nonzero(np.asarray(out["w"]))[0]
        assert set(kept) == {0, 2}
        # residual carries everything dropped
        np.testing.assert_allclose(out["w"] + res["w"], g["w"], atol=1e-6)

    def test_train_with_compression_converges(self):
        comp = Int8Compressor()
        state = {"res": None}

        def hook(grads, opt_state):
            if state["res"] is None:
                state["res"] = comp.init(grads)
            out, state["res"] = comp.apply(grads, state["res"])
            return out, opt_state

        res = train_loop(
            CFG, DC, total_steps=10, grad_compressor=hook, opt=SMOKE_OPT
        )
        assert np.mean(res.losses[-3:]) < np.mean(res.losses[:3])


class TestPipelineRunner:
    def _stages(self, S):
        def mk(s):
            def fn(x):
                if isinstance(x, tuple):
                    base, *skips = x
                    return base * 2.0 + sum(skips) + s
                return x * 2.0 + s

            return fn

        return [mk(s) for s in range(S)]

    def test_matches_sequential_reference(self):
        runner = PipelineRunner(self._stages(4), num_microbatches=3)
        inputs = [jnp.full((2,), float(m)) for m in range(3)]
        out, stats = runner.run(inputs)
        ref = runner.run_reference(inputs)
        for a, b in zip(out, ref):
            np.testing.assert_allclose(a, b)
        assert stats.handoffs == 3 * 3  # (S-1) hand-offs × M microbatches

    def test_skip_connections_ride_the_chain(self):
        skips = ((0, 2), (0, 3))
        runner = PipelineRunner(self._stages(4), skips=skips, num_microbatches=2)
        inputs = [jnp.ones((2,)) * (m + 1) for m in range(2)]
        out, stats = runner.run(inputs)
        ref = runner.run_reference(inputs)
        for a, b in zip(out, ref):
            np.testing.assert_allclose(a, b)
        # optimized: still only (S-1) hand-offs per microbatch; naive would
        # pay one extra per skip edge
        assert stats.handoffs_per_microbatch == 3
        assert runner.naive_handoffs_per_microbatch() == 5

    def test_plan_eliminates_skips(self):
        runner = PipelineRunner(
            self._stages(5), skips=((0, 2), (1, 4)), num_microbatches=2
        )
        gone = {
            (d.source, d.sink) for d in runner.plan.elimination.eliminated
        }
        assert ("F0", "F2") in gone and ("F1", "F4") in gone
