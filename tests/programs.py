"""Shared example-program corpus for the differential test matrix.

One registry instead of three private copies: ``tests/test_wavefront.py``,
``tests/test_cyclic.py`` and ``tests/test_paper_regression.py`` historically
each grew their own program lists; any new program family (most recently the
non-affine inspector set) had to be added in several places or silently
missed a backend.  Everything the oracle harness (``tests/oracle.py``) should
sweep now lives here:

  * ``PAPER_PROGRAMS``        — the paper's Alg. 1 / Alg. 4 / Alg. 6 loops;
  * ``DIFFERENTIAL_PROGRAMS`` — paper loops + 2-D distances, guards,
    stencils, doall and seeded-random programs (the classic wavefront set);
  * ``CYCLIC_PROGRAMS``       — mixed-Δ recurrences exercising the
    SCC-condensed hybrid scheduler;
  * ``NONAFFINE_PROGRAMS``    — indirect-subscript programs (gather/scatter,
    sparse matvec, histogram) whose exact dependences only the runtime
    inspector (:mod:`repro.core.inspector`) can resolve;
  * ``ALL_PROGRAMS``          — the union, unique by name.

Builders stay importable individually (several tests re-instantiate them at
other bounds); entries are ``(name, LoopProgram)`` pairs ready for
``pytest.mark.parametrize(..., ids=...)``.
"""

from __future__ import annotations

import random
from typing import List, Tuple

from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    gather_scatter,
    histogram,
    paper_alg1,
    paper_alg4,
    paper_alg6,
    sparse_matvec,
)

Corpus = List[Tuple[str, LoopProgram]]


# ---------------------------------------------------------------------- #
# Affine builders (formerly private to test_wavefront.py)
# ---------------------------------------------------------------------- #

def random_program(seed: int, n_stmt: int = 4, n_iter: int = 6) -> LoopProgram:
    rng = random.Random(seed)
    arrays = ["a", "b", "c", "d"]
    stmts = []
    for k in range(n_stmt):
        reads = tuple(
            ArrayRef(rng.choice(arrays), -rng.randint(0, 3))
            for _ in range(rng.randint(0, 3))
        )
        stmts.append(Statement(f"S{k+1}", ArrayRef(rng.choice(arrays), 0), reads))
    return LoopProgram(statements=tuple(stmts), bounds=((1, 1 + n_iter),))


def guarded_program() -> LoopProgram:
    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("p", 0), (ArrayRef("p", -1),)),
            Statement(
                "S2", ArrayRef("a", 0), (ArrayRef("a", -1),), guard=ArrayRef("p", -1)
            ),
        ),
        bounds=((1, 7),),
    )


def distance_2d() -> LoopProgram:
    """2-D distance case: (1,1) dep covered by (1,0)+(0,1) self-deps."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (-1, 0)), ArrayRef("a", (0, -1))),
            ),
            Statement("S2", ArrayRef("c", (0, 0)), (ArrayRef("a", (-1, -1)),)),
        ),
        bounds=((0, 4), (0, 4)),
    )


# ---------------------------------------------------------------------- #
# Cyclic / mixed-Δ builders (formerly private to test_cyclic.py)
# ---------------------------------------------------------------------- #

def skew_recurrence(ni=5, nj=5):
    """a[i,j] = f(a[i-1,j+1]): mixed-sign (1,-1) self-recurrence; the hybrid
    runs it as a chunked DOACROSS of width nj-1."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
        ),
        bounds=((0, ni), (0, nj)),
    )


def mixed_cycle_pm1():
    """The acceptance example: retained {Δ components +1, -1} closing a
    statement cycle — S1 -> S2 with (0,1), S2 -> S1 with (1,-1)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("b", (-1, 1)),)),
            Statement("S2", ArrayRef("b", (0, 0)), (ArrayRef("a", (0, -1)),)),
        ),
        bounds=((0, 4), (0, 4)),
    )


def skew_pipeline():
    """Recurrence SCC + downstream DOALL consumer (cross-SCC pipelining)."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
            Statement("S2", ArrayRef("c", (0, 0)), (ArrayRef("a", (0, 0)),)),
        ),
        bounds=((0, 5), (0, 6)),
    )


def double_skew():
    """Two carried mixed-sign deps with different linearized distances —
    the chunk must follow the minimum."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (-1, 2)), ArrayRef("a", (-1, -1))),
            ),
        ),
        bounds=((0, 5), (0, 6)),
    )


def guarded_recurrence():
    """Mixed-sign recurrence under a data-dependent guard: the guard path
    must survive the nested-fori_loop lowering too."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("p", (0, 0)), (ArrayRef("p", (-1, 1)),)),
            Statement(
                "S2",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (-1, 1)),),
                guard=ArrayRef("p", (0, 0)),
            ),
        ),
        bounds=((0, 4), (0, 5)),
    )


def producer_into_cycle():
    """Acyclic producer feeding a two-statement mixed-sign cycle."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("d", (0, 0)), ()),
            Statement(
                "S2",
                ArrayRef("a", (0, 0)),
                (ArrayRef("b", (-1, 1)), ArrayRef("d", (0, 0))),
            ),
            Statement("S3", ArrayRef("b", (0, 0)), (ArrayRef("a", (0, -1)),)),
        ),
        bounds=((0, 4), (0, 4)),
    )


# ---------------------------------------------------------------------- #
# Registries
# ---------------------------------------------------------------------- #

PAPER_PROGRAMS: Corpus = [
    ("alg1", paper_alg1(8)),
    ("alg4_the_alg5_loop", paper_alg4(8)),
    ("alg6", paper_alg6(8)),
]

DIFFERENTIAL_PROGRAMS: Corpus = [
    *PAPER_PROGRAMS,
    ("distance_2d", distance_2d()),
    ("guarded", guarded_program()),
    (
        "doall_parallel",
        LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", 0),)),
                Statement("S2", ArrayRef("c", 0), (ArrayRef("a", 0),)),
            ),
            bounds=((0, 9),),
        ),
    ),
    (
        "stencil_delta3",
        LoopProgram(
            statements=(
                Statement(
                    "S1", ArrayRef("a", 0), (ArrayRef("a", -1), ArrayRef("a", -3))
                ),
            ),
            bounds=((1, 9),),
        ),
    ),
    (
        "nest_2d_cross",
        LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("b", (-1, 0)),)),
                Statement("S2", ArrayRef("b", (0, 0)), (ArrayRef("a", (0, -1)),)),
            ),
            bounds=((0, 3), (0, 3)),
        ),
    ),
    ("random_0", random_program(0)),
    ("random_1", random_program(1)),
    ("random_2", random_program(2, n_stmt=3, n_iter=5)),
    ("random_3", random_program(3, n_stmt=2, n_iter=8)),
]

CYCLIC_PROGRAMS: Corpus = [
    ("paper_alg4_cyclic_isd", paper_alg4(8)),
    ("skew_recurrence", skew_recurrence()),
    ("mixed_cycle_pm1", mixed_cycle_pm1()),
    ("skew_pipeline", skew_pipeline()),
    ("double_skew", double_skew()),
    ("guarded_recurrence", guarded_recurrence()),
    ("producer_into_cycle", producer_into_cycle()),
]

# Indirect-subscript programs: the static analyzer can only emit conservative
# serializing proxies for these; exact parallelism needs the runtime
# inspector.  The default initial_store() hash values truncate into the
# pad-8 index box (see repro.core.inspector.indexed_store), so the oracle
# matrix runs them unmodified.
NONAFFINE_PROGRAMS: Corpus = [
    ("gather_scatter", gather_scatter(8)),
    ("sparse_matvec", sparse_matvec(8)),
    ("histogram", histogram(8)),
]


def _unique_by_name(*corpora: Corpus) -> Corpus:
    seen, out = set(), []
    for corpus in corpora:
        for name, prog in corpus:
            if name not in seen:
                seen.add(name)
                out.append((name, prog))
    return out


ALL_PROGRAMS: Corpus = _unique_by_name(
    DIFFERENTIAL_PROGRAMS, CYCLIC_PROGRAMS, NONAFFINE_PROGRAMS
)
