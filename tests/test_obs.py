"""repro.obs: span tracing, the unified metrics registry, and the
predicted-vs-measured strategy profiler.

The contracts pinned here (ISSUE 7's acceptance criteria):

  * spans nest correctly under concurrent planner threads (the serve
    loop's 2-thread pool shape);
  * trace export round-trips valid Chrome-trace JSON;
  * tracing overhead on a traced plan().compile().run() stays under a
    loose bounded ratio vs. untraced;
  * instrumentation changes no structural cache key and no oracle
    bit-equality (routed through tests/oracle.py);
  * the three legacy stat surfaces are registry-backed views now, with one
    ``obs.reset_all()`` replacing the three-way reset dance;
  * every recurrence summary row carries the policy's full predicted
    scoreboard (``offers``) and ``profile_executable`` pairs it with a
    measured wall time.
"""

import concurrent.futures
import json
import time

import pytest

from oracle import assert_equivalent
from repro import obs
from repro.obs import metrics, profile, trace
from repro.core import (
    ArrayRef,
    LoopProgram,
    PlanOptions,
    Statement,
    analysis_cache_stats,
    clear_analysis_cache,
    histogram,
    indexed_store,
    inspector_cache_stats,
    paper_alg6,
    plan,
    run_sequential,
)
from repro.core.scc import WavefrontError


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts from zeroed metrics, an empty trace buffer, and
    tracing disabled — and leaves the process the same way."""

    trace.disable()
    obs.reset_all()
    yield
    trace.disable()
    obs.reset_all()


def _recurrence_program(rows=4, cols=12):
    # {(0,1), (1,-1)} mixed-sign recurrence: chunk pinned to 1 by the (0,1)
    # carried dep, so the interpreter's cost model prefers skew — an SCC
    # with a real multi-offer auction
    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, rows), (0, cols)),
    )


# ---------------------------------------------------------------------- #
# Span tracing
# ---------------------------------------------------------------------- #

class TestTracer:
    def test_disabled_by_default_records_nothing(self):
        plan(paper_alg6(6), method="isd").compile("wavefront").run()
        assert trace.events() == []
        assert not trace.tracing_enabled()

    def test_span_records_pipeline_phases(self):
        with trace.tracing():
            exe = plan(paper_alg6(6), method="isd").compile("wavefront")
            exe.run()
        names = {e["name"] for e in trace.events()}
        assert {
            "plan",
            "plan.deps",
            "plan.fission",
            "plan.naive_sync",
            "plan.elimination",
            "plan.validate",
            "plan.optimize",
            "compile",
            "run",
            "wavefront.level",
        } <= names

    def test_tracing_context_restores_prior_state(self):
        assert not trace.tracing_enabled()
        with trace.tracing():
            assert trace.tracing_enabled()
            with trace.tracing():
                assert trace.tracing_enabled()
            assert trace.tracing_enabled()  # restores OUTER state, not off
        assert not trace.tracing_enabled()

    def test_trace_export_round_trips_chrome_json(self):
        with trace.tracing():
            exe = plan(paper_alg6(8), method="isd").compile("wavefront")
            exe.run()
        doc = json.loads(exe.trace_json())
        events = doc["traceEvents"]
        assert events, "traced pipeline produced no events"
        for ev in events:
            assert ev["ph"] == "X"  # complete events only
            assert {"name", "cat", "ts", "dur", "pid", "tid", "args"} <= set(ev)
            assert ev["dur"] >= 0
        # the export is plain JSON all the way down (re-dump is lossless)
        assert json.loads(json.dumps(doc)) == doc
        # module-level export and the Executable view agree
        assert doc == trace.to_chrome_trace()

    def test_parent_attribution_inside_plan(self):
        with trace.tracing():
            plan(paper_alg6(5), method="isd")
        by_name = {}
        for e in trace.events():
            by_name.setdefault(e["name"], e)
        assert by_name["plan.deps"]["args"]["parent"] == "plan"
        assert by_name["plan.validate"]["args"]["parent"] == "plan"
        assert by_name["plan"]["args"]["parent"] is None

    def test_spans_nest_under_concurrent_planner_threads(self):
        """Two planner threads (the serve loop's pool shape) tracing
        concurrently: per-thread span streams must keep strict stack
        discipline — any two same-thread spans are disjoint or nested,
        never partially overlapping — and child spans name the right
        parent even while the other thread is mid-span."""

        def one_wave(n):
            # distinct structures so both threads do real planning work
            prog = paper_alg6(16 + n) if n % 2 else _recurrence_program(4, 8 + n)
            clear_analysis_cache()  # force re-analysis: longer, racier spans
            return plan(prog, method="isd").compile("wavefront").run()

        with trace.tracing():
            with concurrent.futures.ThreadPoolExecutor(
                max_workers=2, thread_name_prefix="sync-planner"
            ) as pool:
                futures = [pool.submit(one_wave, n) for n in range(6)]
                for f in futures:
                    f.result()

        by_tid = {}
        for e in trace.events():
            by_tid.setdefault(e["tid"], []).append(e)
        assert len(by_tid) >= 2, "expected spans from both planner threads"
        for tid, evs in by_tid.items():
            spans = sorted(
                ((e["ts"], e["ts"] + e["dur"], e["name"]) for e in evs)
            )
            for i, (s0, e0, _n0) in enumerate(spans):
                for s1, e1, n1 in spans[i + 1:]:
                    if s1 >= e0:
                        continue  # disjoint
                    assert e1 <= e0, (
                        f"thread {tid}: span {n1!r} partially overlaps "
                        "an earlier span — stack discipline broken"
                    )
            # the nesting metadata survived the concurrency too
            parents = {
                e["name"]: e["args"]["parent"]
                for e in evs
                if e["name"].startswith("plan.")
            }
            for child, parent in parents.items():
                assert parent == "plan", (child, parent)

    def test_buffer_is_bounded(self):
        with trace.tracing():
            for i in range(trace.MAX_EVENTS + 50):
                trace.emit("tick", time.perf_counter_ns())
        assert len(trace.events()) == trace.MAX_EVENTS

    def test_traced_overhead_stays_bounded(self):
        """Tracing on vs off around the same plan().compile().run() —
        a LOOSE ratio (shared-runner jitter), not a precision benchmark;
        the <5% disabled-path budget is the bench gate's job."""

        prog = paper_alg6(64)

        def cycle():
            return plan(prog, method="isd").compile("wavefront").run()

        cycle()  # warm the analysis memo and numpy paths

        def best_of(n=5):
            best = float("inf")
            for _ in range(n):
                t0 = time.perf_counter()
                cycle()
                best = min(best, time.perf_counter() - t0)
            return best

        untraced = best_of()
        with trace.tracing():
            traced = best_of()
        assert traced <= max(untraced, 1e-4) * 10, (
            f"traced={traced*1e6:.0f}us untraced={untraced*1e6:.0f}us"
        )


# ---------------------------------------------------------------------- #
# Unified metrics registry
# ---------------------------------------------------------------------- #

class TestMetricsRegistry:
    def test_counter_gauge_histogram_basics(self):
        c = metrics.counter("t.count")
        c.inc()
        c.inc(4)
        assert c.value == 5
        g = metrics.gauge("t.gauge")
        g.set(2.5)
        assert g.value == 2.5
        h = metrics.histogram("t.hist")
        for v in range(100):
            h.observe(float(v))
        snap = h.snapshot()
        assert snap["count"] == 100
        assert snap["min"] == 0.0 and snap["max"] == 99.0
        assert snap["p50"] == pytest.approx(50.0, abs=2)
        assert snap["p99"] == pytest.approx(98.0, abs=2)
        assert h.percentile(50) == snap["p50"]

    def test_same_name_shares_instrument_and_kind_is_checked(self):
        assert metrics.counter("t.shared") is metrics.counter("t.shared")
        with pytest.raises(TypeError, match="already registered"):
            metrics.gauge("t.shared")

    def test_snapshot_is_json_serializable(self):
        metrics.counter("t.c").inc()
        metrics.histogram("t.h").observe(1.0)
        snap = metrics.snapshot()
        assert json.loads(json.dumps(snap)) == snap

    def test_analysis_stats_are_registry_backed(self):
        prog = paper_alg6(7)
        plan(prog, method="isd")
        plan(prog, method="isd")
        stats = analysis_cache_stats()
        assert stats == {"hits": 1, "misses": 1}
        snap = metrics.snapshot()
        assert snap["analysis_cache.hits"] == 1
        assert snap["analysis_cache.misses"] == 1

    def test_inspector_stats_keep_their_shape(self):
        prog = histogram(6)
        store = indexed_store(prog, {"bin": [0, 1, 2, 0, 1, 2]})
        plan(prog, PlanOptions(deps="inspect")).compile("wavefront").run(
            store={a: dict(c) for a, c in store.items()}
        )
        stats = inspector_cache_stats()
        assert set(stats) == {"hits", "misses", "size"}
        assert stats["misses"] >= 1
        assert metrics.snapshot()["inspector_cache.misses"] == stats["misses"]

    def test_compile_cache_global_is_registered_locals_are_not(self):
        from repro.core import analyze, insert_synchronization
        from repro.compile import CompileCache, compile_cache_stats
        from repro.compile.executor import run_xla

        prog = paper_alg6(5)
        sync = insert_synchronization(prog, analyze(prog))
        local = CompileCache()
        run_xla(sync, cache=local)
        # the test-local cache's counters stay off the registry...
        assert local.stats.as_dict()["misses"] == 1
        assert metrics.snapshot().get("compile_cache.misses", 0) == 0
        # ...while the process-global cache publishes to it
        run_xla(sync)
        assert compile_cache_stats()["misses"] == 1
        assert metrics.snapshot()["compile_cache.misses"] == 1

    def test_per_backend_run_counters(self):
        p = plan(paper_alg6(5), method="isd")
        p.compile("wavefront").run()
        p.compile("wavefront").run()
        p.compile("threaded").run()
        snap = metrics.snapshot()
        assert snap["backend.runs.wavefront"] == 2
        assert snap["backend.runs.threaded"] == 1

    def test_wavefront_rejection_counter(self):
        from repro.core import FLOW, Dependence, analyze

        prog = paper_alg6(6)
        deps = list(analyze(prog)) + [
            Dependence(FLOW, "S2", "S1", "b", (-1,)),  # deadlock cycle
        ]
        with pytest.raises(WavefrontError):
            plan(prog, deps=deps)
        assert metrics.snapshot()["plan.wavefront_rejections"] == 1

    def test_speculation_rollback_counter(self):
        prog = histogram(8)
        store = indexed_store(prog, {"bin": [4] * 8})  # forced conflicts
        init = {a: dict(c) for a, c in store.items()}
        out = (
            plan(prog, PlanOptions(deps="speculate"))
            .compile("wavefront")
            .run(store=init)
        )
        assert out == run_sequential(prog, init)
        snap = metrics.snapshot()
        assert snap["speculation.validations"] == 1
        assert snap["speculation.rollbacks"] == 1

    def test_reset_all_zeroes_every_surface(self):
        prog = paper_alg6(6)
        with trace.tracing():
            plan(prog, method="isd").compile("wavefront").run()
        profile.record({"program": "x"})
        assert trace.events() and profile.records()
        assert analysis_cache_stats()["misses"] == 1
        obs.reset_all()
        assert trace.events() == []
        assert profile.records() == []
        assert analysis_cache_stats() == {"hits": 0, "misses": 0}
        assert inspector_cache_stats() == {"hits": 0, "misses": 0, "size": 0}
        assert all(v in (0, 0.0) for k, v in metrics.snapshot().items()
                   if not isinstance(v, dict))


# ---------------------------------------------------------------------- #
# Invariance: instrumentation must not perturb keys or semantics
# ---------------------------------------------------------------------- #

class TestInstrumentationInvariance:
    def test_structural_key_unchanged_by_tracing(self):
        """Pinned acceptance criterion: the structural cache key is
        byte-identical with tracing off, on, and after traced pipeline
        traffic — observability rides beside the key inputs, never in."""

        from repro.compile.structure import structural_key

        prog = paper_alg6(8)
        p = plan(prog, method="isd")
        key_off = structural_key(prog, p.retained, "doall", None, None, None)
        with trace.tracing():
            p2 = plan(prog, method="isd").compile("wavefront")
            p2.run()
            key_on = structural_key(
                prog, p.retained, "doall", None, None, None
            )
        assert key_on == key_off

    def test_warm_structural_hit_across_tracing_states(self):
        from repro.compile import clear_compile_cache, compile_cache_stats

        clear_compile_cache()
        p = plan(paper_alg6(9), method="isd")
        p.compile("xla")
        assert compile_cache_stats()["misses"] == 1
        with trace.tracing():
            p.compile("xla")  # same structure traced: hit, not a rebuild
        stats = compile_cache_stats()
        assert stats == dict(stats, hits=1, misses=1)

    def test_oracle_bit_equality_with_tracing_enabled(self):
        with trace.tracing():
            assert_equivalent(
                paper_alg6(6), methods=("isd",), threaded=False
            )
        assert trace.events(), "oracle run under tracing recorded nothing"

    def test_summary_obs_present_on_all_backends(self):
        from repro.core import execution_backends

        p = plan(paper_alg6(5), method="isd")
        for backend in execution_backends():
            s = p.compile(backend).report().summary()
            assert s["obs"]["backend"] == backend
            assert s["obs"]["tracing"] is False

    def test_summary_obs_is_deterministic_across_pipeline_traffic(self):
        # the shim/staged bit-identity contract: more pipeline runs in
        # between must not change what summary() returns
        p = plan(paper_alg6(5), method="isd")
        exe = p.compile("wavefront")
        before = exe.report().summary()
        plan(paper_alg6(12), method="isd").compile("wavefront").run()
        assert exe.report().summary() == before


# ---------------------------------------------------------------------- #
# Strategy profiler: predicted next to measured
# ---------------------------------------------------------------------- #

class TestStrategyProfiler:
    def test_recurrence_rows_carry_offer_scoreboard(self):
        exe = plan(_recurrence_program(), method="isd").compile("wavefront")
        (rec,) = exe.report().summary()["scc"]["recurrences"]
        assert rec["strategy"] in rec["offers"]
        assert set(rec["offers"]) >= {"chunk", "skew"}
        # the winner's predicted cost is the auction's minimum
        assert rec["cost"] == min(rec["offers"].values())
        assert rec["offers"][rec["strategy"]] == rec["cost"]

    def test_forced_policy_has_no_auction(self):
        exe = plan(_recurrence_program(), method="isd").compile(
            "wavefront", scc_policy="chunk"
        )
        (rec,) = exe.report().summary()["scc"]["recurrences"]
        assert rec["strategy"] == "chunk"
        assert rec["offers"] == {}

    def test_profile_executable_pairs_predicted_with_measured(self):
        exe = plan(_recurrence_program(), method="isd").compile("wavefront")
        (row,) = profile.profile_executable(exe, program="rec_4x12")
        assert row["program"] == "rec_4x12"
        assert row["backend"] == "wavefront"
        assert row["measured_us"] > 0
        assert row["levels"] == exe.wavefront.depth
        assert row["measured_us_per_level"] == pytest.approx(
            row["measured_us"] / row["levels"]
        )
        assert row["predicted_cost"] == row["predicted"][row["strategy"]]
        assert profile.records() == [row]

    def test_profile_doall_program_emits_whole_program_row(self):
        exe = plan(paper_alg6(6), method="isd").compile("wavefront")
        (row,) = profile.profile_executable(exe, program="alg6")
        assert row["strategy"] == "doall"
        assert row["predicted"] == {}
        assert row["measured_us"] > 0

    def test_profiled_run_preserves_oracle_semantics(self):
        prog = _recurrence_program()
        exe = plan(prog, method="isd").compile("wavefront")
        profile.profile_executable(exe, program="rec")
        init = prog.initial_store()
        assert exe.run(
            store={a: dict(c) for a, c in init.items()}
        ) == run_sequential(prog, init)
