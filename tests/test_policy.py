"""Property + unit coverage for the per-SCC scheduling-policy engine
(repro.core.policy): unimodular-skew legality (determinant ±1, transformed
retained distances per-dimension non-negative, bijective round-trip of
instance coordinates over the iteration space), cost-model strategy
selection, forced-policy fallback, entry-point validation, and differential
bit-equality of every strategy on both fast backends.

Follows the tests/test_strip_properties.py form: seeded-random suites that
always run, plus hypothesis ``@given`` versions (skipped without the
``test`` extra) over the same generators.
"""

import itertools
import random

import pytest

from _hypothesis_compat import HealthCheck, given, settings, st
from oracle import assert_equivalent
from repro.core import (
    ArrayRef,
    ChunkedDoacross,
    CostModelPolicy,
    LoopProgram,
    PerSccModel,
    Statement,
    UnimodularSkew,
    analyze,
    analyze_sccs,
    find_unimodular_skew,
    plan,
    resolve_policy,
    run_wavefront,
    skew_point,
    unskew_point,
)
from repro.core.policy import mat_det, mat_vec, policy_signature


def carried(prog):
    return [d for d in analyze(prog) if d.loop_carried]


def skew_stencil(ni=6, nj=5):
    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
        ),
        bounds=((0, ni), (0, nj)),
    )


def wide_serialized(ni=6, nj=24):
    """{(0,1), (1,-1)} self-recurrence: chunk pinned to 1, skew runs a
    diagonal wavefront — the policy engine's motivating case."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, ni), (0, nj)),
    )


def unskewable(ni=6, nj=12):
    """{(1,-4), (1,4)}: the feasible-row cone degenerates to (a, 0) rows
    inside the bounded entry range, so no det-±1 matrix exists — forced
    skew must fall back to chunking."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (-1, 4)), ArrayRef("a", (-1, -4))),
            ),
        ),
        bounds=((0, ni), (0, nj)),
    )


def random_distances(seed: int):
    """Lexicographically positive 2-D distance sets biased to mixed signs
    (the analyzer only ever retains lex-non-negative distances)."""

    rng = random.Random(seed)
    dists = []
    for _ in range(rng.randint(1, 4)):
        di = rng.randint(0, 2)
        dj = rng.randint(-3, 3) if di > 0 else rng.randint(0, 3)
        if di == 0 and dj == 0:
            dj = 1
        dists.append((di, dj))
    return dists


# ---------------------------------------------------------------------- #
# Skew legality properties (seeded — always run)
# ---------------------------------------------------------------------- #

class TestSkewLegality:
    def _assert_legal(self, dists, ndim=2, box=None):
        mat = find_unimodular_skew(dists, ndim)
        if mat is None:
            return None
        # (1) unimodular: determinant is exactly ±1
        assert mat_det(mat) in (1, -1)
        # (2) every transformed distance is per-dimension non-negative
        # (implies lexicographic non-negativity), and non-zero distances
        # stay non-zero (a bijection cannot collapse a dependence)
        for d in dists:
            td = mat_vec(mat, d)
            assert all(x >= 0 for x in td), (mat, d, td)
            if any(x != 0 for x in d):
                assert any(x != 0 for x in td)
        # (3) round-tripped instance coordinates are bijective on the
        # iteration space: unskew(skew(p)) == p pointwise and the image has
        # full cardinality (injectivity)
        box = box or [range(-2, 4)] * ndim
        pts = list(itertools.product(*box))
        image = {skew_point(mat, p) for p in pts}
        assert len(image) == len(pts)
        for p in pts:
            assert unskew_point(mat, skew_point(mat, p)) == p
        return mat

    @pytest.mark.parametrize("seed", range(40))
    def test_seeded_random_distance_sets(self, seed):
        self._assert_legal(random_distances(seed))

    def test_identity_when_already_nonnegative(self):
        assert find_unimodular_skew([(1, 0), (0, 2)], 2) == ((1, 0), (0, 1))
        assert find_unimodular_skew([(2,), (1,)], 1) == ((1,),)

    def test_classic_skew_found_and_legal(self):
        mat = self._assert_legal([(1, -1)])
        assert mat is not None

    def test_wide_serializer_distances_skewable(self):
        assert self._assert_legal([(0, 1), (1, -1)]) is not None

    def test_infeasible_cone_returns_none(self):
        assert find_unimodular_skew([(1, -4), (1, 4)], 2) is None

    def test_one_dimensional_negative_has_no_skew(self):
        # 1-D retained distances are validated lex-non-negative upstream;
        # a genuinely negative one admits no 1-D unimodular fix
        assert find_unimodular_skew([(-1,)], 1) is None

    def test_three_dimensional_elementary_search(self):
        mat = self._assert_legal(
            [(1, -1, 0), (0, 1, 0), (0, 0, 1)], ndim=3,
            box=[range(-1, 3)] * 3,
        )
        assert mat is not None

    @given(st.integers(min_value=0, max_value=10**9))
    @settings(
        max_examples=50,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_property_skew_legality(self, seed):
        self._assert_legal(random_distances(seed))


# ---------------------------------------------------------------------- #
# Strategy selection / cost model
# ---------------------------------------------------------------------- #

class TestStrategySelection:
    def test_cost_model_picks_skew_on_wide_serialized_recurrence(self):
        prog = wide_serialized(6, 24)
        part = analyze_sccs(prog, carried(prog))
        (rec,) = part.recurrences
        assert rec.strategy == "skew"
        assert rec.skew is not None
        assert "cost model picked skew" in rec.reason
        # the skewed depth must beat the serialized chunk depth recorded
        # in the scoreboard
        chunk_part = analyze_sccs(prog, carried(prog), scc_policy="chunk")
        assert rec.cost < chunk_part.recurrences[0].cost

    def test_cost_model_falls_back_to_chunk_when_skew_infeasible(self):
        prog = unskewable()
        part = analyze_sccs(prog, carried(prog))
        (rec,) = part.recurrences
        assert rec.strategy in ("chunk", "dswp")  # skew must not appear
        assert rec.skew is None

    def test_forced_skew_on_unskewable_scc_falls_back_with_reason(self):
        prog = unskewable()
        part = analyze_sccs(prog, carried(prog), scc_policy="skew")
        (rec,) = part.recurrences
        assert rec.strategy == "chunk"
        assert "infeasible" in rec.reason and "fell back to chunk" in rec.reason

    def test_forced_strategies_are_recorded(self):
        prog = skew_stencil()
        for name in ("chunk", "skew", "dswp"):
            part = analyze_sccs(prog, carried(prog), scc_policy=name)
            (rec,) = part.recurrences
            assert rec.strategy == name
            assert part.policy == name

    def test_non_doall_models_keep_chunking(self):
        """Skew/dswp plans decline non-doall models (per-processor free
        orders already serialize the lanes), so the hybrid behaves exactly
        as before: chunk 1 under dswp."""

        prog = skew_stencil(6, 9)
        part = analyze_sccs(prog, carried(prog), model="dswp")
        (rec,) = part.recurrences
        assert rec.strategy == "chunk"
        assert rec.chunk == 1

    def test_custom_policy_instance_plugs_in(self):
        class SkewOnly(UnimodularSkew):
            name = "skew-only"

        prog = skew_stencil()
        part = analyze_sccs(prog, carried(prog), scc_policy=SkewOnly())
        assert part.recurrences[0].strategy == "skew"
        assert part.policy == "skew-only"

    def test_report_summary_carries_strategy_and_reason(self):
        rep = plan(wide_serialized(5, 16), method="isd").compile("wavefront").report()
        (rec,) = rep.summary()["scc"]["recurrences"]
        assert rec["strategy"] == "skew"
        assert rec["skew"] is not None
        assert "cost model" in rec["reason"]
        assert rep.summary()["scc"]["policy"] == "auto"
        # threaded backend (no schedule) surfaces the same strategy record
        rep_t = plan(wide_serialized(5, 16), method="isd").compile("threaded").report()
        assert rep_t.summary()["scc"]["recurrences"][0]["strategy"] == "skew"

    def test_policy_signature_distinguishes_but_is_stable(self):
        assert policy_signature(None) == policy_signature("auto")
        assert policy_signature("skew") != policy_signature("chunk")
        assert policy_signature("skew") != policy_signature(None)
        assert policy_signature(CostModelPolicy()) == policy_signature("auto")
        assert policy_signature(
            CostModelPolicy(candidates=(ChunkedDoacross(),))
        ) != policy_signature("auto")

    def test_structural_key_covers_custom_policy_state(self):
        """The compile-cache key canonicalizes policy instance state, so
        differently-configured instances of one custom class never alias
        one artifact (and equal configurations do share one)."""

        from repro.compile.structure import structural_key

        class ThresholdPolicy(ChunkedDoacross):
            name = "threshold"

            def __init__(self, threshold):
                self.threshold = threshold

        prog = skew_stencil(4, 4)
        deps = tuple(carried(prog))
        k1 = structural_key(prog, deps, scc_policy=ThresholdPolicy(1))
        k9 = structural_key(prog, deps, scc_policy=ThresholdPolicy(9))
        k1b = structural_key(prog, deps, scc_policy=ThresholdPolicy(1))
        assert k1 != k9
        assert k1 == k1b
        assert structural_key(prog, deps) == structural_key(
            prog, deps, scc_policy="auto"
        )
        assert structural_key(prog, deps, scc_policy="skew") != structural_key(
            prog, deps, scc_policy="chunk"
        )

    def test_resolve_policy_validation(self):
        with pytest.raises(ValueError, match="unknown scc_policy"):
            resolve_policy("diagonal")
        with pytest.raises(ValueError, match="SchedulingPolicy"):
            resolve_policy(42)
        assert resolve_policy(PerSccModel()).name == "dswp"


class TestEntryValidation:
    @pytest.mark.parametrize("bad", (0, -1, -100, True, 2.5, "4"))
    def test_rejects_non_positive_or_non_int_chunk_limit(self, bad):
        # at PlanOptions construction ...
        with pytest.raises(ValueError, match="chunk_limit"):
            plan(skew_stencil(), chunk_limit=bad)
        # ... and at compile-time override
        with pytest.raises(ValueError, match="chunk_limit"):
            plan(skew_stencil()).compile("wavefront", chunk_limit=bad)

    def test_rejects_unknown_policy_before_any_analysis(self):
        with pytest.raises(ValueError, match="scc_policy"):
            plan(skew_stencil(), scc_policy="wavefrontish")
        with pytest.raises(ValueError, match="scc_policy"):
            plan(skew_stencil()).compile(
                "wavefront", scc_policy="wavefrontish"
            )

    def test_valid_knobs_accepted_where_declared(self):
        rep = plan(skew_stencil()).compile(
            "wavefront", chunk_limit=2, scc_policy="chunk"
        ).report()
        assert rep.chunk_limit == 2

    def test_undeclared_knob_rejected_not_silently_dropped(self):
        """The capability contract: the threaded machine declares no
        scheduling knobs, so passing one errors instead of doing nothing
        (the old behavior silently filtered it away)."""

        with pytest.raises(ValueError, match="threaded.*chunk_limit"):
            plan(skew_stencil(), chunk_limit=2).compile("threaded")


# ---------------------------------------------------------------------- #
# Differential: every strategy bit-equal on both fast backends
# ---------------------------------------------------------------------- #

STRATEGY_PROGRAMS = [
    ("skew_stencil", skew_stencil(5, 6)),
    ("wide_serialized", wide_serialized(4, 9)),
    ("unskewable", unskewable(4, 11)),
]


class TestStrategyDifferential:
    @pytest.mark.parametrize("policy", ("chunk", "skew", "dswp"))
    @pytest.mark.parametrize(
        "name,prog", STRATEGY_PROGRAMS, ids=[n for n, _ in STRATEGY_PROGRAMS]
    )
    def test_forced_strategy_bit_equal_fast_backends(self, name, prog, policy):
        """ISSUE acceptance: a Δ=(1,-1)-style skewable recurrence (and the
        rest of the zoo) runs bit-equal to the sequential oracle on
        wavefront AND xla under every forced strategy."""

        from repro.compile import run_xla

        rep = plan(prog, method="isd").compile("wavefront", scc_policy=policy).report()
        out_wf = run_wavefront(
            rep.optimized_sync, schedule=rep.wavefront, compare=True
        )
        assert out_wf.matches_sequential, ("wavefront", name, policy)
        out_xla = run_xla(
            rep.optimized_sync, schedule=rep.wavefront, compare=True
        )
        assert out_xla.matches_sequential, ("xla", name, policy)

    def test_auto_policy_through_full_oracle_matrix(self):
        assert_equivalent(wide_serialized(4, 7), methods=("none", "isd"))

    @pytest.mark.parametrize("seed", range(12))
    def test_seeded_random_forced_skew_bit_equal(self, seed):
        """Random cyclic programs under forced skew (with chunk fallback
        where infeasible) must stay bit-equal on the NumPy backend."""

        rng = random.Random(seed)
        stmts = []
        arrays = ["a", "b", "c"]
        for k in range(rng.randint(1, 3)):
            reads = tuple(
                ArrayRef(
                    rng.choice(arrays),
                    (-rng.randint(0, 1), rng.randint(-2, 2)),
                )
                for _ in range(rng.randint(1, 3))
            )
            stmts.append(
                Statement(
                    f"S{k+1}", ArrayRef(rng.choice(arrays), (0, 0)), reads
                )
            )
        prog = LoopProgram(
            statements=tuple(stmts),
            bounds=((0, rng.randint(3, 4)), (0, rng.randint(3, 5))),
        )
        for policy in ("skew", "dswp"):
            rep = plan(prog, method="isd").compile("wavefront", scc_policy=policy).report()
            out = run_wavefront(
                rep.optimized_sync, schedule=rep.wavefront, compare=True
            )
            assert out.matches_sequential, (seed, policy)

    @given(st.integers(min_value=0, max_value=10**9))
    @settings(
        max_examples=20,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_property_cost_model_choice_matches_oracle(self, seed):
        rng = random.Random(seed)
        ni, nj = rng.randint(3, 5), rng.randint(3, 6)
        prog = wide_serialized(ni, nj) if seed % 2 else skew_stencil(ni, nj)
        rep = plan(prog, method="isd").compile("wavefront").report()
        out = run_wavefront(rep.optimized_sync, schedule=rep.wavefront)
        assert out.matches_sequential


# ---------------------------------------------------------------------- #
# Schedule geometry under skew
# ---------------------------------------------------------------------- #

class TestSkewGeometry:
    def test_skew_depth_beats_chunk_depth_on_wide_inner_dim(self):
        prog = wide_serialized(6, 48)
        wf_auto = plan(prog, method="isd").compile("wavefront").report().wavefront
        wf_chunk = plan(prog, method="isd").compile("wavefront", scc_policy="chunk").report().wavefront
        assert wf_auto.scc.recurrences[0].strategy == "skew"
        # chunk=1 serializes all iterations; skew is a diagonal wavefront
        assert wf_chunk.depth == 6 * 48
        assert wf_auto.depth < wf_chunk.depth / 2

    def test_skew_schedule_covers_every_instance_exactly_once(self):
        prog = wide_serialized(5, 13)
        wf = plan(prog, method="isd").compile("wavefront").report().wavefront
        seen = [
            it for level in wf.levels for g in level for it in g.iterations
        ]
        assert len(seen) == len(set(seen)) == 5 * 13

    def test_every_dep_edge_strictly_increases_level_under_skew(self):
        prog = wide_serialized(5, 9)
        rep = plan(prog, method="isd").compile("wavefront", scc_policy="skew").report()
        wf = rep.wavefront
        lvl = wf.level_of()
        for d in wf.retained:
            for it in prog.iterations():
                dst = tuple(x + dd for x, dd in zip(it, d.distance))
                if (d.sink, dst) in lvl:
                    assert lvl[(d.source, it)] < lvl[(d.sink, dst)]
