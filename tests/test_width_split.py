"""Width-split band lowering (ROADMAP 3b, :mod:`repro.compile.lowering`).

A recurrence band's ramp-up/ramp-down levels run at sliced lane widths (the
"width ladder"), so a skewed diamond stops paying the plateau's padded lane
count on every level.  Contracts:

* **Bit-equality** — split and unsplit lowerings produce identical stores,
  and both match the sequential oracle across elimination methods (the
  sliced-away lanes are masked padding, so this is structural).
* **Degenerate bands stay byte-identical** — a uniform band (every row as
  wide as the plateau) appends no cut points: its dynamic vector, and
  therefore its trace, is exactly yesterday's.
* **Bucket identity survives** — the ladder depth is derived from the
  dynamic vector's *shape* (a bucket component), so bounds sharing a bucket
  still share one trace (PR 8's zero-re-trace property).
* **SPMD opts out** — the sharded artifact's per-shard lane slicing needs
  full padded widths; its ``_band_rungs`` hook pins the ladder off.
"""

from __future__ import annotations

import numpy as np
import pytest

import repro.obs as obs
from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    analyze,
    insert_synchronization,
)
from repro.core.wavefront import _DenseStore
from repro.compile import lowering
from repro.compile.cache import CompileCache
from repro.compile.executor import run_xla

from oracle import assert_equivalent


@pytest.fixture(autouse=True)
def _clean():
    obs.reset_all()
    yield
    obs.reset_all()


def _serialized_skew(ni, nj):
    """One statement carrying {(0,1), (1,-1)} — skewed into a diagonal
    wavefront whose band widths ramp 1, 2, … up to the plateau and back."""

    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, ni), (0, nj)),
    )


def _prepare(prog, scc_policy, cache=None):
    sync = insert_synchronization(prog, analyze(prog))
    store = prog.initial_store()
    cache = cache if cache is not None else CompileCache()
    rep = run_xla(
        sync, cache=cache, scc_policy=scc_policy, compare=False, store=store
    )
    dense = _DenseStore({a: dict(c) for a, c in store.items()})
    case, _ = rep.compiled.prepare(sync.program, dense)
    return rep, case


def _rec_dyns(case):
    return [
        dyn
        for seg, dyn in zip(case.static.segments or (), case.seg_dyn)
        if seg[0] == "rec"
    ]


# ---------------------------------------------------------------------- #
# The ladder itself
# ---------------------------------------------------------------------- #

def test_ladder_cuts_are_monotone_and_fit_the_ramp():
    rep, case = _prepare(_serialized_skew(48, 96), "skew")
    assert rep.matches_sequential
    (dyn,) = _rec_dyns(case)
    (seg,) = [s for s in case.static.segments if s[0] == "rec"]
    n_stmts = len(seg[1])
    L = (dyn.shape[0] - 1 - n_stmts) // 2
    assert L == lowering.WIDTH_LADDER_RUNGS  # wide enough for a full ladder
    n = int(dyn[0])
    cuts = [int(c) for c in dyn[1 + n_stmts:]]
    # monotone: 0 <= P_1 <= ... <= P_L <= Q_L <= ... <= Q_1 <= n
    assert all(a <= b for a, b in zip([0] + cuts, cuts + [n]))
    # narrowest rung holds at least WIDTH_LADDER_MIN lanes
    assert cuts[0] > 0 and cuts[-1] < n


def test_split_bit_equal_to_unsplit_and_oracle(monkeypatch):
    prog = _serialized_skew(40, 80)
    rep_split, case_split = _prepare(prog, "skew")
    assert _rec_dyns(case_split)[0].shape[0] > 2  # ladder engaged

    monkeypatch.setattr(lowering, "WIDTH_LADDER_RUNGS", 0)
    rep_unsplit, case_unsplit = _prepare(prog, "skew")
    assert _rec_dyns(case_unsplit)[0].shape[0] == 2  # [run, row0]
    monkeypatch.undo()

    assert rep_split.matches_sequential
    assert rep_unsplit.matches_sequential
    assert rep_split.store == rep_unsplit.store


def test_full_corpus_equivalence_with_ladder_active():
    """The canonical differential harness over programs whose bands ramp —
    every registered backend, naive and optimized sync, bit-for-bit."""

    assert_equivalent(_serialized_skew(20, 40), threaded=False)
    # mixed-sign diagonal recurrence (chunked ramp + tail)
    assert_equivalent(
        LoopProgram(
            statements=(
                Statement(
                    "S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)
                ),
            ),
            bounds=((0, 24), (0, 12)),
        ),
        threaded=False,
    )


def test_uniform_band_appends_no_cuts():
    """A chunked DOACROSS whose chunks all fill the padded width exactly —
    the dynamic vector (hence the trace) must be byte-identical to the
    pre-ladder lowering."""

    # mixed-sign (1,-1) over 15×16: chunk 15 tiles the 240 iterations into
    # 16 equal rows — every row as wide as the plateau, nothing to split
    prog = LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
        ),
        bounds=((0, 15), (0, 16)),
    )
    sync = insert_synchronization(prog, analyze(prog))
    store = prog.initial_store()
    cache = CompileCache()
    rep = run_xla(
        sync, cache=cache, scc_policy="chunk", compare=False, store=store
    )
    dense = _DenseStore({a: dict(c) for a, c in store.items()})
    case, _ = rep.compiled.prepare(sync.program, dense)
    (dyn,) = _rec_dyns(case)
    (seg,) = [s for s in case.static.segments if s[0] == "rec"]
    assert dyn.shape[0] == 1 + len(seg[1])  # [run, row bases] — no cuts


def test_bucket_identity_and_zero_retrace_with_ladder():
    cache = CompileCache()
    prog_a = _serialized_skew(48, 96)
    rep_a, _ = _prepare(prog_a, "skew", cache=cache)
    comp = rep_a.compiled
    assert comp.trace_count == 1
    # same bucket (47/95 pad to the same shapes): tables rebuild, trace
    # does not
    prog_b = _serialized_skew(47, 95)
    sync_b = insert_synchronization(prog_b, analyze(prog_b))
    rep_b = run_xla(
        sync_b,
        cache=cache,
        scc_policy="skew",
        compare=False,
        store=prog_b.initial_store(),
    )
    assert rep_b.compiled is comp
    assert comp.trace_count == 1
    assert comp.bucket_count == 1


def test_spmd_pins_the_ladder_off():
    from repro.compile.spmd import SpmdCompiledProgram

    assert SpmdCompiledProgram._band_rungs(object(), 4096) == 0
    # the base artifact ladders the same width
    assert lowering.CompiledProgram._band_rungs(object(), 4096) == 3


def test_lane_cap_never_exceeds_statement_width():
    """Multi-statement band shapes: a statement narrower than the band
    plateau is never sliced below its own padded width (the cut search
    clamps per statement)."""

    rep, case = _prepare(_serialized_skew(16, 128), "skew")
    assert rep.matches_sequential
    for seg, dyn in zip(case.static.segments, case.seg_dyn):
        if seg[0] != "rec":
            continue
        n_stmts = len(seg[1])
        L = (dyn.shape[0] - 1 - n_stmts) // 2
        wpb = max(
            case.tables[k]["lanemask"].shape[1] for k in seg[1]
        )
        for i in range(L):
            assert wpb >> (L - i) >= lowering.WIDTH_LADDER_MIN
