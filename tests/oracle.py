"""Differential-testing harness: one entry point that runs a LoopProgram
through every *registered* execution backend × synchronization variant and
asserts bit-equality.

The executors (see ROADMAP "Execution backends"):

  * ``run_sequential`` — the semantic oracle, always authoritative;
  * ``threaded``   — the paper's machine (one thread per iteration,
    send/wait only), authoritative for sync *sufficiency* under races;
  * ``wavefront``  — the NumPy level-schedule interpreter;
  * ``xla``        — the structurally cached jitted level loop
    (:mod:`repro.compile`), authoritative for nothing by itself — which is
    exactly why every later PR's tests route through this harness instead of
    trusting it.

Backends are discovered through the parallelizer registry
(:func:`repro.core.execution_backends`), so registering a new backend makes
it differentially tested here with zero per-test changes.

``assert_equivalent`` is the canonical check: for each elimination method it
builds naive and optimized sync programs and demands that every registered
backend reproduces the sequential store bit-for-bit from the same initial
memory image.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core import (
    LoopProgram,
    execution_backends,
    plan,
    run_sequential,
)

METHODS = ("none", "isd", "pattern", "both")


def _backend_names(
    backends: Optional[Sequence[str]], threaded: bool
) -> Tuple[str, ...]:
    known = tuple(execution_backends())
    names = tuple(backends) if backends is not None else known
    unknown = [n for n in names if n not in known]
    if unknown:
        raise ValueError(
            f"unknown backend(s) {unknown}; registered: {known}"
        )
    if not threaded:
        names = tuple(n for n in names if n != "threaded")
    if not names:
        raise ValueError(
            "no backends left to compare — a differential run against "
            "nothing would pass vacuously"
        )
    return names


def run_all_backends(
    prog: LoopProgram,
    *,
    methods: Sequence[str] = METHODS,
    stalls: Optional[Mapping[Tuple[str, Tuple[int, ...]], float]] = None,
    threaded: bool = True,
    store: Optional[Mapping[str, dict]] = None,
    backends: Optional[Sequence[str]] = None,
) -> Dict[str, dict]:
    """Execute ``prog`` on every registered backend × method.

    Returns label → store with labels ``sequential`` and
    ``<backend>/<method>/<naive|optimized>``.  All runs start from the same
    initial memory image, so stores are comparable cell for cell.
    ``threaded=False`` drops the (slow) thread machine; ``backends`` narrows
    the set explicitly.
    """

    names = _backend_names(backends, threaded)
    specs = execution_backends()
    init = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    results: Dict[str, dict] = {
        "sequential": run_sequential(prog, init),
    }
    for method in methods:
        # staged pipeline: ONE analysis per method, then one compile per
        # backend — the optimized variant executes through Executable.run
        # (the uniform run contract), the naive variant through the
        # backend's raw differential hook (it is not a plan product)
        p = plan(prog, method=method)
        for name in names:
            exe = p.compile(name)
            results[f"{name}/{method}/optimized"] = exe.run(
                store=init, stalls=stalls
            )
            results[f"{name}/{method}/naive"] = specs[name].differential(
                p.naive_sync, store=init, stalls=stalls
            )
    return results


def assert_equivalent(
    prog: LoopProgram,
    *,
    methods: Sequence[str] = METHODS,
    stalls: Optional[Mapping[Tuple[str, Tuple[int, ...]], float]] = None,
    threaded: bool = True,
    backends: Optional[Sequence[str]] = None,
) -> Dict[str, dict]:
    """Assert every backend/variant reproduces the sequential store exactly.

    Returns the result dict so callers can make further assertions (e.g. on
    specific cells).  Failure messages name the first diverging backend and
    cell, which is what you want from a differential harness at 2 a.m.
    """

    results = run_all_backends(
        prog,
        methods=methods,
        stalls=stalls,
        threaded=threaded,
        backends=backends,
    )
    expect = results["sequential"]
    for label, store in results.items():
        if label == "sequential":
            continue
        assert store == expect, (
            f"{label} diverged from sequential execution: "
            f"{_first_divergence(expect, store)}"
        )
    return results


def _first_divergence(expect: dict, got: dict) -> str:
    for arr in expect:
        if arr not in got:
            return f"array {arr!r} missing"
        for idx, v in expect[arr].items():
            g = got[arr].get(idx)
            if g != v:
                return f"{arr}{list(idx)}: expected {v!r}, got {g!r}"
    return "stores have equal cells but unequal structure"
