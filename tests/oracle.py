"""Differential-testing harness: one entry point that runs a LoopProgram
through every executor × synchronization variant and asserts bit-equality.

The three executors (see ROADMAP "Execution backends"):

  * ``run_sequential`` — the semantic oracle, always authoritative;
  * ``run_threaded``   — the paper's machine (one thread per iteration,
    send/wait only), authoritative for sync *sufficiency* under races;
  * ``run_wavefront``  — the fast static-schedule backend, authoritative
    for nothing by itself — which is exactly why every later PR's tests
    route through this harness instead of trusting it.

``assert_equivalent`` is the canonical check: for each elimination method it
builds naive and optimized sync programs and demands that threaded and
wavefront execution both reproduce the sequential store bit-for-bit from the
same initial memory image.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core import (
    LoopProgram,
    parallelize,
    run_sequential,
    run_threaded,
    run_wavefront,
)

METHODS = ("none", "isd", "pattern", "both")


def run_all_backends(
    prog: LoopProgram,
    *,
    methods: Sequence[str] = METHODS,
    stalls: Optional[Mapping[Tuple[str, Tuple[int, ...]], float]] = None,
    threaded: bool = True,
    store: Optional[Mapping[str, dict]] = None,
) -> Dict[str, dict]:
    """Execute ``prog`` on every backend × method; return label → store.

    Labels: ``sequential``, ``threaded/<method>/naive``,
    ``threaded/<method>/optimized``, ``wavefront/<method>/naive``,
    ``wavefront/<method>/optimized``.  All runs start from the same initial
    memory image, so stores are comparable cell for cell.
    """

    init = {a: dict(c) for a, c in (store or prog.initial_store()).items()}
    results: Dict[str, dict] = {
        "sequential": run_sequential(prog, init),
    }
    for method in methods:
        rep = parallelize(prog, method=method, backend="wavefront")
        variants = {"naive": rep.naive_sync, "optimized": rep.optimized_sync}
        for label, sync in variants.items():
            if threaded:
                t = run_threaded(sync, stalls=stalls, store=init, compare=False)
                results[f"threaded/{method}/{label}"] = t.store
            schedule = rep.wavefront if label == "optimized" else None
            w = run_wavefront(sync, schedule=schedule, store=init, compare=False)
            results[f"wavefront/{method}/{label}"] = w.store
    return results


def assert_equivalent(
    prog: LoopProgram,
    *,
    methods: Sequence[str] = METHODS,
    stalls: Optional[Mapping[Tuple[str, Tuple[int, ...]], float]] = None,
    threaded: bool = True,
) -> Dict[str, dict]:
    """Assert every backend/variant reproduces the sequential store exactly.

    Returns the result dict so callers can make further assertions (e.g. on
    specific cells).  Failure messages name the first diverging backend and
    cell, which is what you want from a differential harness at 2 a.m.
    """

    results = run_all_backends(
        prog, methods=methods, stalls=stalls, threaded=threaded
    )
    expect = results["sequential"]
    for label, store in results.items():
        if label == "sequential":
            continue
        assert store == expect, (
            f"{label} diverged from sequential execution: "
            f"{_first_divergence(expect, store)}"
        )
    return results


def _first_divergence(expect: dict, got: dict) -> str:
    for arr in expect:
        if arr not in got:
            return f"array {arr!r} missing"
        for idx, v in expect[arr].items():
            g = got[arr].get(idx)
            if g != v:
                return f"{arr}{list(idx)}: expected {v!r}, got {g!r}"
    return "stores have equal cells but unequal structure"
