"""Smoke tests running the examples' ``main()`` in-process, so the examples
cannot rot against API changes (they are the first thing a reader runs)."""

import importlib.util
import pathlib
import sys

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parents[1] / "examples"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(name, EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_main(capsys):
    mod = _load("quickstart")
    mod.main()
    out = capsys.readouterr().out
    assert "synchronization elimination" in out
    assert "threaded execution matches sequential: True" in out


def test_pipeline_demo_main(monkeypatch, capsys):
    mod = _load("pipeline_demo")
    monkeypatch.setattr(
        sys, "argv", ["pipeline_demo.py", "--stages", "4", "--microbatches", "4"]
    )
    mod.main()
    out = capsys.readouterr().out
    assert "matches sequential reference: True" in out


def test_serve_cyclic_plan_concurrent_replanning():
    """The serving path's recurrence-bearing scan rides the structural
    cache under concurrent re-planning: one artifact, counted hits for
    every wave after the first, and a recurrence strategy on the record."""

    import concurrent.futures
    import importlib

    from repro.compile import clear_compile_cache, compile_cache_stats

    serve = importlib.import_module("repro.launch.serve")
    clear_compile_cache()
    first = serve.plan_scan_sync(3, 4)  # cold: the one structural miss
    (rec,) = first.summary()["scc"]["recurrences"]
    assert rec["strategy"] in ("skew", "chunk", "dswp")
    assert rec["statements"] == ["RESCORE"]
    assert first.summary()["scc"]["policy"] == "auto"

    waves = 6
    with concurrent.futures.ThreadPoolExecutor(max_workers=4) as pool:
        plans = list(
            pool.map(lambda _: serve.plan_scan_sync(3, 4), range(waves))
        )
    # different bounds = same structure: still the same artifact
    other_bounds = serve.plan_scan_sync(5, 7)
    keys = {p.compiled.key for p in plans} | {
        first.compiled.key,
        other_bounds.compiled.key,
    }
    assert len(keys) == 1, "concurrent re-plans must share one artifact"
    stats = compile_cache_stats()
    assert stats["misses"] == 1
    assert stats["hits"] == waves + 1


def test_serve_concurrent_wave_planning_pairs_decode_and_scan():
    """plan_wave resolves the acyclic decode plan and the cyclic scan plan
    concurrently; repeated waves hit the cache for both structures."""

    import importlib

    from repro.compile import clear_compile_cache, compile_cache_stats

    serve = importlib.import_module("repro.launch.serve")
    clear_compile_cache()
    for _ in range(3):
        decode_plan, scan_plan, route_exe, rescore_exe = serve.plan_wave(4, 3)
    assert decode_plan.summary()["scc"]["recurrences"] == []
    assert scan_plan.summary()["scc"]["recurrences"]
    # the non-affine wave workloads ride the same structural cache: the
    # deps mode is part of the key, so inspect/speculate artifacts are
    # their own (single) entries
    assert route_exe.plan.options.deps == "inspect"
    assert rescore_exe.plan.options.deps == "speculate"
    stats = compile_cache_stats()
    assert stats["misses"] == 4  # one per structure, first wave only
    assert stats["hits"] == 8  # four hits per subsequent wave


@pytest.mark.slow
def test_serve_main(monkeypatch, capsys):
    """The serving driver end to end (smoke scale), including the per-wave
    sync plans riding the structural compile cache."""

    import importlib

    mod = importlib.import_module("repro.launch.serve")
    monkeypatch.setattr(
        sys,
        "argv",
        ["serve.py", "--arch", "yi_6b", "--requests", "6", "--slots", "3",
         "--max-new", "3"],
    )
    mod.main()
    out = capsys.readouterr().out
    assert "decode sync plan:" in out
    assert "compile cache" in out
    assert "cyclic scan plan:" in out
    assert "strategy=" in out
