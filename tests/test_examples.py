"""Smoke tests running the examples' ``main()`` in-process, so the examples
cannot rot against API changes (they are the first thing a reader runs)."""

import importlib.util
import pathlib
import sys

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parents[1] / "examples"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(name, EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_main(capsys):
    mod = _load("quickstart")
    mod.main()
    out = capsys.readouterr().out
    assert "synchronization elimination" in out
    assert "threaded execution matches sequential: True" in out


def test_pipeline_demo_main(monkeypatch, capsys):
    mod = _load("pipeline_demo")
    monkeypatch.setattr(
        sys, "argv", ["pipeline_demo.py", "--stages", "4", "--microbatches", "4"]
    )
    mod.main()
    out = capsys.readouterr().out
    assert "matches sequential reference: True" in out


@pytest.mark.slow
def test_serve_main(monkeypatch, capsys):
    """The serving driver end to end (smoke scale), including the per-wave
    sync plan riding the structural compile cache."""

    import importlib

    mod = importlib.import_module("repro.launch.serve")
    monkeypatch.setattr(
        sys,
        "argv",
        ["serve.py", "--arch", "yi_6b", "--requests", "6", "--slots", "3",
         "--max-new", "3"],
    )
    mod.main()
    out = capsys.readouterr().out
    assert "decode sync plan:" in out
    assert "compile cache" in out
