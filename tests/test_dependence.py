"""Dependence analysis — validated against the paper's Fig. 3a / Alg. 4 / Alg. 6."""

import pytest

from repro.core import (
    ANTI,
    FLOW,
    OUTPUT,
    ArrayRef,
    LoopProgram,
    Statement,
    analyze,
    loop_carried,
    paper_alg1,
    paper_alg4,
    paper_alg6,
)


def dep_set(deps):
    return {(d.kind, d.source, d.sink, d.array, d.distance) for d in deps}


class TestPaperAlg1:
    """Fig. 3(a): the acyclic example."""

    def test_exact_dependence_set(self):
        deps = analyze(paper_alg1())
        assert dep_set(deps) == {
            (FLOW, "S2", "S1", "b", (1,)),   # S1 reads b[i-1]
            (FLOW, "S2", "S3", "b", (0,)),   # S3 reads b[i] (loop-independent)
            (FLOW, "S1", "S3", "a", (1,)),   # S3 reads a[i-1]
            (FLOW, "S4", "S3", "d", (2,)),   # S3 reads d[i-2]
            (FLOW, "S2", "S4", "b", (2,)),   # S4 reads b[i-2]
        }

    def test_loop_carried_subset(self):
        deps = analyze(paper_alg1())
        carried = loop_carried(deps)
        assert all(d.loop_carried for d in carried)
        assert len(carried) == 4  # the Δ=0 S2→S3 dep is loop-independent


class TestPaperAlg4:
    """Fig. 5: the cyclic example."""

    def test_contains_papers_three_dependences(self):
        deps = dep_set(analyze(paper_alg4()))
        # the paper's stated graph: δf Δa=1, δf Δb=2, δf Δc=1
        assert (FLOW, "S1", "S3", "a", (1,)) in deps
        assert (FLOW, "S2", "S3", "b", (2,)) in deps
        assert (FLOW, "S3", "S2", "c", (1,)) in deps

    def test_analyzer_finds_the_dependence_the_paper_missed(self):
        """S1 reads b[i-1] which S2 writes — a real flow dependence with
        Δ=1 that Alg. 5 in the paper does not synchronize (see
        test_executor.py for the resulting race)."""

        deps = dep_set(analyze(paper_alg4()))
        assert (FLOW, "S2", "S1", "b", (1,)) in deps
        assert len(deps) == 4


class TestPaperAlg6:
    def test_exact_dependence_set(self):
        deps = analyze(paper_alg6())
        assert dep_set(deps) == {
            (FLOW, "S1", "S3", "a", (2,)),
            (FLOW, "S3", "S2", "c", (1,)),
        }


class TestOrientation:
    """The classical definitions: a raw negative distance flips the pair."""

    def test_anti_dependence(self):
        # S1 reads x[i+1]; S2 writes x[i]: read happens (i) before write (i+1)
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("y", 0), (ArrayRef("x", 1),)),
                Statement("S2", ArrayRef("x", 0), ()),
            ),
            bounds=((0, 4),),
        )
        deps = dep_set(analyze(prog))
        assert (ANTI, "S1", "S2", "x", (1,)) in deps

    def test_loop_independent_anti(self):
        # S1 reads x[i]; S2 (later) writes x[i]
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("y", 0), (ArrayRef("x", 0),)),
                Statement("S2", ArrayRef("x", 0), ()),
            ),
            bounds=((0, 4),),
        )
        deps = dep_set(analyze(prog))
        assert (ANTI, "S1", "S2", "x", (0,)) in deps

    def test_output_dependence(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("x", 0), ()),
                Statement("S2", ArrayRef("x", -1), ()),
            ),
            bounds=((1, 5),),
        )
        deps = dep_set(analyze(prog))
        # S1 writes x[i]; S2 writes x[j-1]: same cell when j = i+1 → S1 first
        assert (OUTPUT, "S1", "S2", "x", (1,)) in deps

    def test_self_flow_dependence(self):
        # recurrence a[i] = a[i-1]
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("a", -1),)),
            ),
            bounds=((1, 5),),
        )
        deps = dep_set(analyze(prog))
        assert (FLOW, "S1", "S1", "a", (1,)) in deps

    def test_flipped_flow_becomes_anti_with_positive_distance(self):
        # S2 writes b[i]; S1 (earlier lexically) reads b[i+2]: the read at
        # iteration i touches b[i+2], written at iteration i+2 → anti, Δ=2
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("y", 0), (ArrayRef("b", 2),)),
                Statement("S2", ArrayRef("b", 0), ()),
            ),
            bounds=((0, 6),),
        )
        deps = analyze(prog)
        for d in deps:
            assert all(x >= 0 for x in d.distance) or d.distance == (0,)
        assert (ANTI, "S1", "S2", "b", (2,)) in dep_set(deps)


class TestMultiDim:
    def test_2d_distances(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 0)),)),
                Statement("S2", ArrayRef("b", (0, 0)), (ArrayRef("a", (0, -2)),)),
            ),
            bounds=((0, 4), (0, 4)),
        )
        deps = dep_set(analyze(prog))
        assert (FLOW, "S1", "S1", "a", (1, 0)) in deps
        assert (FLOW, "S1", "S2", "a", (0, 2)) in deps


class TestControlDependence:
    """Paper §2.1: S_b is control dependent on S_a when whether S_b executes
    depends on S_a's outcome — modeled via guarded statements."""

    def _guarded(self, n=7):
        return LoopProgram(
            statements=(
                Statement("S1", ArrayRef("p", 0), (ArrayRef("a", -1),)),
                Statement(
                    "S2",
                    ArrayRef("a", 0),
                    (ArrayRef("b", -1),),
                    guard=ArrayRef("p", -1),
                ),
                Statement("S3", ArrayRef("b", 0), (ArrayRef("a", 0),)),
            ),
            bounds=((1, n),),
        )

    def test_control_dep_found(self):
        from repro.core import CONTROL

        deps = analyze(self._guarded())
        assert (CONTROL, "S1", "S2", "p", (1,)) in dep_set(deps)

    def test_guard_before_write_is_anti(self):
        # S1 reads p[i+1] as guard, S2 writes p[i] → anti S1→S2 Δ1
        prog = LoopProgram(
            statements=(
                Statement(
                    "S1", ArrayRef("y", 0), (), guard=ArrayRef("p", 1)
                ),
                Statement("S2", ArrayRef("p", 0), ()),
            ),
            bounds=((0, 5),),
        )
        assert (ANTI, "S1", "S2", "p", (1,)) in dep_set(analyze(prog))

    def test_guarded_execution_matches_sequential(self):
        from repro.core import insert_synchronization, run_threaded

        prog = self._guarded()
        sync = insert_synchronization(prog, analyze(prog))
        rep = run_threaded(sync, stalls={("S1", (2,)): 0.1})
        assert rep.matches_sequential

    def test_guarded_optimized_sync_matches(self):
        from repro.core import plan, run_threaded

        rep = plan(self._guarded(), method="both").compile("threaded").report()
        assert len(rep.elimination.eliminated) >= 1
        run = run_threaded(rep.optimized_sync, stalls={("S2", (1,)): 0.1})
        assert run.matches_sequential

    def test_missing_control_sync_races(self):
        """When δc is the ONLY dependence into the guarded statement,
        dropping its sync lets the guard be read stale — wrong results under
        an adversarial stall on the guard producer.  (In ``_guarded`` above
        the δc is transitively covered by the flow-sync chain — which the
        optimizer correctly detects and eliminates.)"""

        from repro.core import CONTROL, insert_synchronization, run_threaded

        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("p", 0), (), compute=lambda: 1.0),
                Statement(
                    "S2", ArrayRef("a", 0), (), guard=ArrayRef("p", -1)
                ),
            ),
            bounds=((1, 6),),
        )
        deps = analyze(prog)
        assert any(d.kind == CONTROL for d in deps)
        # stale guards must read negative so skipped≠executed is observable
        store = prog.initial_store()
        store["p"] = {k: -1.0 for k in store["p"]}

        synced = insert_synchronization(prog, deps)
        ok = run_threaded(synced, stalls={("S1", (1,)): 0.3}, store=store)
        assert ok.matches_sequential

        broken = insert_synchronization(
            prog, [d for d in deps if d.kind != CONTROL]
        )
        # the race needs the iteration-2 thread to win the guard read; under
        # CPU load the adversarial window can be missed — retry with longer
        # stalls until the mis-ordering manifests
        raced = False
        for stall in (0.3, 0.8, 1.5):
            rep = run_threaded(
                broken, stalls={("S1", (1,)): stall}, store=store
            )
            if not rep.matches_sequential:
                raced = True
                break
        assert raced
