"""The multi-tenant plan service (:mod:`repro.serve`).

Four contracts under test:

* **Options discipline** — ``ServiceOptions`` is frozen, hashable and
  eagerly validated: an unknown knob or a bad value fails at construction
  with a ValueError naming the accepted set (the same shape as the backend
  capability contracts).
* **Soak** — three program structures × two bucketed bounds each, twenty
  waves: after the warmup wave the ``xla.traces`` counter must not move
  (shape-bucketed traced artifacts — steady-state re-trace rate 0), a
  deliberately chatty tenant under a tight LRU cap must show evictions
  *without* disturbing the other tenants' plans, and mid-soak samples must
  stay bit-equal to the sequential oracle.
* **Concurrency** — six submitter threads racing mixed structures through
  one service keep the structural compile cache's miss count equal to the
  number of distinct structures (per-structure admission: a lost
  ``get_or_compile`` race would count a second miss).
* **Inspector memo on the serve path** (PR 6 follow-up) — waves that change
  only non-index data reuse the instance graph: the memo hit counter grows
  and the miss counter stays flat, including when a wave hands the same
  index pattern over as floats instead of ints (the content digest
  normalizes value types).
"""

from __future__ import annotations

import threading

import pytest

import repro.obs as obs
from repro.obs import metrics
from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    inspect_dependences,
    inspector_cache_stats,
    indexed_store,
    run_sequential,
)
from repro.serve import (
    PlanService,
    ServiceOptions,
    decode_program,
    plan_rescore_sync,
    scan_program,
)


def _doall_program(n: int) -> LoopProgram:
    """A dependence-free two-statement chain — the third soak structure."""

    return LoopProgram(
        statements=(
            Statement("A", ArrayRef("a", 0), (ArrayRef("b", 0),)),
            Statement("B", ArrayRef("c", 0), (ArrayRef("a", 0),)),
        ),
        bounds=((0, n),),
    )


def _fresh_initial(prog: LoopProgram) -> dict:
    return {a: dict(c) for a, c in prog.initial_store().items()}


# ---------------------------------------------------------------------- #
# ServiceOptions
# ---------------------------------------------------------------------- #

def test_service_options_rejects_unknown_knob_naming_accepted_set():
    with pytest.raises(ValueError) as exc:
        ServiceOptions(worker=4)  # typo for "workers"
    msg = str(exc.value)
    assert "'worker'" in msg
    # the accepted set is spelled out so the caller can fix the knob
    for name in (
        "backend",
        "workers",
        "plan_cache_capacity",
        "max_queue_depth",
        "default_tenant",
    ):
        assert name in msg


def test_service_options_validates_values():
    with pytest.raises(ValueError) as exc:
        ServiceOptions(backend="no-such-backend")
    assert "no-such-backend" in str(exc.value)
    for bad in ({"workers": 0}, {"plan_cache_capacity": 0},
                {"max_queue_depth": -1}, {"workers": True}):
        with pytest.raises(ValueError):
            ServiceOptions(**bad)
    with pytest.raises(ValueError):
        ServiceOptions(default_tenant="")


def test_service_options_frozen_and_hashable():
    opts = ServiceOptions(workers=3)
    assert opts.workers == 3
    assert opts.backend == "xla"  # defaults survive the custom __init__
    with pytest.raises(Exception):
        opts.workers = 5  # type: ignore[misc]
    assert hash(opts) == hash(ServiceOptions(workers=3))
    assert opts != ServiceOptions(workers=4)


# ---------------------------------------------------------------------- #
# Basic request surface
# ---------------------------------------------------------------------- #

def test_submit_runs_and_matches_oracle():
    obs.reset_all()
    with PlanService(ServiceOptions(workers=2)) as svc:
        prog = decode_program(8)
        res = svc.submit(prog, tenant="t0", run=True).result()
        assert res.tenant == "t0"
        assert res.plan_cached is False
        assert res.store == run_sequential(prog, _fresh_initial(prog))
        # same structure+bounds again: plan-LRU hit
        res2 = svc.submit(prog, tenant="t0", run=True).result()
        assert res2.plan_cached is True
        assert res2.store == res.store
        stats = svc.drain()
        t0_stats = dict(stats["tenants"]["t0"])
        assert t0_stats.pop("bytes") > 0  # artifact entries are byte-accounted
        assert t0_stats == {
            "size": 1, "hits": 1, "misses": 1, "evictions": 0,
        }
        assert stats["submitted"] == stats["completed"] == 2
        # the second request reused the cached compiled artifact
        assert metrics.counter("plan_cache.artifact_hits").value == 1


def test_admission_bound_and_close_reject():
    obs.reset_all()
    svc = PlanService(ServiceOptions(workers=1, max_queue_depth=1))
    prog = _doall_program(8)
    from repro.compile.structure import program_fingerprint

    # hold the structure's admission lock so the first request parks in
    # resolve() — the admission bound is then observable deterministically
    gate = svc._structure_lock(program_fingerprint(prog))
    gate.acquire()
    try:
        first = svc.submit(prog, tenant="t")
        with pytest.raises(RuntimeError) as exc:
            svc.submit(prog, tenant="t")
        assert "max_queue_depth" in str(exc.value)
    finally:
        gate.release()
    assert first.result().plan is not None
    svc.close()
    with pytest.raises(RuntimeError) as exc:
        svc.submit(prog, tenant="t")
    assert "closed" in str(exc.value)
    svc.close()  # idempotent


def test_deadline_drops_expired_queued_request():
    """A request still queued past its ``deadline_ms`` is dropped at
    dequeue (future fails, ``serve.deadline_drops`` counts it) while the
    request occupying the worker runs to completion.  Deterministic: with
    one worker, the deadlined request cannot start until the first request
    finishes, and the first request is parked on the structure admission
    lock until well past the deadline."""

    import time

    obs.reset_all()
    svc = PlanService(ServiceOptions(workers=1, max_queue_depth=4))
    prog = _doall_program(8)
    from repro.compile.structure import program_fingerprint

    gate = svc._structure_lock(program_fingerprint(prog))
    gate.acquire()
    try:
        first = svc.submit(prog, tenant="t")
        doomed = svc.submit(prog, tenant="t", deadline_ms=1.0)
        # hold the gate until the deadline has certainly expired
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < 0.02:
            time.sleep(0.005)
    finally:
        gate.release()
    assert first.result().plan is not None
    with pytest.raises(RuntimeError) as exc:
        doomed.result()
    assert "deadline" in str(exc.value)
    stats = svc.drain()
    assert stats["deadline_drops"] == 1
    assert metrics.counter("serve.deadline_drops").value == 1
    # a request that starts before its deadline is NOT preempted
    ok = svc.submit(prog, tenant="t", deadline_ms=60_000.0).result()
    assert ok.plan is not None
    svc.close()


def test_deadline_ms_validation():
    svc = PlanService(ServiceOptions(workers=1))
    prog = _doall_program(8)
    for bad in (0, -1, -0.5, True, "5"):
        with pytest.raises(ValueError):
            svc.submit(prog, deadline_ms=bad)
    svc.close()


# ---------------------------------------------------------------------- #
# The soak: re-trace rate 0 + evictions + mid-soak oracle samples
# ---------------------------------------------------------------------- #

def test_soak_zero_retraces_after_warmup():
    obs.reset_all()
    # (tenant, program factory, two bounds variants in the same or adjacent
    # power-of-two buckets)
    structures = [
        ("decode", decode_program, (12, 13)),
        ("scan", lambda h: scan_program(3, h), (4, 5)),
        ("doall", _doall_program, (16, 17)),
    ]
    waves = 20
    with PlanService(
        ServiceOptions(workers=2, plan_cache_capacity=2)
    ) as svc:
        # warmup wave: every (structure, bounds) pair runs once, paying
        # whatever jit traces its buckets need
        scan_exe = None
        for tenant, make, bounds in structures:
            for b in bounds:
                res = svc.submit(make(b), tenant=tenant, run=True).result()
                if tenant == "scan":
                    scan_exe = res.executable
        svc.drain()
        traces_warm = metrics.counter("xla.traces").value
        assert traces_warm > 0  # warmup actually traced something

        # the two scan bounds (horizon 4 and 5) pad into the SAME bucket:
        # one jit trace serves both — the tentpole's core claim
        assert scan_exe is not None
        assert scan_exe.compiled.trace_count == 1
        assert scan_exe.compiled.bucket_count == 1

        # soak: 20 waves over the warm set; the "mixed" tenant replays all
        # six keys through its capacity-2 LRU every wave (guaranteed
        # eviction churn) without touching the per-structure tenants
        for wave in range(waves):
            results = []
            for tenant, make, bounds in structures:
                prog = make(bounds[wave % 2])
                sample = wave in (5, 10, 15)
                results.append(
                    (prog, svc.submit(prog, tenant=tenant, run=sample))
                )
                svc.submit(prog, tenant="mixed")
            for prog, fut in results:
                res = fut.result()
                if res.store is not None:  # sampled wave: oracle check
                    assert res.store == run_sequential(
                        prog, _fresh_initial(prog)
                    ), f"soak diverged from oracle at wave {wave}"
        stats = svc.drain()

    # steady state: not a single new jit trace across all 20 waves
    assert metrics.counter("xla.traces").value == traces_warm
    # ...and the warm executions were bucket hits
    assert metrics.counter("xla.bucket_hits").value > 0

    # the chatty tenant churned its tight LRU...
    assert stats["tenants"]["mixed"]["evictions"] > 0
    assert stats["plan_cache"]["evictions"] > 0
    assert metrics.counter("plan_cache.evictions").value > 0
    # ...while the per-structure tenants stayed hot and untouched
    for tenant in ("decode", "scan", "doall"):
        assert stats["tenants"][tenant]["evictions"] == 0
        assert stats["tenants"][tenant]["hits"] >= waves
        assert stats["tenants"][tenant]["misses"] == 2  # the two bounds
    assert stats["plan_cache"]["size"] <= 4 * 2  # per-tenant bound held
    # the snapshot is the SERVE_sync artifact: it must be JSON-able
    import json

    json.dumps(stats)


# ---------------------------------------------------------------------- #
# Concurrency: structural misses == distinct structures under racing
# submitters
# ---------------------------------------------------------------------- #

def test_six_submitters_keep_structural_misses_at_distinct_structures():
    obs.reset_all()
    from repro.compile import compile_cache_stats

    programs = [decode_program(9), scan_program(3, 6), _doall_program(11)]
    n_threads, per_thread = 6, 8
    with PlanService(ServiceOptions(workers=4)) as svc:
        barrier = threading.Barrier(n_threads)
        futures, errs = [], []
        lock = threading.Lock()

        def submitter(tid: int) -> None:
            barrier.wait()  # maximize the race on the cold structures
            try:
                batch = [
                    svc.submit(
                        programs[(tid + k) % len(programs)], tenant=f"t{tid}"
                    )
                    for k in range(per_thread)
                ]
                with lock:
                    futures.extend(batch)
            except Exception as e:  # pragma: no cover - failure reporting
                with lock:
                    errs.append(e)

        threads = [
            threading.Thread(target=submitter, args=(i,))
            for i in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        for fut in futures:
            assert fut.result().executable is not None
        stats = svc.drain()

    cc = compile_cache_stats()
    # per-structure admission: every cold structure was planned and lowered
    # exactly once, no matter how many submitters raced it
    assert cc["misses"] == len(programs), cc
    # every other request was served without lowering: either a structural
    # compile-cache hit or (for a same-tenant repeat) an artifact-level hit
    # that skipped compile() entirely
    art = metrics.counter("plan_cache.artifact_hits").value
    assert cc["hits"] + art == n_threads * per_thread - len(programs), (cc, art)
    assert stats["completed"] == n_threads * per_thread


# ---------------------------------------------------------------------- #
# Inspector memo across serve waves (PR 6 follow-up)
# ---------------------------------------------------------------------- #

def test_inspector_memo_hits_across_waves_with_changed_nonindex_data():
    obs.reset_all()
    exe = plan_rescore_sync(8)  # deps="speculate" sparse matvec
    prog = exe.plan.program
    rows = [3, 1, 0, 2, 7, 5, 4, 6]  # a permutation: no conflicts
    cols = list(range(8))

    store1 = indexed_store(prog, {"row": rows, "col": cols})
    init1 = {a: dict(c) for a, c in store1.items()}
    out1 = exe.run(store={a: dict(c) for a, c in store1.items()})
    assert out1 == run_sequential(prog, init1)
    s1 = inspector_cache_stats()
    assert s1["misses"] >= 1  # the first wave's validation inspected

    # wave 2: identical index contents, different *non-index* data — the
    # instance graph is unchanged, so validation must be a memo HIT; a
    # regression here reads as a counter bump, not a slowdown
    store2 = indexed_store(prog, {"row": rows, "col": cols})
    for arr in ("v", "x"):
        for cell in store2[arr]:
            store2[arr][cell] = store2[arr][cell] + 7.25
    init2 = {a: dict(c) for a, c in store2.items()}
    out2 = exe.run(store={a: dict(c) for a, c in store2.items()})
    assert out2 == run_sequential(prog, init2)
    assert out2 != out1  # the data change was real
    s2 = inspector_cache_stats()
    assert s2["misses"] == s1["misses"], "non-index change re-inspected"
    assert s2["hits"] == s1["hits"] + 1

    # no rollbacks: the permutation rows carry no conflict
    assert metrics.counter("speculation.rollbacks").value == 0

    # int-vs-float index contents digest identically (the PR 6 bug: the
    # raw-repr digest split {"row": [3, ...]} from {"row": [3.0, ...]} into
    # two memo entries)
    from repro.core.inspector import index_content_digest

    store_f = indexed_store(prog, {"row": rows, "col": cols})
    for arr in ("row", "col"):
        for cell in store_f[arr]:
            store_f[arr][cell] = float(store_f[arr][cell])
    assert index_content_digest(prog, store_f) == index_content_digest(
        prog, store2
    )
    inspect_dependences(prog, store_f)
    s3 = inspector_cache_stats()
    assert s3["misses"] == s2["misses"]
    assert s3["hits"] == s2["hits"] + 1


# ---------------------------------------------------------------------- #
# Byte-accounted artifact LRU
# ---------------------------------------------------------------------- #

def test_byte_budget_evicts_and_gauge_tracks():
    obs.reset_all()
    prog = decode_program(8)
    # a 1-byte budget: every entry is over budget the moment it lands, so
    # the LRU retains nothing — yet requests still resolve and run
    # correctly (the budget bounds memory, never correctness)
    with PlanService(
        ServiceOptions(workers=1, plan_cache_bytes=1)
    ) as svc:
        for _ in range(3):
            res = svc.submit(prog, tenant="t", run=True).result()
            assert res.store == run_sequential(prog, _fresh_initial(prog))
        stats = svc.drain()
    assert stats["plan_cache"]["size"] == 0
    assert stats["plan_cache"]["bytes"] == 0
    assert stats["plan_cache"]["bytes_budget"] == 1
    assert stats["plan_cache"]["evictions"] == 3
    assert stats["tenants"]["t"]["misses"] == 3  # nothing survived to hit
    assert metrics.gauge("plan_cache.bytes").value == 0
    assert metrics.counter("plan_cache.evictions").value == 3

    obs.reset_all()
    # the default (roomy) budget: the entry — plan plus the compiled
    # artifact attached by the first request — stays resident and its
    # estimated footprint rides the plan_cache.bytes gauge
    with PlanService(ServiceOptions(workers=1)) as svc:
        svc.submit(prog, tenant="t", run=True).result()
        res2 = svc.submit(prog, tenant="t", run=True).result()
        assert res2.plan_cached is True
        stats = svc.drain()
    assert stats["plan_cache"]["evictions"] == 0
    assert stats["plan_cache"]["bytes"] > 0
    assert stats["tenants"]["t"]["bytes"] == stats["plan_cache"]["bytes"]
    assert (
        metrics.gauge("plan_cache.bytes").value
        == stats["plan_cache"]["bytes"]
    )
    # the warm request reused the attached artifact instead of compiling
    assert metrics.counter("plan_cache.artifact_hits").value == 1
