"""The per-host cost-profile subsystem (:mod:`repro.calibrate`).

Contracts under test:

* **Persistence round-trip** — ``measure()`` installs and persists a
  schema-versioned profile; a fresh process state (``reset()``) reloads it
  via ``warm()`` with *zero re-measurement* (``calibrate.measurements``
  stays flat — the restart-reuse acceptance criterion).
* **Corrupt / stale fallback** — truncated JSON, wrong schema, wrong
  fingerprint, or invalid unit values are each ignored with a
  ``calibrate.fallbacks`` tick; the hand-set defaults keep pricing.
* **Env switch** — ``REPRO_CALIBRATE=off`` pins the defaults regardless of
  warmed or persisted state.
* **Invariance pins** — like the tracing-invariance pins in
  ``test_obs.py``: calibration state must never leak into structural cache
  keys, and only offer *prices* (never the offer set or the schedule's
  structure) may respond to a profile.  ``StrategyPlan.profile_generation``
  records which profile priced the auction.
"""

from __future__ import annotations

import json

import pytest

import repro.calibrate as calibrate
import repro.obs as obs
from repro.obs import metrics
from repro.core import (
    ArrayRef,
    LoopProgram,
    PlanOptions,
    Statement,
    plan,
)

FAST_UNITS = {
    "xla_step": 0.5,
    "xla_lane": 0.25,
    "spmd_collective": 2.0,
    "spmd_collective_lane": 0.0625,
    "dispatch": 40.0,
}


@pytest.fixture(autouse=True)
def _isolated_calibration(tmp_path, monkeypatch):
    """Every test gets its own cache dir, the env switch unset, and a
    clean in-memory state on both sides (pytest runs this file before
    test_plan_api's pinned golden summary — leaking an active profile
    would flip its calibration pointer)."""

    monkeypatch.setenv("REPRO_CALIBRATE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_CALIBRATE", raising=False)
    obs.reset_all()
    yield
    obs.reset_all()


def _fake_measure(monkeypatch, units=None):
    """Patch the microbenchmark suite with a deterministic stub that still
    ticks the measurement counter (so reuse-vs-remeasure is observable)."""

    from repro.calibrate import microbench

    def fake_measure_units(**kwargs):
        metrics.counter("calibrate.measurements").inc()
        return dict(units or FAST_UNITS), {"stub": True}

    monkeypatch.setattr(microbench, "measure_units", fake_measure_units)


def _recurrence(ni=6, nj=24):
    return LoopProgram(
        statements=(
            Statement(
                "S1",
                ArrayRef("a", (0, 0)),
                (ArrayRef("a", (0, -1)), ArrayRef("a", (-1, 1))),
            ),
        ),
        bounds=((0, ni), (0, nj)),
    )


# ---------------------------------------------------------------------- #
# Defaults / env switch
# ---------------------------------------------------------------------- #

def test_default_units_resolve_hand_set_constants_late(monkeypatch):
    import repro.compile as compile_pkg

    assert calibrate.units()["xla_step"] == compile_pkg.XLA_STEP_LANE_UNITS
    # late resolution: a monkeypatched constant takes effect immediately,
    # in xla_level_cost AND spmd_level_cost (the old spmd.py imported the
    # constant by value at module import time, freezing it)
    monkeypatch.setattr(compile_pkg, "XLA_STEP_LANE_UNITS", 7.25)
    assert calibrate.units()["xla_step"] == 7.25
    from repro.compile.spmd import spmd_level_cost  # noqa: F401 (imports)

    assert calibrate.units()["xla_step"] == 7.25


def test_env_switch_pins_defaults(monkeypatch):
    _fake_measure(monkeypatch)
    calibrate.measure()
    assert calibrate.active_profile().source == "measured"
    monkeypatch.setenv("REPRO_CALIBRATE", "off")
    assert not calibrate.enabled()
    assert calibrate.active_profile().source == "default"
    assert calibrate.profile_generation() == 0
    assert calibrate.units() == calibrate.default_profile().units
    # measure/warm become no-ops returning defaults
    assert calibrate.measure().source == "default"
    assert calibrate.warm().source == "default"


# ---------------------------------------------------------------------- #
# Persistence round-trip + restart reuse
# ---------------------------------------------------------------------- #

def test_measure_persists_and_roundtrips(monkeypatch, tmp_path):
    _fake_measure(monkeypatch)
    prof = calibrate.measure()
    assert prof.source == "measured"
    assert prof.generation == 1
    assert prof.units == FAST_UNITS
    path = calibrate.profile_path()
    assert path.parent == tmp_path
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == calibrate.SCHEMA_VERSION
    assert on_disk["fingerprint"] == calibrate.host_fingerprint()
    loaded = calibrate.load_profile()
    assert loaded is not None
    assert loaded.source == "persisted"
    assert loaded.units == prof.units
    assert loaded.generation == 1
    # re-measuring bumps the generation monotonically
    assert calibrate.measure().generation == 2


def test_warm_reuses_persisted_profile_with_zero_remeasurement(monkeypatch):
    _fake_measure(monkeypatch)
    calibrate.warm()  # cold: measures and persists
    assert metrics.counter("calibrate.measurements").value == 1
    # "restart": in-memory state gone, file survives
    obs.reset_all()
    assert calibrate.active_profile().source == "default"
    prof = calibrate.warm()
    assert prof.source == "persisted"
    assert prof.generation == 1
    assert metrics.counter("calibrate.measurements").value == 0  # flat
    assert metrics.counter("calibrate.loads").value == 1
    # further warms are no-ops on the installed profile
    assert calibrate.warm() is prof
    assert metrics.counter("calibrate.loads").value == 1


def test_plan_service_warm_profile_knob(monkeypatch):
    _fake_measure(monkeypatch)
    from repro.serve import PlanService, ServiceOptions

    with pytest.raises(ValueError):
        ServiceOptions(warm_profile="yes")
    with PlanService(ServiceOptions(warm_profile=True)):
        assert calibrate.active_profile().source == "measured"
    obs.reset_all()
    # second service start: persisted reuse, no re-measurement
    with PlanService(ServiceOptions(warm_profile=True)):
        assert calibrate.active_profile().source == "persisted"
        assert metrics.counter("calibrate.measurements").value == 0


# ---------------------------------------------------------------------- #
# Corrupt / stale fallback
# ---------------------------------------------------------------------- #

def test_corrupt_and_stale_profiles_fall_back(monkeypatch):
    path = calibrate.profile_path()
    path.parent.mkdir(parents=True, exist_ok=True)

    # missing file: None, but NOT a fallback (nothing was corrupt)
    assert calibrate.load_profile() is None
    assert metrics.counter("calibrate.fallbacks").value == 0

    good = calibrate.CostProfile(
        units=dict(FAST_UNITS),
        fingerprint=calibrate.host_fingerprint(),
        generation=3,
        source="measured",
    )

    def dump(mutate):
        raw = good.as_dict()
        mutate(raw)
        path.write_text(json.dumps(raw))

    cases = [
        lambda raw: raw.update(schema=99),
        lambda raw: raw.update(fingerprint="feedfacedeadbeef"),
        lambda raw: raw.update(generation=-1),
        lambda raw: raw["units"].update(xla_step=0.0),
        lambda raw: raw["units"].update(xla_lane=float("nan")),
        lambda raw: raw["units"].pop("dispatch"),
    ]
    for i, mutate in enumerate(cases, start=1):
        dump(mutate)
        assert calibrate.load_profile() is None
        assert metrics.counter("calibrate.fallbacks").value == i

    # truncated JSON (a torn write without the atomic replace)
    path.write_text(json.dumps(good.as_dict())[:25])
    assert calibrate.load_profile() is None

    # warm() on a corrupt file re-measures instead of trusting it
    _fake_measure(monkeypatch)
    prof = calibrate.warm()
    assert prof.source == "measured"
    assert metrics.counter("calibrate.measurements").value == 1
    # and the hand-set defaults kept pricing until then
    assert calibrate.load_profile().units == FAST_UNITS


def test_foreign_host_profile_triggers_remeasure(monkeypatch):
    _fake_measure(monkeypatch)
    calibrate.measure()
    old_path = calibrate.profile_path()
    obs.reset_all()
    # the host changes identity (e.g. a different device count after
    # restart): the old file's *content* fingerprint no longer validates,
    # and the new host's own profile path does not exist yet
    monkeypatch.setattr(
        calibrate, "host_fingerprint", lambda info=None: "0123456789abcdef"
    )
    assert calibrate.load_profile(old_path) is None  # stale, fallback ticked
    assert metrics.counter("calibrate.fallbacks").value == 1
    prof = calibrate.warm()
    assert prof.source == "measured"
    assert prof.fingerprint == "0123456789abcdef"
    assert metrics.counter("calibrate.measurements").value == 1


# ---------------------------------------------------------------------- #
# Invariance pins: structural keys and offers vs calibration state
# ---------------------------------------------------------------------- #

def test_structural_key_invariant_to_calibration(monkeypatch):
    from repro.compile import structural_key

    prog = _recurrence()
    retained = tuple(plan(prog).elimination.retained)
    before = structural_key(prog, retained, model="doall")
    _fake_measure(
        monkeypatch,
        units={**FAST_UNITS, "xla_step": 1e6, "dispatch": 1e-6},
    )
    calibrate.measure()
    assert structural_key(prog, retained, model="doall") == before


def test_offers_and_schedule_structure_invariant_to_calibration(monkeypatch):
    """Only offer *prices* may respond to the profile: the offer set, the
    winning schedule's structure under a pinned policy, and the plan's
    sync instructions stay put; ``profile_generation`` records provenance."""

    from repro.core import clear_analysis_cache

    prog = _recurrence()
    rec0 = (
        plan(prog).compile("wavefront").report().wavefront.scc.recurrences[0]
    )
    assert rec0.profile_generation == 0
    assert rec0.offers  # the auto auction ran

    _fake_measure(
        monkeypatch,
        units={**FAST_UNITS, "dispatch": 123.0},
    )
    calibrate.measure()
    clear_analysis_cache()  # fresh auction, profile intact
    rec1 = (
        plan(prog).compile("wavefront").report().wavefront.scc.recurrences[0]
    )
    assert rec1.strategy == rec0.strategy
    assert rec1.chunk == rec0.chunk
    # the offer set — and even the recorded model-space prices — are
    # calibration-invariant (the profile scales them at scoring time,
    # uniformly for the interpreter's dispatch-weight model)
    assert rec1.offers == rec0.offers
    assert rec1.profile_generation == 1


def test_obs_summary_carries_calibration_pointer(monkeypatch):
    assert obs.obs_summary("xla")["calibration"] == {
        "enabled": True,
        "source": "default",
        "generation": 0,
        "profile_export": (
            "repro.calibrate.active_profile() / profile_path()"
        ),
    }
    _fake_measure(monkeypatch)
    calibrate.measure()
    ptr = obs.obs_summary("xla")["calibration"]
    assert ptr["source"] == "measured"
    assert ptr["generation"] == 1


# ---------------------------------------------------------------------- #
# One real (tiny) measurement through the lowering machinery
# ---------------------------------------------------------------------- #

def test_real_microbenchmark_smoke():
    prof = calibrate.measure(n=256, widths=(4, 16), repeats=1)
    assert prof.source == "measured"
    for name in calibrate.UNIT_NAMES:
        assert prof.units[name] > 0.0
    assert metrics.counter("calibrate.measurements").value > 0
    # measured units price the xla hook immediately
    assert calibrate.units() == prof.units


def test_microbench_rejects_degenerate_parameters():
    from repro.calibrate.microbench import measure_units

    with pytest.raises(ValueError):
        measure_units(n=256, widths=(8,))  # one width cannot fit a line
    with pytest.raises(ValueError):
        measure_units(n=64, widths=(4, 32))  # bands too short to difference
