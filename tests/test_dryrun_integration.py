"""End-to-end dry-run integration: launch ``repro.launch.dryrun`` as a real
subprocess (its XLA_FLAGS must be set before jax imports, so in-process
testing is impossible by design) and validate the produced record.

Uses the cheapest cell (mamba2 decode: no attention cache, sub-second
compile) so the test stays under a minute including the 512-device startup.
"""

import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_dryrun_cell_end_to_end(tmp_path):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            "mamba2_2_7b",
            "--shape",
            "decode_32k",
            "--out",
            str(tmp_path),
        ],
        capture_output=True,
        text=True,
        timeout=480,
        env=env,
        cwd=str(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "all requested cells compiled" in proc.stdout

    (record_file,) = tmp_path.glob("*.json")
    r = json.loads(record_file.read_text())
    assert r["arch"] == "mamba2-2.7b"
    assert r["chips"] == 256
    assert r["roofline"]["compute_s"] >= 0
    assert r["roofline_analytic"]["dominant"] in (
        "compute",
        "memory",
        "collective",
    )
    mem = r["memory"]
    assert mem["argument_bytes"] > 0
    # mamba2 decode comfortably fits a 16 GB chip
    assert mem["argument_bytes"] + mem["temp_bytes"] < 16e9
    coll = r["collectives"]
    assert coll["total_bytes"] >= 0


@pytest.mark.slow
def test_dryrun_skip_record(tmp_path):
    """A sub-quadratic-gated cell writes a skip record and exits 0."""

    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            "yi_6b",
            "--shape",
            "long_500k",
            "--out",
            str(tmp_path),
        ],
        capture_output=True,
        text=True,
        timeout=240,
        env=env,
        cwd=str(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    (record_file,) = tmp_path.glob("*.json")
    r = json.loads(record_file.read_text())
    assert "skipped" in r and "full-attention" in r["skipped"]


@pytest.mark.slow
def test_pp_lowering_single_permute(tmp_path):
    """The sync-planned pipeline lowers to one collective-permute per step
    on the production mesh (paper's elimination, visible in compiled HLO)."""

    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.runtime.pp_lowering"],
        capture_output=True,
        text=True,
        timeout=480,
        env=env,
        cwd=str(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "pp lowering: OK" in proc.stdout
    assert "collective-permutes in HLO: 1" in proc.stdout
