"""Unit tests for the SCC-condensed hybrid scheduler (repro.core.scc):
Tarjan condensation, recurrence classification and chunk sizing, the
unschedulability diagnostics (offending SCC + witness cycle, raised at
parallelize() time), and the structural properties of hybrid schedules —
every cross-unit enforced order strictly increases the level, recurrence
chunks never exceed the minimum carried distance, and downstream acyclic
SCCs pipeline against producer chunks instead of waiting for the whole
recurrence.
"""

import pytest

from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    WavefrontError,
    analyze,
    analyze_sccs,
    paper_alg4,
    paper_alg6,
    plan,
    scc_signature,
    tarjan_sccs,
    validate_retained,
)
from repro.core.dependence import FLOW, Dependence
from repro.core.wavefront import schedule_levels


def skew_stencil(ni=6, nj=5):
    """a[i,j] = f(a[i-1,j+1]) — the classic mixed-sign (1,-1) recurrence."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
        ),
        bounds=((0, ni), (0, nj)),
    )


def mixed_cycle(ni=4, nj=4):
    """S1 -> S2 with Δ=(0,1) and S2 -> S1 with Δ=(1,-1): a retained
    {Δ=+1, Δ=-1} component mix closing a statement cycle."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("b", (-1, 1)),)),
            Statement("S2", ArrayRef("b", (0, 0)), (ArrayRef("a", (0, -1)),)),
        ),
        bounds=((0, ni), (0, nj)),
    )


def skew_pipeline(ni=8, nj=9):
    """Recurrence SCC feeding an acyclic DOALL consumer."""

    return LoopProgram(
        statements=(
            Statement("S1", ArrayRef("a", (0, 0)), (ArrayRef("a", (-1, 1)),)),
            Statement("S2", ArrayRef("c", (0, 0)), (ArrayRef("a", (0, 0)),)),
        ),
        bounds=((0, ni), (0, nj)),
    )


def carried(prog):
    return [d for d in analyze(prog) if d.loop_carried]


class TestTarjan:
    def test_condensation_topological_order(self):
        adj = {
            "A": {"B"},
            "B": {"C"},
            "C": {"B", "D"},
            "D": set(),
            "E": {"A"},
        }
        comps = tarjan_sccs(["A", "B", "C", "D", "E"], adj)
        assert sorted(map(sorted, comps)) == [["A"], ["B", "C"], ["D"], ["E"]]
        order = {n: k for k, comp in enumerate(comps) for n in comp}
        for u, succs in adj.items():
            for v in succs:
                assert order[u] <= order[v]

    def test_alg4_statement_cycle_found(self):
        """The paper's cyclic example: S1 δf(a,1) S3 δf(c,1) S2 δf(b,1) S1
        closes a 3-cycle (via the dependence the paper's Fig. 5 misses)."""

        prog = paper_alg4(8)
        part = analyze_sccs(prog, carried(prog))
        cyclic = [s for s in part.sccs if s.cyclic]
        assert len(cyclic) == 1
        assert set(cyclic[0].statements) == {"S1", "S2", "S3"}
        # positive distances only: layerable, NOT a recurrence block
        assert not cyclic[0].recurrence
        assert part.recurrences == ()

    def test_alg6_all_nonneg_no_recurrence(self):
        prog = paper_alg6(8)
        part = analyze_sccs(prog, carried(prog))
        assert part.recurrences == ()


class TestRecurrenceClassification:
    def test_skew_chunk_is_min_carried_linearized_distance(self):
        prog = skew_stencil(6, 5)
        part = analyze_sccs(prog, carried(prog), scc_policy="chunk")
        (rec,) = part.recurrences
        # distance (1,-1) linearizes to inner_extent - 1 = 4
        assert rec.chunk == rec.carried_min == 4
        assert rec.statements == ("S1",)
        assert rec.cyclic
        assert rec.strategy == "chunk"

    def test_mixed_cycle_chunk_one(self):
        prog = mixed_cycle()
        part = analyze_sccs(prog, carried(prog), scc_policy="chunk")
        (rec,) = part.recurrences
        assert set(rec.statements) == {"S1", "S2"}
        # the (0,1) dependence forces fully sequential chunks
        assert rec.chunk == 1

    def test_chunk_limit_knob_caps_but_never_zero(self):
        prog = skew_stencil(6, 9)
        carried_deps = carried(prog)
        part = analyze_sccs(prog, carried_deps, chunk_limit=3, scc_policy="chunk")
        assert part.recurrences[0].chunk == 3
        part = analyze_sccs(prog, carried_deps, chunk_limit=100, scc_policy="chunk")
        assert part.recurrences[0].chunk == 8  # capped by carried_min
        part = analyze_sccs(prog, carried_deps, chunk_limit=0, scc_policy="chunk")
        assert part.recurrences[0].chunk == 1

    def test_dswp_free_orders_force_sequential_chunks(self):
        """Per-statement processor order is free under dswp — batching a
        chunk may not reorder it, so recurrence chunks collapse to 1."""

        prog = skew_stencil(6, 9)
        part = analyze_sccs(prog, carried(prog), model="dswp")
        assert part.recurrences[0].chunk == 1

    def test_signature_is_bounds_free(self):
        a = scc_signature(skew_stencil(6, 5), carried(skew_stencil(6, 5)))
        b = scc_signature(skew_stencil(40, 11), carried(skew_stencil(40, 11)))
        assert a == b


class TestUnschedulableDiagnostics:
    def test_witness_cycle_names_scc_statements(self):
        prog = paper_alg6(6)
        deps = [
            Dependence(FLOW, "S1", "S2", "a", (1,)),
            Dependence(FLOW, "S2", "S1", "b", (-1,)),
        ]
        with pytest.raises(WavefrontError) as ei:
            validate_retained(prog, deps)
        msg = str(ei.value)
        assert "SCC {S1, S2}" in msg
        assert "witness cycle" in msg
        assert "S2 δf(b, Δ=-1) S1" in msg
        assert "deadlock" in msg

    def test_zero_distance_backward_rejected(self):
        prog = paper_alg6(6)
        bad = Dependence(FLOW, "S3", "S1", "a", (0,))
        with pytest.raises(WavefrontError, match="sink precedes the source"):
            validate_retained(prog, [bad])

    def test_zero_distance_self_dep_rejected(self):
        prog = paper_alg6(6)
        bad = Dependence(FLOW, "S1", "S1", "a", (0,))
        with pytest.raises(WavefrontError, match="before itself"):
            validate_retained(prog, [bad])

    def test_raised_at_plan_time_for_every_backend(self):
        """The satellite contract: unschedulable sets fail at plan() time,
        not mid-execution — before any backend is involved, including the
        threaded machine, which would otherwise deadlock at run time."""

        prog = paper_alg6(6)
        deps = list(analyze(prog)) + [
            Dependence(FLOW, "S2", "S1", "b", (-1,)),
        ]
        for backend in ("threaded", "wavefront"):
            with pytest.raises(WavefrontError, match="witness cycle"):
                plan(prog, deps=deps).compile(backend).report()

    def test_analyzer_output_always_validates(self):
        for prog in (paper_alg4(8), skew_stencil(), mixed_cycle()):
            validate_retained(prog, analyze(prog))  # must not raise


class TestHybridLayering:
    def test_every_cross_unit_dep_increases_level(self):
        for prog in (skew_stencil(), mixed_cycle(), skew_pipeline()):
            deps = carried(prog)
            wf = schedule_levels(prog, deps)
            lvl = wf.level_of()
            scc_of = wf.scc.scc_of()
            rec = {s.id for s in wf.scc.recurrences}
            for d in deps:
                for it in prog.iterations():
                    dst = tuple(x + dd for x, dd in zip(it, d.distance))
                    if (d.sink, dst) not in lvl:
                        continue
                    same_chunk = (
                        scc_of[d.source] == scc_of[d.sink]
                        and scc_of[d.source] in rec
                        and lvl[(d.source, it)] == lvl[(d.sink, dst)]
                    )
                    if same_chunk:
                        # intra-chunk orders must be zero-distance, honored
                        # by lexical statement order within the level
                        assert all(x == 0 for x in d.distance)
                    else:
                        assert lvl[(d.source, it)] < lvl[(d.sink, dst)]

    def test_chunk_widths_bounded_by_chunk_size(self):
        wf = schedule_levels(
            skew_stencil(6, 5), carried(skew_stencil(6, 5)),
            scc_policy="chunk",
        )
        (rec,) = wf.scc.recurrences
        assert wf.max_width <= rec.chunk
        assert wf.instances == 6 * 5

    def test_pipelining_beats_blocked_execution(self):
        """The DOALL consumer levels right behind each producer chunk: total
        depth stays near the chunk count instead of doubling."""

        prog = skew_pipeline(8, 9)
        wf = schedule_levels(prog, carried(prog), scc_policy="chunk")
        (rec,) = wf.scc.recurrences
        n_chunks = -(-72 // rec.chunk)
        assert wf.depth <= n_chunks + 2  # pipelined
        assert wf.depth < 2 * n_chunks  # far from blocked

    def test_one_group_per_statement_and_level(self):
        """The XLA cursor machinery requires it; the hybrid guarantees it."""

        for prog in (skew_stencil(), mixed_cycle(), skew_pipeline()):
            wf = schedule_levels(prog, carried(prog))
            for groups in wf.levels:
                names = [g.statement for g in groups]
                assert len(names) == len(set(names))

    def test_report_surfaces_partition(self):
        rep = plan(skew_stencil(), method="isd").compile("wavefront").report()
        s = rep.summary()
        assert s["scc"]["recurrences"][0]["statements"] == ["S1"]
        assert rep.wavefront.summary()["scc"]["sccs"] == 1
