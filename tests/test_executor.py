"""Threaded shared-memory executor: semantics, races, and sync accounting.

This is where the paper's claims become falsifiable: a correctly
synchronized program matches sequential execution under any adversarial
schedule; removing a *needed* sync produces wrong answers; removing a
*redundant* sync (per §4.2) never does.
"""

import pytest

from repro.core import (
    analyze,
    insert_synchronization,
    paper_alg1,
    paper_alg4,
    paper_alg6,
    plan,
    run_threaded,
    strip_dependences,
)
from repro.core.dependence import paper_alg4_dependences


class TestCorrectSync:
    def test_alg4_full_sync_matches_sequential(self):
        prog = paper_alg4(8)
        sync = insert_synchronization(prog, analyze(prog))
        rep = run_threaded(sync)
        assert rep.matches_sequential

    def test_alg4_full_sync_under_adversarial_stalls(self):
        prog = paper_alg4(6)
        sync = insert_synchronization(prog, analyze(prog))
        rep = run_threaded(
            sync, stalls={("S2", (1,)): 0.2, ("S3", (2,)): 0.1}
        )
        assert rep.matches_sequential
        assert rep.stats.blocked_waits > 0  # the stalls actually forced waits

    def test_alg1_sync_matches(self):
        prog = paper_alg1(8)
        sync = insert_synchronization(prog, analyze(prog))
        assert run_threaded(sync).matches_sequential


class TestPaperAlg5Race:
    def test_paper_alg5_misses_a_dependence(self):
        """The paper's Alg. 5 (built from its stated 3-dep graph) omits the
        S2 δf(b,Δ=1) S1 dependence.  Stalling S2 at iteration 1 makes S1 at
        iteration 2 read b[1] before it is written — wrong results.  Our
        analyzer's 4-dep graph fixes this (previous test)."""

        prog = paper_alg4(6)
        alg5 = insert_synchronization(prog, paper_alg4_dependences())
        rep = run_threaded(alg5, stalls={("S2", (1,)): 0.3})
        assert not rep.matches_sequential

    def test_removing_needed_sync_breaks(self):
        """Dropping a retained (non-redundant) dependence's sync is unsafe."""

        prog = paper_alg6(6)
        deps = analyze(prog)
        sync = insert_synchronization(prog, deps)
        # strip the *retained* Δ=1 dep (the wrong one to remove)
        keep_wrong = [d for d in deps if d.delta == 1]
        broken = strip_dependences(sync, keep_wrong)
        rep = run_threaded(broken, stalls={("S3", (1,)): 0.3})
        assert not rep.matches_sequential


class TestOptimizedSyncStillCorrect:
    @pytest.mark.parametrize("method", ["isd", "pattern", "both"])
    def test_alg6_optimized(self, method):
        rep = plan(paper_alg6(6), method=method).compile("threaded").report()
        run = run_threaded(
            rep.optimized_sync, stalls={("S3", (1,)): 0.15, ("S2", (2,)): 0.1}
        )
        assert run.matches_sequential

    def test_alg4_optimized(self):
        rep = plan(paper_alg4(6), method="isd").compile("threaded").report()
        run = run_threaded(rep.optimized_sync, stalls={("S2", (1,)): 0.15})
        assert run.matches_sequential

    def test_sync_ops_reduced(self):
        rep = plan(paper_alg6(8), method="isd").compile("threaded").report()
        naive = run_threaded(rep.naive_sync)
        opt = run_threaded(rep.optimized_sync)
        assert naive.matches_sequential and opt.matches_sequential
        assert opt.stats.waits < naive.stats.waits
        assert opt.stats.sends < naive.stats.sends


class TestDSWPModel:
    def test_pipelined_execution_matches(self):
        """One thread per statement (Fig. 4), Δ=0 deps synchronized."""

        prog = paper_alg4(6)
        deps = analyze(prog)
        sync = insert_synchronization(prog, deps, model="dswp")
        rep = run_threaded(sync, model="dswp", stalls={("S1", (2,)): 0.1})
        assert rep.matches_sequential
        assert rep.stats.threads == 3  # one per statement

    def test_dswp_without_sync_races(self):
        prog = paper_alg4(6)
        sync = insert_synchronization(prog, [], model="dswp")  # no deps → no sync
        rep = run_threaded(sync, model="dswp", stalls={("S2", (0,)): 0.25})
        assert not rep.matches_sequential
