"""Per-architecture smoke tests: every assigned arch instantiates a reduced
same-family config, runs one forward + one train-gradient step on CPU, and
(where a decode path exists) verifies incremental decoding against the full
forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, get_smoke_config
from repro.models import model_zoo as zoo

B, S, SMAX = 2, 12, 16


def make_batch(cfg, key=None):
    key = key or jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.num_frames, cfg.d_model)
        )
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = zoo.init(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        logits, aux = zoo.forward_logits(params, batch, cfg)
        S_out = S + (cfg.num_patches if cfg.frontend == "vision" else 0)
        # logits cover the PADDED vocab; padded positions are masked to -inf
        assert logits.shape == (B, S_out, cfg.padded_vocab_size)
        real = logits[..., : cfg.vocab_size].astype(jnp.float32)
        assert bool(jnp.all(jnp.isfinite(real)))
        # padded entries can never win argmax
        assert int(jnp.max(jnp.argmax(logits, -1))) < cfg.vocab_size
        assert bool(jnp.isfinite(aux))

    def test_train_gradient_step(self, arch):
        cfg = get_smoke_config(arch)
        params = zoo.init(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)

        def loss(p):
            l, _ = zoo.loss_fn(p, batch, cfg)
            return l

        l, grads = jax.value_and_grad(loss)(params)
        assert bool(jnp.isfinite(l))
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat)
        gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in flat) ** 0.5
        assert gnorm > 0.0

    def test_decode_matches_forward(self, arch):
        cfg = get_smoke_config(arch).scaled(dtype="float32")
        if cfg.has_moe:
            # exact match requires no capacity drops
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0)
            )
        params = zoo.init(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        toks = batch["tokens"]
        full, _ = zoo.forward_logits(params, batch, cfg)
        npfx = cfg.num_patches if cfg.frontend == "vision" else 0
        cache = zoo.init_cache(cfg, B, SMAX + npfx)
        lp, cache = zoo.prefill(params, {**batch, "tokens": toks[:, :6]}, cfg, cache)
        np.testing.assert_allclose(lp[:, 0], full[:, npfx + 5], atol=2e-4, rtol=2e-4)
        cl = 6 + npfx
        for t in range(6, S):
            lg, cache = zoo.decode_step(
                params, toks[:, t : t + 1], cfg, cache, jnp.int32(cl)
            )
            cl += 1
            np.testing.assert_allclose(
                lg[:, 0], full[:, npfx + t], atol=2e-4, rtol=2e-4
            )

    def test_full_config_is_published_spec(self, arch):
        """The FULL config (never instantiated here) matches the assignment."""

        cfg = get_config(arch)
        spec = {
            "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102_400),
            "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32_000),
            "gemma3_27b": (62, 5376, 32, 16, 21504, 262_144),
            "yi_6b": (32, 4096, 32, 4, 11008, 64_000),
            "granite_3_2b": (40, 2048, 32, 8, 8192, 49_155),
            "internlm2_20b": (48, 6144, 48, 8, 16384, 92_544),
            "jamba_v01_52b": (32, 4096, 32, 8, 14336, 65_536),
            "mamba2_2_7b": (64, 2560, 1, 1, 0, 50_280),
            "whisper_medium": (24, 1024, 16, 16, 4096, 51_865),
            "llava_next_34b": (60, 7168, 56, 8, 20480, 64_000),
        }[arch]
        got = (
            cfg.num_layers,
            cfg.d_model,
            cfg.num_heads,
            cfg.num_kv_heads,
            cfg.d_ff,
            cfg.vocab_size,
        )
        assert got == spec

    def test_smoke_same_family_as_full(self, arch):
        full, smoke = get_config(arch), get_smoke_config(arch)
        assert full.family == smoke.family
        assert [p.mixer for p in full.block] == [p.mixer for p in smoke.block]
        assert [p.mlp for p in full.block] == [p.mlp for p in smoke.block]
        assert full.has_moe == smoke.has_moe
        assert full.has_mamba == smoke.has_mamba


class TestMoEArchSpecs:
    def test_deepseek_experts(self):
        cfg = get_config("deepseek_moe_16b")
        assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
        assert cfg.moe.num_shared == 2

    def test_mixtral_experts(self):
        cfg = get_config("mixtral_8x7b")
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2

    def test_jamba_ratio(self):
        cfg = get_config("jamba_v01_52b")
        from repro.configs.base import ATTN, MAMBA

        mixers = [p.mixer for p in cfg.block]
        assert mixers.count(ATTN) == 1 and mixers.count(MAMBA) == 7
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 2
        moes = sum(p.mlp == "moe" for p in cfg.block)
        assert moes * cfg.num_blocks == 16  # MoE every other layer

    def test_mamba2_state(self):
        cfg = get_config("mamba2_2_7b")
        assert cfg.mamba.d_state == 128
        assert cfg.mamba.num_heads(cfg.d_model) == 80


class TestParamCounts:
    """Full-config parameter counts (via eval_shape — no allocation) land
    near the published sizes, catching mis-wired configs."""

    @pytest.mark.parametrize(
        "arch,expected_b,tol",
        [
            ("yi_6b", 6.06e9, 0.12),
            ("mixtral_8x7b", 46.7e9, 0.15),
            ("deepseek_moe_16b", 16.4e9, 0.15),
            ("mamba2_2_7b", 2.7e9, 0.15),
            ("granite_3_2b", 2.5e9, 0.25),
            ("internlm2_20b", 19.9e9, 0.15),
            ("llava_next_34b", 34.4e9, 0.15),
            ("jamba_v01_52b", 52e9, 0.25),
            ("whisper_medium", 0.77e9, 0.25),
            ("gemma3_27b", 27e9, 0.20),
        ],
    )
    def test_param_count(self, arch, expected_b, tol):
        cfg = get_config(arch)
        shapes = zoo.abstract_params(cfg)
        n = sum(
            int(np.prod(x.shape)) for x in jax.tree.leaves(shapes)
        )
        assert abs(n - expected_b) / expected_b < tol, f"{arch}: {n/1e9:.2f}B"
