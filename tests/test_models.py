"""Layer-level numerics: chunked attention vs quadratic oracle, grouped MoE
dispatch vs dense oracle, chunked SSD vs sequential recurrence, conv state."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import rmsnorm, rmsnorm_init, softmax_cross_entropy


class TestChunkedAttention:
    @pytest.mark.parametrize("Sq,Sk,chunk", [(16, 16, 4), (8, 32, 8), (32, 32, 32), (7, 13, 5)])
    @pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
    def test_matches_reference_causal(self, Sq, Sk, chunk, H, KV):
        if Sq != Sk:
            return  # causal offsets tested separately
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (2, Sq, H, 16))
        k = jax.random.normal(k2, (2, Sk, KV, 16))
        v = jax.random.normal(k3, (2, Sk, KV, 16))
        out = attn_lib.chunked_attention(q, k, v, causal=True, chunk=chunk)
        ref = attn_lib.attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [1, 3, 8, 64])
    def test_sliding_window(self, window):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(k1, (1, 24, 4, 8))
        k = jax.random.normal(k2, (1, 24, 2, 8))
        v = jax.random.normal(k3, (1, 24, 2, 8))
        out = attn_lib.chunked_attention(q, k, v, causal=True, window=window, chunk=5)
        ref = attn_lib.attention_reference(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(k1, (2, 6, 4, 8))
        k = jax.random.normal(k2, (2, 17, 4, 8))
        v = jax.random.normal(k3, (2, 17, 4, 8))
        out = attn_lib.chunked_attention(q, k, v, causal=False, chunk=4)
        ref = attn_lib.attention_reference(q, k, v, causal=False)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_decode_matches_reference_row(self):
        """decode_attention == last row of the full causal attention."""

        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
        S = 12
        q_all = jax.random.normal(k1, (2, S, 4, 8))
        k_all = jax.random.normal(k2, (2, S, 2, 8))
        v_all = jax.random.normal(k3, (2, S, 2, 8))
        ref = attn_lib.attention_reference(q_all, k_all, v_all, causal=True)
        Smax = 16
        kc = jnp.zeros((2, Smax, 2, 8)).at[:, :S].set(k_all)
        vc = jnp.zeros((2, Smax, 2, 8)).at[:, :S].set(v_all)
        out = attn_lib.decode_attention(q_all[:, -1:], kc, vc, jnp.int32(S))
        np.testing.assert_allclose(out[:, 0], ref[:, -1], atol=2e-5, rtol=2e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        sq=st.integers(1, 24),
        chunk=st.integers(1, 32),
        h=st.sampled_from([1, 2, 4]),
        dtype=st.sampled_from(["float32", "bfloat16"]),
    )
    def test_property_shapes_dtypes(self, sq, chunk, h, dtype):
        dt = jnp.dtype(dtype)
        k1, k2 = jax.random.split(jax.random.PRNGKey(4))
        q = jax.random.normal(k1, (1, sq, 4, 8)).astype(dt)
        k = jax.random.normal(k2, (1, sq, h, 8)).astype(dt)
        out = attn_lib.chunked_attention(q, k, k, causal=True, chunk=chunk)
        assert out.shape == q.shape and out.dtype == dt
        ref = attn_lib.attention_reference(q, k, k, causal=True)
        tol = 2e-5 if dtype == "float32" else 3e-2
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
        )


class TestMoE:
    def _cfg(self, cap=100.0):
        cfg = get_smoke_config("mixtral_8x7b").scaled(dtype="float32")
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap)
        )

    def test_grouped_dispatch_matches_dense_oracle(self):
        cfg = self._cfg()
        p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y, aux = moe_lib.moe_apply(p, x, cfg)
        ref = moe_lib.moe_reference(p, x, cfg)
        np.testing.assert_allclose(y, ref, atol=1e-5, rtol=1e-5)
        assert jnp.isfinite(aux)

    def test_capacity_drops_are_bounded(self):
        """With realistic capacity_factor tokens may drop — output stays
        finite and within a bounded distance of the no-drop oracle."""

        cfg = self._cfg(cap=1.0)
        p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        y, _ = moe_lib.moe_apply(p, x, cfg)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_shared_experts_always_on(self):
        cfg = get_smoke_config("deepseek_moe_16b").scaled(dtype="float32")
        p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y_with, _ = moe_lib.moe_apply(p, x, cfg)
        p0 = dict(p)
        p0["w_down"] = jnp.zeros_like(p["w_down"])  # kill routed experts
        y_shared, _ = moe_lib.moe_apply(p0, x, cfg)
        from repro.models.layers import mlp

        np.testing.assert_allclose(y_shared, mlp(p["shared"], x), atol=1e-5)
        assert float(jnp.max(jnp.abs(y_with - y_shared))) > 1e-4


class TestSSD:
    @pytest.mark.parametrize("S,chunk", [(8, 4), (16, 16), (13, 4), (32, 8)])
    def test_chunked_matches_sequential(self, S, chunk):
        B, H, P, N = 2, 3, 4, 5
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, S, N))
        y, h = mamba_lib.ssd_chunked(x, dt, A, Bm, Cm, chunk)
        y_ref, h_ref = mamba_lib.ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(h, h_ref, atol=1e-4, rtol=1e-4)

    def test_state_continuation(self):
        """prefill(first half) state + ssd(second half, h0) == full run."""

        B, S, H, P, N = 1, 16, 2, 4, 3
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(jax.random.fold_in(ks[3], 7), (B, S, N))
        y_full, h_full = mamba_lib.ssd_chunked(x, dt, A, Bm, Cm, 4)
        _, h1 = mamba_lib.ssd_chunked(
            x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], 4
        )
        y2, h2 = mamba_lib.ssd_chunked(
            x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:], 4, h0=h1
        )
        np.testing.assert_allclose(y2, y_full[:, 8:], atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(h2, h_full, atol=1e-4, rtol=1e-4)

    def test_causal_conv_state(self):
        B, S, C, K = 2, 10, 6, 4
        x = jax.random.normal(jax.random.PRNGKey(2), (B, S, C))
        w = jax.random.normal(jax.random.PRNGKey(3), (K, C))
        y_full, tail = mamba_lib._causal_conv(x, w)
        # step-by-step with state must reproduce the full conv
        tail_s = None
        ys = []
        for t in range(S):
            yt, tail_s = mamba_lib._causal_conv(x[:, t : t + 1], w, tail_s)
            ys.append(yt)
        np.testing.assert_allclose(
            jnp.concatenate(ys, axis=1), y_full, atol=1e-5, rtol=1e-5
        )
        np.testing.assert_allclose(tail_s, tail, atol=1e-6)


class TestPrimitives:
    def test_rmsnorm_unit_scale(self):
        p = rmsnorm_init(8)
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8)) * 10
        y = rmsnorm(p, x, 1e-6)
        np.testing.assert_allclose(
            jnp.mean(y**2, -1), jnp.ones(4), atol=1e-3, rtol=1e-3
        )

    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((2, 3, 7))
        labels = jnp.array([[0, 1, 2], [3, 4, 5]])
        loss = softmax_cross_entropy(logits, labels)
        np.testing.assert_allclose(loss, jnp.log(7.0), atol=1e-6)

    def test_cross_entropy_mask(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 5))
        labels = jnp.zeros((1, 4), jnp.int32)
        m = jnp.array([[1, 1, 0, 0]])
        full = softmax_cross_entropy(logits[:, :2], labels[:, :2])
        masked = softmax_cross_entropy(logits, labels, m)
        np.testing.assert_allclose(full, masked, atol=1e-6)
