"""send/wait insertion (Alg. 5) and both elimination algorithms (§4.2)."""

import pytest

from repro.core import (
    ArrayRef,
    Dependence,
    FLOW,
    LoopProgram,
    Statement,
    analyze,
    eliminate_pattern,
    eliminate_transitive,
    insert_synchronization,
    isd_window,
    paper_alg4,
    paper_alg6,
    plan,
    prime_factors,
    strip_dependences,
)
from repro.core.dependence import paper_alg4_dependences
from repro.core.elimination import pattern_matches


class TestAlg5Insertion:
    """Reproduce Alg. 5 instruction-for-instruction from the paper's graph."""

    def setup_method(self):
        self.prog = paper_alg4()
        self.sync = insert_synchronization(self.prog, paper_alg4_dependences())

    def test_sends(self):
        sends = {
            name: [(s.reg,) for s in self.sync.post_sends[name]]
            for name in self.prog.names
        }
        assert sends == {"S1": [(0,)], "S2": [(1,)], "S3": [(2,)]}

    def test_waits(self):
        w_s2 = self.sync.pre_waits["S2"]
        assert [(w.reg, w.distance) for w in w_s2] == [(2, (1,))]
        w_s3 = self.sync.pre_waits["S3"]
        # Alg. 5 order: wait(1, i-2, b) then wait(0, i-1, a)
        assert [(w.reg, w.distance) for w in w_s3] == [(1, (2,)), (0, (1,))]
        assert self.sync.pre_waits["S1"] == ()

    def test_instruction_count(self):
        assert self.sync.sync_instruction_count() == {
            "sends": 3,
            "waits": 3,
            "total": 6,
        }

    def test_pretty_matches_paper_shape(self):
        text = self.sync.pretty()
        assert "send(0, i, a)" in text
        assert "wait(2, i-1, c)" in text
        assert "wait(1, i-2, b)" in text
        assert "wait(0, i-1, a)" in text
        assert "send(2, i, c)" in text


class TestWindowFormula:
    """Paper: 'least product of the unique prime factors of the dependence
    distance, plus one'."""

    def test_prime_factors(self):
        assert prime_factors(12) == {2, 3}
        assert prime_factors(1) == set()
        assert prime_factors(0) == set()
        assert prime_factors(7) == {7}

    def test_alg6_window_is_three(self):
        assert isd_window([2, 1]) == 3  # the Fig. 6 dotted box

    def test_window_examples(self):
        assert isd_window([1]) == 2
        assert isd_window([4]) == 5      # primes {2} → 3, but max_d+1 = 5
        assert isd_window([6]) == 7
        assert isd_window([2, 3]) == 7   # 2·3 + 1


class TestAlg6Elimination:
    def test_isd_eliminates_delta2(self):
        prog = paper_alg6()
        res = eliminate_transitive(prog, analyze(prog))
        assert [d.pretty() for d in res.eliminated] == ["S1 δf(a, Δ=2) S3"]
        assert [d.pretty() for d in res.retained] == ["S3 δf(c, Δ=1) S2"]

    def test_witness_is_fig6_chain(self):
        """The witness must be the alternating S2/S3 chain of Fig. 6
        (anchored at the loop start): S1(i)→S2(i)→S3(i)→S2(i+1)→S3(i+1)→
        S2(i+2)→S3(i+2)."""

        prog = paper_alg6()
        res = eliminate_transitive(prog, analyze(prog))
        (path,) = res.witnesses.values()
        names = [n for n, _ in path]
        iters = [i[0] for _, i in path]
        assert names == ["S1", "S2", "S3", "S2", "S3", "S2", "S3"]
        assert iters == [1, 1, 1, 2, 2, 3, 3]

    def test_pattern_eliminates_delta2(self):
        prog = paper_alg6()
        res = eliminate_pattern(prog, analyze(prog))
        assert [d.pretty() for d in res.eliminated] == ["S1 δf(a, Δ=2) S3"]

    def test_pattern_conditions(self):
        prog = paper_alg6()
        deps = analyze(prog)
        de = next(d for d in deps if d.delta == 2)
        dr = next(d for d in deps if d.delta == 1)
        assert pattern_matches(prog, de, dr)
        # δr itself can't be eliminated by δe (not backward from δe's view)
        assert not pattern_matches(prog, dr, de)

    def test_optimized_sync_halves_instructions(self):
        rep = plan(paper_alg6(), method="isd").compile("threaded").report()
        assert rep.naive_sync.sync_instruction_count()["total"] == 4
        assert rep.optimized_sync.sync_instruction_count()["total"] == 2


class TestPatternConditionsNegative:
    """Each of the five §4.2 conditions must individually gate elimination."""

    def _mk(self, de_delta, dr_delta, de_src, de_snk, dr_src, dr_snk, prog=None):
        prog = prog or paper_alg6()
        de = Dependence(FLOW, de_src, de_snk, "a", (de_delta,))
        dr = Dependence(FLOW, dr_src, dr_snk, "c", (dr_delta,))
        return prog, de, dr

    def test_iii_requires_lexically_backward(self):
        # δr forward (S1→S2) fails condition iii
        prog, de, dr = self._mk(2, 1, "S1", "S3", "S1", "S2")
        assert not pattern_matches(prog, de, dr)

    def test_iv_requires_unit_distance(self):
        prog, de, dr = self._mk(4, 2, "S1", "S3", "S3", "S2")
        assert not pattern_matches(prog, de, dr)

    def test_v_requires_same_sign(self):
        prog, de, dr = self._mk(2, -1, "S1", "S3", "S3", "S2")
        assert not pattern_matches(prog, de, dr)

    def test_i_requires_path_to_source(self):
        # source(δe)=S3 lexically after source(δr)=S2 → no path (i)
        prog, de, dr = self._mk(2, 1, "S3", "S3", "S2", "S1")
        assert not pattern_matches(prog, de, dr)

    def test_ii_requires_sink_reach(self):
        # sink(δr)=S3 after sink(δe)=S1 → condition ii fails
        prog, de, dr = self._mk(2, 1, "S1", "S1", "S3", "S3")
        assert not pattern_matches(prog, de, dr)


class TestTransitiveReductionGeneral:
    def test_chain_covers_long_dependence(self):
        """A Δ=1 dep between the same statements covers the Δ=3 one:
        S2(i)→S1(i+1)→S2(i+1)→S1(i+2)→…→S1(i+3)."""

        prog = LoopProgram(
            statements=(
                Statement(
                    "S1",
                    ArrayRef("a", 0),
                    (ArrayRef("b", -1), ArrayRef("b", -3)),
                ),
                Statement("S2", ArrayRef("b", 0), (ArrayRef("a", 0),)),
            ),
            bounds=((1, 10),),
        )
        deps = analyze(prog)
        res = eliminate_transitive(prog, deps)
        gone = {(d.source, d.sink, d.distance) for d in res.eliminated}
        assert ("S2", "S1", (3,)) in gone
        retained = {(d.source, d.sink, d.distance) for d in res.retained}
        assert ("S2", "S1", (1,)) in retained

    def test_uncoverable_dependence_is_retained(self):
        """A lone Δ=2 dep with no helpers must be retained."""

        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), ()),
                Statement("S2", ArrayRef("b", 0), (ArrayRef("a", -2),)),
            ),
            bounds=((1, 8),),
        )
        res = eliminate_transitive(prog, analyze(prog))
        assert len(res.eliminated) == 0
        assert len(res.retained) == 1

    def test_multiple_deps_cooperate(self):
        """Paper: 'It's possible for multiple dependence to work together to
        eliminate another dependence.'  Δ=1 and Δ=2 deps jointly cover Δ=3."""

        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), ()),
                Statement("S2", ArrayRef("b", 0), (ArrayRef("a", -1),)),
                Statement(
                    "S3",
                    ArrayRef("c", 0),
                    (ArrayRef("b", -2), ArrayRef("a", -3)),
                ),
            ),
            bounds=((1, 12),),
        )
        deps = analyze(prog)
        res = eliminate_transitive(prog, deps)
        gone = {(d.source, d.sink, d.distance) for d in res.eliminated}
        # S1→S3 Δ3 covered by S1(i)→S2(i+1) [Δ1] → S3(i+3) [Δ2]:
        # neither helper alone spans Δ3
        assert ("S1", "S3", (3,)) in gone
        assert len(res.retained) == 2
        # sanity: each helper alone does NOT cover Δ3
        from repro.core.elimination import _covered

        de = next(d for d in deps if d.distance == (3,))
        helpers = [d for d in deps if d.distance != (3,)]
        for h in helpers:
            ok, _ = _covered(prog, de, [h])
            assert not ok

    def test_strip_dependences_removes_pairs(self):
        prog = paper_alg6()
        deps = analyze(prog)
        sync = insert_synchronization(prog, deps)
        res = eliminate_transitive(prog, deps)
        stripped = strip_dependences(sync, res.eliminated)
        assert stripped.sync_instruction_count()["total"] == 2
        # the Δ=1 c-dep's pair survives
        assert any(s.reg is not None for s in stripped.post_sends["S3"])
        assert stripped.pre_waits["S3"] == ()


class TestSendMerging:
    def test_shared_source_shares_send(self):
        """§4.2: a single send can synchronize several dependences."""

        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), ()),
                Statement("S2", ArrayRef("b", 0), (ArrayRef("a", -1),)),
                Statement("S3", ArrayRef("c", 0), (ArrayRef("a", -3),)),
            ),
            bounds=((1, 8),),
        )
        deps = analyze(prog)
        merged = insert_synchronization(prog, deps, merge=True)
        unmerged = insert_synchronization(prog, deps, merge=False)
        assert unmerged.sync_instruction_count()["sends"] == 2
        assert merged.sync_instruction_count()["sends"] == 1
        assert merged.sync_instruction_count()["waits"] == 2
