"""Wavefront backend: differential equivalence across executors, layering
properties, cycle diagnostics, and the compiler-integration surface.

The differential suite runs ≥ 10 programs (the paper's Alg. 1/4/6 — Alg. 4
is the loop Alg. 5 synchronizes — plus 2-D distance cases, guards, stencils
and seeded-random programs) through sequential / threaded / wavefront
execution under naive and optimized synchronization, asserting bit-equal
stores via tests/oracle.py.  The corpus itself lives in tests/programs.py,
shared with the cyclic and inspector suites.
"""

import pytest

from oracle import assert_equivalent, run_all_backends
from programs import DIFFERENTIAL_PROGRAMS, distance_2d
from repro.core import (
    ArrayRef,
    LoopProgram,
    Statement,
    WavefrontError,
    analyze,
    insert_synchronization,
    paper_alg4,
    paper_alg6,
    plan,
    run_threaded,
    run_wavefront,
    schedule_wavefronts,
)
from repro.core.dependence import FLOW, Dependence, paper_alg4_dependences
from repro.core.wavefront import schedule_levels


class TestDifferentialEquivalence:
    @pytest.mark.parametrize(
        "name,prog", DIFFERENTIAL_PROGRAMS, ids=[n for n, _ in DIFFERENTIAL_PROGRAMS]
    )
    def test_all_backends_bit_equal(self, name, prog):
        assert_equivalent(prog)

    def test_stalled_threads_still_equal(self):
        """Adversarial stalls perturb the threaded side only — results must
        stay equal across every backend."""

        assert_equivalent(
            paper_alg6(6), stalls={("S3", (1,)): 0.1, ("S2", (2,)): 0.05}
        )

    def test_results_keyed_by_backend(self):
        """Every *registered* backend shows up in the matrix — including the
        xla backend, with zero per-test changes (the registry contract)."""

        res = run_all_backends(paper_alg6(5), methods=("isd",))
        assert set(res) == {
            "sequential",
            "threaded/isd/naive",
            "threaded/isd/optimized",
            "wavefront/isd/naive",
            "wavefront/isd/optimized",
            "xla/isd/naive",
            "xla/isd/optimized",
            "xla_spmd/isd/naive",
            "xla_spmd/isd/optimized",
        }


class TestUnderSynchronized:
    def test_paper_alg5_graph_mis_executes_deterministically(self):
        """The paper's own Alg. 5 dependence graph misses S2 δf(b,Δ=1) S1.
        The threaded machine needs an adversarial stall to expose the race;
        the wavefront layering mis-executes it *deterministically* — the
        missing edge lets every S1 instance batch at level 0."""

        sync = insert_synchronization(paper_alg4(8), paper_alg4_dependences())
        rep = run_wavefront(sync)
        assert not rep.matches_sequential

    def test_dropping_retained_dep_is_detected(self):
        prog = paper_alg6(6)
        deps = analyze(prog)
        keep_wrong = [d for d in deps if d.loop_carried and d.delta == 1]
        from repro.core import strip_dependences

        sync = insert_synchronization(prog, deps)
        broken = strip_dependences(sync, keep_wrong)
        assert not run_wavefront(broken).matches_sequential


class TestLayering:
    def test_parallel_loop_depth_is_statement_count(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", 0),)),
                Statement("S2", ArrayRef("c", 0), (ArrayRef("a", 0),)),
            ),
            bounds=((0, 64),),
        )
        rep = plan(prog, method="isd").compile("wavefront").report()
        wf = rep.wavefront
        assert wf.depth == 2  # program order only: one level per statement
        assert wf.max_width == 64
        assert wf.batched_ops == 2

    def test_alg6_depth_tracks_sequential_chain(self):
        """Alg. 6 retains the Δ=1 c-dependence; the S2/S3 chain is truly
        sequential, so depth grows ~2 per iteration while S1 stays batched."""

        rep = plan(paper_alg6(10), method="isd").compile("wavefront").report()
        wf = rep.wavefront
        assert wf.depth == 2 * 9 + 1
        lvl = wf.level_of()
        assert all(lvl[("S1", (i,))] == 0 for i in range(1, 10))
        assert lvl[("S2", (3,))] == 5 and lvl[("S3", (3,))] == 6

    def test_levels_respect_enforced_edges(self):
        """Every retained dependence edge and every program-order edge must
        strictly increase the level."""

        for _name, prog in DIFFERENTIAL_PROGRAMS[:6]:
            rep = plan(prog, method="isd").compile("wavefront").report()
            wf = rep.wavefront
            lvl = wf.level_of()
            names = prog.names
            for it in prog.iterations():
                for a, b in zip(names, names[1:]):
                    assert lvl[(a, it)] < lvl[(b, it)]
                for d in rep.elimination.retained:
                    dst = tuple(x + dd for x, dd in zip(it, d.distance))
                    if (d.sink, dst) in lvl:
                        assert lvl[(d.source, it)] < lvl[(d.sink, dst)]

    def test_instances_cover_iteration_space(self):
        prog = paper_alg4(7)
        wf = schedule_wavefronts(insert_synchronization(prog, analyze(prog)))
        assert wf.instances == len(prog.statements) * len(prog.iterations())
        lvl = wf.level_of()
        assert len(lvl) == wf.instances

    def test_summary_fields(self):
        rep = plan(paper_alg6(6), method="isd").compile("wavefront").report()
        s = rep.summary()
        assert s["backend"] == "wavefront"
        assert s["wavefront_depth"] == rep.wavefront.depth
        assert s["wavefront_batched_ops"] == rep.wavefront.batched_ops
        assert rep.wavefront.summary()["depth"] == rep.wavefront.depth


class TestDiagnostics:
    def test_negative_distance_rejected_with_diagnostic(self):
        """A lexicographically negative distance contradicts sequential
        order — rejected at schedule time with the offending dependence
        named (cyclic case: see tests/test_scc.py for witness cycles)."""

        prog = paper_alg6(6)
        sync = insert_synchronization(prog, analyze(prog))
        bad = Dependence(FLOW, "S1", "S2", "a", (-1,))
        with pytest.raises(
            WavefrontError, match="sequential execution order"
        ):
            schedule_wavefronts(sync, [bad])

    def test_mixed_sign_2d_distance_now_schedules(self):
        """Per-dimension sign mixes with lexicographically positive
        distances are no longer rejected: the SCC-condensed hybrid
        schedules them (here as a cross-SCC edge between instance units)."""

        prog = distance_2d()
        sync = insert_synchronization(prog, analyze(prog))
        mixed = Dependence(FLOW, "S1", "S2", "a", (1, -1))
        wf = schedule_wavefronts(sync, list(analyze(prog)) + [mixed])
        lvl = wf.level_of()
        for it in prog.iterations():
            dst = (it[0] + 1, it[1] - 1)
            if ("S2", dst) in lvl:
                assert lvl[("S1", it)] < lvl[("S2", dst)]

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown backend"):
            plan(paper_alg6(4)).compile("gpu").report()

    def test_out_of_store_access_raises(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -20),)),
            ),
            bounds=((0, 4),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        with pytest.raises(KeyError, match="initialized store"):
            run_wavefront(sync)

    def test_out_of_store_write_raises_on_narrow_groups_too(self):
        """The error contract must not depend on wavefront width: a narrow
        (scalar-path) over-upper-bound write gets the same KeyError as the
        batched scatter, not a raw numpy IndexError."""

        prog = LoopProgram(
            statements=(Statement("S1", ArrayRef("a", 20), ()),),
            bounds=((0, 2),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        with pytest.raises(KeyError, match="initialized store"):
            run_wavefront(sync, store={"a": {(i,): 0.0 for i in range(4)}})

    def test_sparse_store_read_raises_not_garbage(self):
        """A user store with holes inside its bounding box must fail loudly
        on a read of a missing cell (as run_sequential does) instead of
        consuming uninitialized dense memory."""

        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            ),
            bounds=((1, 4),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        sparse = {
            "a": {(i,): 0.0 for i in range(0, 5)},
            "b": {(0,): 1.0, (4,): 2.0},  # holes at 1..3
        }
        with pytest.raises(KeyError, match="uninitialized"):
            run_wavefront(sync, store=sparse)

    def test_sparse_store_covered_accesses_still_work(self):
        prog = LoopProgram(
            statements=(
                Statement("S1", ArrayRef("a", 0), (ArrayRef("b", -1),)),
            ),
            bounds=((1, 4),),
        )
        sync = insert_synchronization(prog, analyze(prog))
        store = {
            "a": {(i,): 0.0 for i in range(0, 5)},
            "b": {(i,): float(i) for i in (0, 1, 2, 4)},  # (3,) unused hole
        }
        rep = run_wavefront(sync, store=store, compare=False)
        from repro.core import run_sequential

        assert rep.store == run_sequential(sync.program, store)


class TestKernelScheduleReuse:
    def test_kloop_layering_shows_double_buffering(self):
        from repro.kernels.pipelined_matmul.schedule import (
            kloop_wavefronts,
            overlapped_levels,
            plan_pipeline,
        )

        single = plan_pipeline(1, steps=8)
        double = plan_pipeline(2, steps=8)
        assert overlapped_levels(single.wavefront) == 0
        assert overlapped_levels(double.wavefront) == 7
        wf = kloop_wavefronts(2, steps=8)
        assert wf.depth == double.wavefront.depth
        assert wf.summary()["model"] == "procmap"

    def test_procmap_levels_respect_processor_order(self):
        from repro.kernels.pipelined_matmul.schedule import (
            PROCESSORS,
            kloop_dependences,
            make_kloop_program,
        )

        prog = make_kloop_program(6)
        wf = schedule_levels(
            prog, kloop_dependences(2), model="procmap", processors=PROCESSORS
        )
        lvl = wf.level_of()
        for i in range(5):
            assert lvl[("ISSUE", (i,))] < lvl[("COMPUTE", (i,))]
            assert lvl[("COMPUTE", (i,))] < lvl[("ISSUE", (i + 1,))]
            assert lvl[("LOAD", (i,))] < lvl[("LOAD", (i + 1,))]


@pytest.mark.slow
class TestSpeedup:
    def test_wavefront_at_least_5x_faster_than_threads(self):
        """The acceptance bar: ≥ 5× on a 1024-iteration loop (observed
        ~25×; threads pay per-iteration spawn + send/wait round-trips)."""

        import time

        rep = plan(paper_alg6(1025), method="isd").compile("wavefront").report()
        run_wavefront(rep.optimized_sync, schedule=rep.wavefront, compare=False)
        t0 = time.perf_counter()
        run_wavefront(rep.optimized_sync, schedule=rep.wavefront, compare=False)
        t_wave = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_threaded(rep.optimized_sync, compare=False, timeout=120.0)
        t_thread = time.perf_counter() - t0
        assert t_thread / t_wave >= 5.0
