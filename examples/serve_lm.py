"""Batched serving driver: prefill a prompt batch, then greedy-decode with
the KV cache — the ``serve_step`` the decode dry-run cells lower.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral_8x7b --tokens 32
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2_2_7b --batch 8
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, get_smoke_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import model_zoo as zoo


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi_6b", choices=ARCHITECTURES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = zoo.init(key, cfg)
    max_len = args.prompt_len + args.tokens + (
        cfg.num_patches if cfg.frontend == "vision" else 0
    )

    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.random.normal(
            key, (args.batch, cfg.encoder.num_frames, cfg.d_model)
        )
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model)
        )

    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))

    cache = zoo.init_cache(cfg, args.batch, max_len)
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    cache_len = args.prompt_len + (
        cfg.num_patches if cfg.frontend == "vision" else 0
    )
    generated = [cur]
    t0 = time.perf_counter()
    for t in range(args.tokens - 1):
        cur, cache = serve(params, cur, cache, jnp.int32(cache_len))
        cache_len += 1
        generated.append(cur)
    jax.block_until_ready(cur)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    total = args.batch * args.tokens
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms")
    print(
        f"decode:  {args.tokens-1} steps in {t_decode*1e3:.1f} ms "
        f"({total/max(t_decode,1e-9):.0f} tok/s batched, CPU interpret-scale)"
    )
    print("sample token ids:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
